//! Quickstart: load a trained model, apply Layer Parallelism to the middle
//! of the network, and generate text — the 20-line tour of the public API.
//!
//!     make artifacts && make models
//!     cargo run --release --example quickstart

use truedepth::gen::{generate, Sampler};
use truedepth::harness::{default_net, ScoringCtx};
use truedepth::model::{transform, ServingModel};

fn main() -> truedepth::Result<()> {
    // 1. Load the AOT artifact manifest + trained weights.
    let ctx = ScoringCtx::load("td-small")?;
    let weights = ctx.weights()?;
    let n_layers = ctx.entry().config.n_layers;

    // 2. Build a computational-graph plan: pairs of consecutive layers in
    //    [2, 10) run in parallel — depth 12 → 8, all-reduces 24 → 16/token.
    let plan = transform::pair_parallel(n_layers, 2, 10, true);
    println!("plan: {} (effective depth {})", plan.describe(), plan.effective_depth());

    // 3. Bring up the tensor-parallel serving runtime (2 simulated
    //    accelerators + calibrated interconnect) and generate.
    let model = ServingModel::new(&ctx.manifest, "td-small", &weights, &plan, default_net())?;
    for prompt in ["the capital of avaria is", "copy : ostrich -> ", "3 + 4 = "] {
        let g = generate(&model, prompt, 16, &Sampler::Greedy)?;
        println!("{prompt:>28} → {}", g.text.trim_end());
    }
    Ok(())
}
