//! End-to-end serving driver (the DESIGN.md §validation workload): bring up
//! the full coordinator stack — router → batcher → continuous-batching
//! scheduler → 2-rank tensor-parallel mesh — on the trained td-small model
//! with Layer Parallelism enabled, fire a batch of concurrent requests, and
//! report latency/throughput. Run twice (with/without LP) to see the
//! paper's speedup end-to-end:
//!
//!     cargo run --release --example serve_batch            # LP on
//!     cargo run --release --example serve_batch -- --depth 12   # baseline
//!     cargo run --release --example serve_batch -- --tiers      # one weight
//!         # set, every manifest plan variant (dense/lp/lp_aggr) served
//!         # concurrently — requests cycle through the tiers and the report
//!         # shows per-tier modelled tokens/sec
//!     cargo run --release --example serve_batch -- --tiers \
//!         --trace-out trace.json --metrics-out metrics.json
//!         # also export a Chrome/Perfetto trace + metrics snapshot of the
//!         # run on the simulated clock (README "Observability")
//!     cargo run --release --example serve_batch -- --paged --page-pool 96
//!         # paged KV cache under memory pressure: every request shares one
//!         # system prompt, so the prefix index prefills it once and the
//!         # report's "paged kv" line shows the hits; --page-pool caps the
//!         # logical pools (over-pool requests are rejected at admission)

use std::sync::Arc;

use truedepth::api::CompletionRequest;
use truedepth::cli::Args;
use truedepth::config::ServerConfig;
use truedepth::coordinator::router::Router;
use truedepth::coordinator::Server;
use truedepth::harness::{default_net, ScoringCtx};
use truedepth::model::{transform, ServingModel};
use truedepth::obs::{MetricsSnapshot, Tracer};
use truedepth::text::corpus::{self, DATA_SEED};

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&["tiers", "paged"]);
    let model_name = args.get_or("model", "td-small");
    let n_requests = args.get_usize("requests", 24);
    let max_new = args.get_usize("max-new", 16);
    let multi = args.flag("tiers");

    let ctx = ScoringCtx::load(model_name)?;
    let weights = ctx.weights()?;
    let n = ctx.entry().config.n_layers;
    let mut serving = if multi {
        // the plan-variant registry: every manifest tier from one weight set
        ServingModel::from_manifest(&ctx.manifest, model_name, &weights, default_net())?
    } else {
        let depth = args.get_usize("depth", n - 4); // default: Δ=8 LP
        let plan = if depth == n {
            transform::sequential(n)
        } else {
            transform::lp_for_depth(n, depth, n - 2)
                .ok_or_else(|| truedepth::Error::msg("bad depth"))?
        };
        ServingModel::new(&ctx.manifest, model_name, &weights, &plan, default_net())?
    };
    // --paged: serve from the paged KV cache; --page-pool shrinks the
    // logical page pools to model memory pressure (see README "Paged KV
    // cache" — over-pool requests are rejected at admission, cold shared
    // blocks are evicted LRU under load).
    let paged = args.flag("paged");
    if paged {
        serving.enable_paging()?;
        let pool = args.get_usize("page-pool", 0);
        if pool > 0 {
            serving.set_page_capacity(pool);
        }
    }
    let tiers: Vec<String> =
        serving.variant_ids().iter().map(|v| v.as_str().to_string()).collect();
    let summary: Vec<String> = serving
        .variant_ids()
        .iter()
        .map(|v| {
            let var = serving.variant(v).unwrap();
            format!(
                "{v}: depth {} ({} all-reduces/token)",
                var.effective_depth(),
                var.all_reduces_per_token()
            )
        })
        .collect();
    println!("== serve_batch: {model_name} — {} ==", summary.join("; "));

    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| Arc::new(Tracer::new()));
    let server = Arc::new(match &tracer {
        Some(t) => Server::start_traced(serving, &ServerConfig::default(), t.clone()),
        None => Server::start(serving, &ServerConfig::default()),
    });
    let mut router = Router::new();
    router.add_backend(model_name, server.clone());

    // fire all requests up-front (continuous batching shares decode steps;
    // under --tiers the requests cycle through the registry's tiers)
    let t0 = std::time::Instant::now();
    // under --paged every request shares one system prompt: the prefix
    // index prefills those leading blocks once, later requests attach them
    const SYSTEM_PROMPT: &str = "system: you are a terse assistant. answer only from the \
         provided context, cite sources, never speculate. ";
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            let doc = corpus::eval_doc(DATA_SEED, 5000 + i as u64);
            let snippet = &doc[..doc.len().min(if paged { 16 } else { 64 })];
            let prompt = if paged {
                format!("{SYSTEM_PROMPT}{snippet}")
            } else {
                snippet.to_string()
            };
            let mut req = CompletionRequest::new(prompt).max_tokens(max_new);
            if multi {
                req = req.tier(&tiers[i % tiers.len()]);
            }
            router.route(model_name, req)
        })
        .collect::<truedepth::Result<_>>()?;

    let mut ok = 0usize;
    let mut tokens = 0usize;
    for h in handles {
        let resp = h.wait()?;
        assert!(resp.error.is_none(), "request failed: {:?}", resp.error);
        assert!(resp.generated_tokens() > 0);
        ok += 1;
        tokens += resp.generated_tokens();
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("{}", server.metrics.report());
    let (sync_ops, sync_ms, compute_ms, _) = (0, 0.0, 0.0, 0); // mesh owned by scheduler thread
    let _ = (sync_ops, sync_ms, compute_ms);
    println!(
        "\n{ok}/{n_requests} ok; {tokens} tokens in {wall:.2}s → {:.1} tok/s end-to-end",
        tokens as f64 / wall
    );

    // exports: shut the server down first so the scheduler drains and
    // flushes the mesh event track into the tracer
    if trace_out.is_some() || metrics_out.is_some() {
        let metrics = server.metrics.clone();
        drop(router);
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
        if let (Some(tr), Some(path)) = (&tracer, &trace_out) {
            tr.write_chrome(path)?;
            println!("trace: {} ({} events)", path.display(), tr.len());
        }
        if let Some(path) = &metrics_out {
            MetricsSnapshot::new("serve_batch").with_server(&metrics).write(path)?;
            println!("metrics snapshot: {}", path.display());
        }
    }
    Ok(())
}
