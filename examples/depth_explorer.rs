//! Depth explorer: interactively probe the effective depth of a model —
//! apply any §3 transform to any window and see perplexity, effective
//! depth, and (for servable plans) a sample generation side by side with
//! the untouched model.
//!
//!     cargo run --release --example depth_explorer -- \
//!         --transform pair --s 2 --e 10
//!     cargo run --release --example depth_explorer -- \
//!         --transform prune --s 4 --e 7

use truedepth::cli::Args;
use truedepth::eval::ppl::{eval_windows, perplexity};
use truedepth::gen::{generate, Sampler};
use truedepth::harness::{no_net, ScoringCtx};
use truedepth::model::{transform, Scorer, ServingModel};
use truedepth::text::corpus::DATA_SEED;
use truedepth::util::rng::SplitMix64;

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "td-small");
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let s = args.get_usize("s", 2);
    let e = args.get_usize("e", 10);
    let kind = args.get_or("transform", "pair");

    let plan = match kind {
        "shuffle" => transform::shuffle(n, s, e, &mut SplitMix64::new(7)),
        "prune" => transform::prune(n, s, e),
        "merge" => transform::merge(n, s, e),
        "parallel" => transform::parallel(n, s, e),
        "pair" => transform::pair_parallel(n, s, e, true),
        "triplet" => transform::triplet_parallel(n, s, e),
        other => return Err(truedepth::Error::msg(format!("unknown transform {other}"))),
    };
    let base = transform::sequential(n);

    println!("model {model}: {n} layers");
    println!("transform {kind} on [{s}, {e})");
    println!("  plan: {}", plan.describe());
    println!("  effective depth: {} (base {})", plan.effective_depth(), n);
    println!(
        "  all-reduces/token under TP: {} (base {})",
        plan.all_reduces_per_token(),
        base.all_reduces_per_token()
    );

    let scorer = Scorer::new(&ctx.engine, entry, &weights, 128)?;
    let windows = eval_windows(128, 2, DATA_SEED);
    let ppl_base = perplexity(&scorer, &base, &windows)?;
    let ppl_plan = perplexity(&scorer, &plan, &windows)?;
    println!("  perplexity: {ppl_plan:.3} (base {ppl_base:.3}, Δppl {:+.3})", ppl_plan - ppl_base);

    // servable plans also get a side-by-side generation
    let servable = plan
        .stages
        .iter()
        .all(|st| matches!(st, truedepth::model::Stage::Seq(_) | truedepth::model::Stage::PairLp(..)));
    if servable {
        let prompt = args.get_or("prompt", "the capital of mendia is");
        let sm = ServingModel::new(&ctx.manifest, model, &weights, &plan, no_net())?;
        let sb = ServingModel::new(&ctx.manifest, model, &weights, &base, no_net())?;
        let ga = generate(&sm, prompt, 16, &Sampler::Greedy)?;
        let gb = generate(&sb, prompt, 16, &Sampler::Greedy)?;
        println!("  sample ({prompt:?}):");
        println!("    transformed: {}", ga.text.trim_end());
        println!("    base:        {}", gb.text.trim_end());
    } else {
        println!("  (plan not servable under TP — scoring only)");
    }
    Ok(())
}
