"""Build-time training / fine-tuning for the td-* models.

Pre-training (stands in for the paper's pre-trained Llama/Qwen checkpoints):

    python train.py --model td-small --steps 3000 --out ../checkpoints/td-small

Table-2 fine-tuning — restore accuracy of an LP-transformed model by tuning
ONLY the layers inside the LP window, against the *deployed* LP-TP graph:

    python train.py --model td-small --finetune ../checkpoints/td-small \
        --lp-start 2 --lp-end 10 --steps 1024 --out ../checkpoints/td-small-lp-ft1024

Training uses the pure-jnp path (fast + differentiable); kernel equivalence
with the Pallas path is asserted by the pytest suite, and inference always
runs through the Pallas-lowered artifacts.
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile import params as P
from compile import tok
from compile.modelcfg import CONFIGS

DATA_SEED = 20260711


# --------------------------------------------------------------------------
# Data pipeline: pack documents into fixed-length next-token windows
# --------------------------------------------------------------------------

class Packer:
    """Concatenate BOS-separated documents into [seqlen+1] training windows."""

    def __init__(self, seed: int, seqlen: int, start_doc: int = 0,
                 eval_split: bool = False):
        self.seed = seed
        self.seqlen = seqlen
        self.doc_idx = start_doc
        self.eval_split = eval_split
        self.buf: list[int] = []

    def _next_doc(self) -> list[int]:
        i = self.doc_idx
        self.doc_idx += 1
        text = (D.eval_doc(self.seed, i) if self.eval_split
                else D.gen_corpus_doc(self.seed, i))
        return tok.encode(text, bos=True)

    def next_window(self) -> np.ndarray:
        need = self.seqlen + 1
        while len(self.buf) < need:
            self.buf.extend(self._next_doc())
        w = np.asarray(self.buf[:need], dtype=np.int32)
        self.buf = self.buf[need:]
        return w

    def batch(self, b: int) -> np.ndarray:
        return np.stack([self.next_window() for _ in range(b)])


# --------------------------------------------------------------------------
# AdamW (hand-rolled; no optax in this environment)
# --------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        return p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def grad_mask_for_window(params, lo: int, hi: int):
    """1.0 for params of layers in [lo, hi), plus nothing else — the Table-2
    protocol fine-tunes only the LP-transformed layers."""
    mask = jax.tree_util.tree_map(lambda _: 0.0, params)
    for i in range(lo, hi):
        mask["layers"][i] = jax.tree_util.tree_map(lambda _: 1.0,
                                                   params["layers"][i])
    return mask


# --------------------------------------------------------------------------
# Train loop
# --------------------------------------------------------------------------

def run(args) -> None:
    cfg = CONFIGS[args.model]
    out = Path(args.out)

    if args.finetune:
        params = P.load_checkpoint(args.finetune, cfg)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        pairs = tuple(M.lp_pairs_for_window(cfg.n_layers, args.lp_start,
                                            args.lp_end))
        forward = functools.partial(M.forward_lp, pairs=pairs)
        gmask = grad_mask_for_window(params, args.lp_start, args.lp_end)
        mode = f"finetune lp[{args.lp_start},{args.lp_end}) pairs={pairs}"
    else:
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        forward = M.forward_seq
        gmask = None
        mode = "pretrain"

    def loss(p, batch):
        return M.loss_fn(cfg, p, batch, forward=forward, impl="jnp")

    @jax.jit
    def step(p, opt, batch, lr):
        l, g = jax.value_and_grad(loss)(p, batch)
        if gmask is not None:
            g = jax.tree_util.tree_map(lambda gi, mi: gi * mi, g, gmask)
        p2, opt2 = adamw_update(p, g, opt, lr)
        return p2, opt2, l

    opt = adamw_init(params)
    packer = Packer(DATA_SEED, args.seqlen, start_doc=args.start_doc)
    log = []
    t0 = time.time()
    for it in range(1, args.steps + 1):
        batch = jnp.asarray(packer.batch(args.batch))
        # linear warmup + cosine decay
        warm = min(1.0, it / max(1, args.warmup))
        prog = it / args.steps
        lr = args.lr * warm * (0.5 * (1 + np.cos(np.pi * min(1.0, prog))))
        params, opt, l = step(params, opt, batch, lr)
        if it % args.log_every == 0 or it == 1:
            l = float(l)
            dt = time.time() - t0
            log.append({"step": it, "loss": l, "lr": float(lr),
                        "elapsed_s": round(dt, 1)})
            print(f"step {it:5d}  loss {l:.4f}  ppl {np.exp(l):8.2f}  "
                  f"lr {lr:.2e}  {it / dt:.1f} it/s", flush=True)

    meta = {"mode": mode, "steps": args.steps, "batch": args.batch,
            "seqlen": args.seqlen, "lr": args.lr, "seed": args.seed,
            "data_seed": DATA_SEED, "final_loss": log[-1]["loss"] if log else None}
    P.save_checkpoint(out, cfg, params, meta)
    (out / "train_log.json").write_text(json.dumps(log, indent=1))
    print(f"saved checkpoint -> {out} ({mode})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="td-small", choices=list(CONFIGS))
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seqlen", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--start-doc", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=50)
    ap.add_argument("--out", required=True)
    ap.add_argument("--finetune", default=None,
                    help="checkpoint dir to fine-tune (Table-2 protocol)")
    ap.add_argument("--lp-start", type=int, default=None)
    ap.add_argument("--lp-end", type=int, default=None)
    args = ap.parse_args()
    if args.finetune and (args.lp_start is None or args.lp_end is None):
        ap.error("--finetune requires --lp-start/--lp-end")
    run(args)


if __name__ == "__main__":
    main()
