"""Corpus/tokenizer determinism + golden values shared with the rust mirror.

The GOLDEN_* constants below are duplicated in rust/src/util/rng.rs and
rust/src/text/corpus.rs tests; a drift on either side fails both suites.
"""

import pytest

from compile import data as D
from compile import tok

# sha256 of gen_corpus_doc(20260711, 0) — also asserted by rust tests.
GOLDEN_DOC_HASH = "0e540f2d84c1eb7b5a134c6c9dc08606ed321b2ec2e9ab1f410e40cb2bb8cebf"


def test_splitmix64_reference_values():
    rng = D.SplitMix64(42)
    vals = [rng.next_u64() for _ in range(4)]
    # Known-good SplitMix64 stream for seed 42 (cross-checked with the
    # canonical C implementation; rust mirror asserts the same numbers).
    assert vals == [13679457532755275413, 2949826092126892291,
                    5139283748462763858, 6349198060258255764], vals


def test_below_is_uniform_enough():
    rng = D.SplitMix64(7)
    counts = [0] * 10
    for _ in range(10000):
        counts[rng.below(10)] += 1
    assert min(counts) > 800 and max(counts) < 1200


def test_corpus_is_deterministic():
    assert D.gen_corpus_doc(1, 5) == D.gen_corpus_doc(1, 5)
    assert D.gen_corpus_doc(1, 5) != D.gen_corpus_doc(1, 6)
    assert D.eval_doc(1, 0) == D.gen_corpus_doc(1, D.EVAL_BASE)


def test_corpus_golden_doc():
    """Golden doc asserted identically by rust/src/text/corpus.rs."""
    doc = D.gen_corpus_doc(20260711, 0)
    assert isinstance(doc, str) and len(doc) > 20
    # lock the exact value (regenerate both goldens together if the
    # generator changes):
    import hashlib
    h = hashlib.sha256(doc.encode()).hexdigest()
    assert h == GOLDEN_DOC_HASH, f"corpus drifted: {h} doc={doc[:80]}..."


def test_relation_consistency():
    """capital_of must be a function (same country -> same capital) and the
    tables must be aligned — the ICL relation task depends on this."""
    assert len(D.COUNTRIES) == len(D.CAPITALS)
    for i in range(len(D.COUNTRIES)):
        assert D.capital_of(i) == D.CAPITALS[i]


def test_arith_items_are_correct():
    rng = D.SplitMix64(123)
    for _ in range(200):
        s = D.gen_arith(rng)
        lhs, rhs = s.rstrip(" .").split("=")
        a, op, b = lhs.split()
        expected = int(a) + int(b) if op == "+" else int(a) - int(b)
        assert int(rhs) == expected, s
        assert int(rhs) >= 0


def test_reverse_items_are_correct():
    rng = D.SplitMix64(5)
    for _ in range(100):
        s = D.gen_reverse(rng)
        body = s[len("rev : "):].rstrip(" .")
        w, r = body.split(" -> ")
        assert r == w[::-1]


def test_pattern_items_are_correct():
    rng = D.SplitMix64(9)
    for _ in range(100):
        s = D.gen_pattern(rng)
        body = s[len("next : "):].rstrip(" .")
        seq, nxt = body.split(" -> ")
        letters = seq.split()
        assert len(letters) == 3
        idx = [D.LETTERS.index(c) for c in letters]
        assert idx[1] == idx[0] + 1 and idx[2] == idx[1] + 1
        assert D.LETTERS.index(nxt) == idx[2] + 1


def test_tokenizer_roundtrip():
    s = "the capital of avaria is avaport . 3 + 5 = 8 ."
    ids = tok.encode(s, bos=True, eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == s
    assert max(ids) < tok.VOCAB_SIZE


def test_tokenizer_pad():
    ids = tok.encode("abc")
    p = tok.pad_to(ids, 8)
    assert len(p) == 8 and p[3:] == [tok.PAD] * 5
    assert tok.pad_to(ids, 3) == ids  # exact fit is a no-op
    # regression: undersized lengths used to silently drop the tail
    with pytest.raises(ValueError):
        tok.pad_to(list(range(10)), 4)
