"""L2 model invariants: sharding identities, graph-mode equivalences, and
prefill/decode consistency — the properties the rust coordinator relies on.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import tok
from compile.modelcfg import ModelConfig

CFG = ModelConfig(name="t", vocab=tok.VOCAB_SIZE, d_model=64, n_layers=4,
                  n_heads=4, head_dim=16, d_ff=128, ctx=64, slots=2)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 255, size=32).astype(np.int32))


def test_forward_shapes(params, tokens):
    logits = M.forward_seq(CFG, params, tokens)
    assert logits.shape == (32, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_pallas_and_jnp_forward_agree(params, tokens):
    a = M.forward_seq(CFG, params, tokens, impl="jnp")
    b = M.forward_seq(CFG, params, tokens, impl="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_lp_with_no_pairs_is_sequential(params, tokens):
    a = M.forward_seq(CFG, params, tokens)
    b = M.forward_lp(CFG, params, tokens, pairs=[])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_lp_pairs_change_output_but_stay_finite(params, tokens):
    a = M.forward_seq(CFG, params, tokens)
    b = M.forward_lp(CFG, params, tokens, pairs=[(1, 2)])
    assert np.isfinite(np.asarray(b)).all()
    assert not np.allclose(a, b)


def test_lp_pairs_for_window():
    assert M.lp_pairs_for_window(12, 2, 10) == [(2, 3), (4, 5), (6, 7), (8, 9)]
    assert M.lp_pairs_for_window(12, 2, 7) == [(2, 3), (4, 5)]  # odd tail stays
    assert M.lp_pairs_for_window(12, 5, 5) == []


def test_tp_shard_sum_equals_full_attention(params, tokens):
    """TP correctness identity: full attention delta == sum of the two
    half-head shards. This is what makes the coordinator's all-reduce the
    mathematically right combinator."""
    h = M.forward_seq(CFG, params, tokens)  # any activation-like tensor
    h = jnp.tanh(h[:, : CFG.d_model])       # [T, D]
    lp = params["layers"][0]
    full = M.attn_delta(CFG, h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                        lp["wo"])
    d = CFG.d_model
    half = d // 2
    shard_fn = M.make_shard_attn_prefill(CFG, impl="jnp")
    p0, _, _ = shard_fn(h, lp["ln1"], lp["wq"][:, :half], lp["wk"][:, :half],
                        lp["wv"][:, :half], lp["wo"][:half, :])
    p1, _, _ = shard_fn(h, lp["ln1"], lp["wq"][:, half:], lp["wk"][:, half:],
                        lp["wv"][:, half:], lp["wo"][half:, :])
    np.testing.assert_allclose(p0 + p1, full, rtol=1e-4, atol=1e-4)


def test_tp_ffn_shard_sum_equals_full(params, tokens):
    h = jnp.tanh(M.forward_seq(CFG, params, tokens)[:, : CFG.d_model])
    lp = params["layers"][1]
    full = M.ffn_delta(CFG, h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])
    fh = CFG.d_ff // 2
    shard = M.make_shard_ffn(CFG, impl="jnp")
    p0, = shard(h, lp["ln2"], lp["wg"][:, :fh], lp["wu"][:, :fh], lp["wd"][:fh, :])
    p1, = shard(h, lp["ln2"], lp["wg"][:, fh:], lp["wu"][:, fh:], lp["wd"][fh:, :])
    np.testing.assert_allclose(p0 + p1, full, rtol=1e-4, atol=1e-4)


def test_lp_fused_equals_sum_of_attn_deltas(params, tokens):
    """abl2 identity: the fused dual-layer kernel == A_a(x) + A_b(x)."""
    h = jnp.tanh(M.forward_seq(CFG, params, tokens)[:, : CFG.d_model])
    t = h.shape[0]
    # pad h to T=128 bucket shape used by the fused artifact? fused fn is
    # shape-generic; call directly at T=32.
    la, lb = params["layers"][0], params["layers"][1]
    da = M.attn_delta(CFG, h, la["ln1"], la["wq"], la["wk"], la["wv"], la["wo"])
    db = M.attn_delta(CFG, h, lb["ln1"], lb["wq"], lb["wk"], lb["wv"], lb["wo"])
    wqkv2 = jnp.concatenate([la["wq"], la["wk"], la["wv"],
                             lb["wq"], lb["wk"], lb["wv"]], axis=1)
    wo2 = jnp.concatenate([la["wo"], lb["wo"]], axis=0)
    fused_fn = M.make_lp_fused_attn(CFG, impl="jnp")
    fused, = fused_fn(h, la["ln1"], lb["ln1"], wqkv2, wo2)
    np.testing.assert_allclose(fused, da + db, rtol=1e-4, atol=1e-4)


def test_prefill_decode_consistency(params):
    """Incremental decode through the shard executables must reproduce the
    sequential forward: prefill T0 tokens, then decode one more token; the
    logits must match forward_seq on T0+1 tokens."""
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 255, size=9).astype(np.int32))
    t0 = 8
    # reference: full forward on all 9 tokens
    ref_logits = M.forward_seq(CFG, params, toks)

    d, c, s = CFG.d_model, CFG.ctx, CFG.slots
    prefill_attn = M.make_shard_attn_prefill(CFG, impl="jnp")
    decode_attn = M.make_shard_attn_decode(CFG, impl="jnp")
    decode_ffn = M.make_shard_ffn_decode(CFG, impl="jnp")

    # ---- prefill first t0 tokens through full-width (LP-style) shards
    h = params["emb"][toks[:t0]]
    caches = []
    for lp in params["layers"]:
        part, k, v = prefill_attn(h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                                  lp["wo"])
        h = h + part
        h = h + M.ffn_delta(CFG, h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])
        kc = jnp.zeros((s, c, d)).at[0, :t0].set(k)
        vc = jnp.zeros((s, c, d)).at[0, :t0].set(v)
        caches.append((kc, vc))

    # ---- decode token at position t0 in slot 0
    x = params["emb"][toks[t0]][None, :].repeat(s, axis=0)
    pos = jnp.asarray([t0] * s, jnp.int32)
    for i, lp in enumerate(params["layers"]):
        kc, vc = caches[i]
        part, kc2, vc2 = decode_attn(x, lp["ln1"], lp["wq"], lp["wk"],
                                     lp["wv"], lp["wo"], kc, vc, pos)
        x = x + part
        fpart, = decode_ffn(x, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])
        x = x + fpart
        caches[i] = (kc2, vc2)
    from compile.kernels import ref as R
    logits_dec = R.rmsnorm(x, params["lnf"]) @ params["wout"]
    np.testing.assert_allclose(logits_dec[0], ref_logits[t0], rtol=2e-3,
                               atol=2e-3)


def test_loss_decreases_on_tiny_overfit(params):
    """Three AdamW steps on one batch must reduce the loss (training loop
    sanity, keeps train.py honest)."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from train import adamw_init, adamw_update

    rng = np.random.default_rng(0)
    batch = jnp.asarray(rng.integers(0, 255, size=(2, 17)).astype(np.int32))

    def loss(p):
        return M.loss_fn(CFG, p, batch)

    p = params
    opt = adamw_init(p)
    l0, g = jax.value_and_grad(loss)(p)
    for _ in range(3):
        p, opt = adamw_update(p, g, opt, 1e-3)
        l1, g = jax.value_and_grad(loss)(p)
    assert float(l1) < float(l0)
