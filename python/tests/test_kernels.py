"""Kernel-vs-ref: the core L1 correctness signal.

Hypothesis sweeps shapes; every Pallas kernel must match the pure-jnp oracle
in kernels/ref.py to float32 tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (rmsnorm, dual_rmsnorm, flash_attention,
                             cached_attention, swiglu_ffn)
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


@settings(**SETTINGS)
@given(t=st.sampled_from([1, 3, 32, 64]), d=st.sampled_from([16, 128, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, t, d), rand(rng, d)
    np.testing.assert_allclose(rmsnorm(x, w), ref.rmsnorm(x, w),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(t=st.sampled_from([1, 8, 64]), d=st.sampled_from([32, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_dual_rmsnorm_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x, wa, wb = rand(rng, t, d), rand(rng, d), rand(rng, d)
    a, b = dual_rmsnorm(x, wa, wb)
    ra, rb = ref.dual_rmsnorm(x, wa, wb)
    np.testing.assert_allclose(a, ra, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(b, rb, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(t=st.sampled_from([32, 64, 128, 256]), h=st.sampled_from([1, 4, 8]),
       hd=st.sampled_from([16, 32]), seed=st.integers(0, 2**31 - 1))
def test_flash_attention_matches_ref(t, h, hd, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, t, h, hd) for _ in range(3))
    np.testing.assert_allclose(flash_attention(q, k, v),
                               ref.causal_attention(q, k, v),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_causality():
    """Future K/V must not influence the output: perturb position j; outputs
    at positions < j must be bit-identical."""
    rng = np.random.default_rng(0)
    t, h, hd = 64, 2, 32
    q, k, v = (rand(rng, t, h, hd) for _ in range(3))
    base = np.asarray(flash_attention(q, k, v))
    k2 = np.asarray(k).copy()
    v2 = np.asarray(v).copy()
    k2[40:] += 100.0
    v2[40:] -= 50.0
    pert = np.asarray(flash_attention(q, jnp.asarray(k2), jnp.asarray(v2)))
    np.testing.assert_array_equal(base[:40], pert[:40])
    assert not np.allclose(base[40:], pert[40:])


@settings(**SETTINGS)
@given(c=st.sampled_from([32, 128, 256]), h=st.sampled_from([2, 4]),
       hd=st.sampled_from([16, 32]), seed=st.integers(0, 2**31 - 1),
       posfrac=st.floats(0.0, 1.0))
def test_cached_attention_matches_ref(c, h, hd, seed, posfrac):
    rng = np.random.default_rng(seed)
    q = rand(rng, h, hd)
    kc, vc = rand(rng, c, h, hd), rand(rng, c, h, hd)
    pos = min(c - 1, int(posfrac * c))
    np.testing.assert_allclose(cached_attention(q, kc, vc, pos),
                               ref.cached_attention(q, kc, vc, pos),
                               rtol=1e-4, atol=1e-4)


def test_cached_attention_ignores_future_cache():
    rng = np.random.default_rng(1)
    c, h, hd = 64, 2, 16
    q, kc, vc = rand(rng, h, hd), rand(rng, c, h, hd), rand(rng, c, h, hd)
    pos = 10
    out = np.asarray(cached_attention(q, kc, vc, pos))
    kc2, vc2 = np.asarray(kc).copy(), np.asarray(vc).copy()
    kc2[pos + 1:] = 1e3
    vc2[pos + 1:] = -1e3
    out2 = np.asarray(cached_attention(q, jnp.asarray(kc2), jnp.asarray(vc2), pos))
    np.testing.assert_array_equal(out, out2)


@settings(**SETTINGS)
@given(t=st.sampled_from([1, 32, 128]), d=st.sampled_from([64, 128]),
       f=st.sampled_from([128, 256]), seed=st.integers(0, 2**31 - 1))
def test_swiglu_matches_ref(t, d, f, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, t, d)
    wg, wu = rand(rng, d, f, scale=0.1), rand(rng, d, f, scale=0.1)
    wd = rand(rng, f, d, scale=0.1)
    np.testing.assert_allclose(swiglu_ffn(x, wg, wu, wd),
                               ref.swiglu_ffn(x, wg, wu, wd),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_q", [16, 32, 64])
def test_flash_attention_block_size_invariance(block_q):
    """The BlockSpec schedule must not change the numbers."""
    rng = np.random.default_rng(7)
    q, k, v = (rand(rng, 128, 4, 32) for _ in range(3))
    a = flash_attention(q, k, v, block_q=block_q)
    b = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
