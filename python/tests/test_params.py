"""Weight-store (.tdw) round-trip + checkpoint layout."""

import numpy as np
import jax

from compile import params as P
from compile import model as M
from compile import tok
from compile.modelcfg import ModelConfig

CFG = ModelConfig(name="t", vocab=tok.VOCAB_SIZE, d_model=32, n_layers=2,
                  n_heads=2, head_dim=16, d_ff=64, ctx=32, slots=2)


def test_tdw_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.normal(size=(3, 4)).astype(np.float32),
        "b.c": rng.integers(0, 100, size=(7,)).astype(np.int32),
        "scalarish": rng.normal(size=(1,)).astype(np.float32),
    }
    p = tmp_path / "w.tdw"
    P.save_tdw(p, tensors)
    back = P.load_tdw(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_flatten_unflatten_inverse():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    flat = P.flatten_params(params)
    assert "layers.0.wq" in flat and "emb" in flat
    back = P.unflatten_params(flat, CFG.n_layers)
    for i in range(CFG.n_layers):
        for k in params["layers"][i]:
            np.testing.assert_array_equal(np.asarray(params["layers"][i][k]),
                                          back["layers"][i][k])
    np.testing.assert_array_equal(np.asarray(params["wout"]), back["wout"])


def test_checkpoint_roundtrip(tmp_path):
    params = M.init_params(jax.random.PRNGKey(1), CFG)
    P.save_checkpoint(tmp_path / "ck", CFG, params, meta={"note": "test"})
    assert (tmp_path / "ck" / "weights.tdw").exists()
    assert (tmp_path / "ck" / "config.json").exists()
    back = P.load_checkpoint(tmp_path / "ck", CFG)
    np.testing.assert_allclose(np.asarray(params["layers"][1]["wd"]),
                               back["layers"][1]["wd"])
