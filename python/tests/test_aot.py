"""AOT pipeline: every artifact spec lowers to parseable HLO text with the
expected parameter count, and the manifest inventory is complete."""

import json
from pathlib import Path

import pytest

from compile import aot
from compile.modelcfg import SMALL, SEQ_BUCKETS


@pytest.fixture(scope="module")
def specs():
    return aot.artifact_specs(SMALL, impl="pallas")


def test_inventory_complete(specs):
    for t in SEQ_BUCKETS:
        for stem in ("embed", "attn", "ffn", "logits", "tpattn_prefill",
                     "tpffn_prefill", "lpattn_prefill"):
            assert f"{stem}_t{t}" in specs
        for w in ("half", "full"):
            assert f"cache_insert_{w}_t{t}" in specs
    for mode in ("tp", "lp"):
        assert f"{mode}attn_decode" in specs
        assert f"{mode}ffn_decode" in specs
    assert "embed_decode" in specs and "logits_decode" in specs
    assert "lpfused_attn_t128" in specs


@pytest.mark.parametrize("name", ["attn_t32", "tpattn_decode",
                                  "cache_insert_half_t32"])
def test_lowering_produces_hlo_text(specs, name):
    fn, arg_specs, arg_names = specs[name]
    text = aot.to_hlo_text(fn, arg_specs)
    assert text.startswith("HloModule")
    # the ENTRY computation has one parameter per argument; nested
    # computations (reduce/fusion bodies) have at most 2 — so the max
    # parameter index over the whole text equals len(args) - 1.
    import re
    max_idx = max(int(m) for m in re.findall(r"parameter\((\d+)\)", text))
    assert max_idx == len(arg_specs) - 1 == len(arg_names) - 1


def test_source_hash_is_stable():
    assert aot._source_hash("pallas") == aot._source_hash("pallas")
    assert aot._source_hash("pallas") != aot._source_hash("jnp")


def test_built_manifest_matches_inventory():
    """If `make artifacts` has run, the manifest on disk must cover the
    current inventory for every model (guards stale artifacts)."""
    mpath = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not mpath.exists():
        pytest.skip("artifacts not built yet")
    manifest = json.loads(mpath.read_text())
    inv = set(aot.artifact_specs(SMALL, impl=manifest["impl"]).keys())
    for model, entry in manifest["models"].items():
        have = set(entry["artifacts"].keys())
        assert inv == have, f"{model}: missing {inv - have}, extra {have - inv}"
        for a in entry["artifacts"].values():
            assert (mpath.parent / a["file"]).exists()
