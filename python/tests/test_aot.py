"""AOT pipeline: every artifact spec lowers to parseable HLO text with the
expected parameter count, and the manifest inventory is complete."""

import json
from pathlib import Path

import pytest

from compile import aot
from compile.modelcfg import (
    BASE,
    PREFILL_CHUNK,
    SMALL,
    SEQ_BUCKETS,
    batch_buckets,
    plan_variants,
)


@pytest.fixture(scope="module")
def specs():
    return aot.artifact_specs(SMALL, impl="pallas")


def test_inventory_complete(specs):
    for t in SEQ_BUCKETS:
        for stem in ("embed", "attn", "ffn", "logits", "tpattn_prefill",
                     "tpffn_prefill", "lpattn_prefill"):
            assert f"{stem}_t{t}" in specs
        for w in ("half", "full"):
            assert f"cache_insert_{w}_t{t}" in specs
    for mode in ("tp", "lp"):
        assert f"{mode}attn_decode" in specs
        assert f"{mode}ffn_decode" in specs
    assert "embed_decode" in specs and "logits_decode" in specs
    assert "lpfused_attn_t128" in specs
    for b in batch_buckets(SMALL.slots):
        for mode in ("tp", "lp"):
            assert f"{mode}attn_decode_b{b}" in specs
            assert f"{mode}ffn_decode_b{b}" in specs
        assert f"embed_decode_b{b}" in specs
        assert f"logits_decode_b{b}" in specs
    for mode in ("tp", "lp"):
        assert f"{mode}attn_chunk" in specs
        assert f"{mode}ffn_chunk" in specs
        assert f"{mode}attn_chunk_paged" in specs
        for b in batch_buckets(SMALL.slots):
            assert f"{mode}attn_decode_paged_b{b}" in specs
    assert "embed_chunk" in specs and "logits_chunk" in specs


def test_batch_bucket_ladder():
    assert batch_buckets(1) == (1,)
    assert batch_buckets(4) == (1, 2, 4)
    assert batch_buckets(6) == (1, 2, 4, 6)   # non-power-of-two slots capped
    assert batch_buckets(8) == (1, 2, 4, 8)


def test_bucket_attn_signature(specs):
    """The bucketed attention carries the full-[S] caches plus a lanes
    vector — the contract runtime::buckets binds against."""
    b = batch_buckets(SMALL.slots)[0]
    _, arg_specs, arg_names = specs[f"tpattn_decode_b{b}"]
    assert arg_names == ["x", "ln1", "wq", "wk", "wv", "wo", "kcache",
                         "vcache", "pos", "lanes"]
    assert arg_specs[0].shape == (b, SMALL.d_model)
    assert arg_specs[6].shape == (SMALL.slots, SMALL.ctx, SMALL.d_model // 2)
    assert arg_specs[9].shape == (b,)


def test_chunk_attn_signature(specs):
    """The chunk prefill attention carries the full-[S] caches plus the
    slot/off/valid scalars — the contract rust model::prefill binds
    against (and inserts its own K/V rows: no separate cache_insert)."""
    _, arg_specs, arg_names = specs["tpattn_chunk"]
    assert arg_names == ["h", "ln1", "wq", "wk", "wv", "wo", "kcache",
                         "vcache", "slot", "off", "valid"]
    assert arg_specs[0].shape == (PREFILL_CHUNK, SMALL.d_model)
    assert arg_specs[6].shape == (SMALL.slots, SMALL.ctx, SMALL.d_model // 2)
    for i in (8, 9, 10):
        assert arg_specs[i].shape == ()
        assert arg_specs[i].dtype == aot.I32
    _, lp_specs, _ = specs["lpattn_chunk"]
    assert lp_specs[6].shape == (SMALL.slots, SMALL.ctx, SMALL.d_model)
    assert SMALL.ctx % PREFILL_CHUNK == 0


def test_paged_attn_signatures(specs):
    """The paged variants swap the dense slot/lanes indexing for i32 page
    tables against the shared per-width pools — the contract rust
    model::kvcache's allocator and the kv_pages manifest section bind
    against."""
    from compile.modelcfg import kv_pages
    kvp = kv_pages(SMALL)
    page, nb = kvp["page_tokens"], kvp["blocks_per_slot"]
    assert page == PREFILL_CHUNK and nb * page == SMALL.ctx
    _, arg_specs, arg_names = specs["tpattn_chunk_paged"]
    assert arg_names == ["h", "ln1", "wq", "wk", "wv", "wo", "kpool",
                         "vpool", "pt", "off", "valid"]
    assert arg_specs[6].shape == (kvp["pool_pages_half"], page,
                                  SMALL.d_model // 2)
    assert arg_specs[8].shape == (nb,) and arg_specs[8].dtype == aot.I32
    _, lp_specs, _ = specs["lpattn_chunk_paged"]
    assert lp_specs[6].shape == (kvp["pool_pages_full"], page, SMALL.d_model)
    b = batch_buckets(SMALL.slots)[-1]
    _, d_specs, d_names = specs[f"tpattn_decode_paged_b{b}"]
    assert d_names == ["x", "ln1", "wq", "wk", "wv", "wo", "kpool", "vpool",
                       "pos", "pt"]
    assert d_specs[9].shape == (b, nb) and d_specs[9].dtype == aot.I32
    # pools size a dense-equivalent worst case plus the scratch page
    half = kvp["pool_pages_half"]
    full = kvp["pool_pages_full"]
    assert (half - 1) % (SMALL.slots * nb) == 0
    assert (full - 1) % (SMALL.slots * nb) == 0


@pytest.mark.parametrize("name", ["attn_t32", "tpattn_decode",
                                  "cache_insert_half_t32", "tpattn_chunk",
                                  "tpattn_chunk_paged",
                                  "lpattn_decode_paged_b1"])
def test_lowering_produces_hlo_text(specs, name):
    fn, arg_specs, arg_names = specs[name]
    text = aot.to_hlo_text(fn, arg_specs)
    assert text.startswith("HloModule")
    # the ENTRY computation has one parameter per argument; nested
    # computations (reduce/fusion bodies) have at most 2 — so the max
    # parameter index over the whole text equals len(args) - 1.
    import re
    max_idx = max(int(m) for m in re.findall(r"parameter\((\d+)\)", text))
    assert max_idx == len(arg_specs) - 1 == len(arg_names) - 1


def test_plan_variants_are_valid_tiers():
    """Every variant uses each layer at most once, stays in range, has
    stage arity 1 or 2, and the tiers strictly descend in effective depth
    (dense > lp > lp_aggr) — the ordering the serving cost model turns
    into tokens/sec."""
    for cfg in (SMALL, BASE):
        variants = plan_variants(cfg)
        assert list(variants) == ["dense", "lp", "lp_aggr"]
        depths = []
        for name, stages in variants.items():
            used = [i for st in stages for i in st]
            assert sorted(used) == sorted(set(used)), f"{name}: layer reuse"
            assert all(0 <= i < cfg.n_layers for i in used), f"{name}: range"
            assert all(len(st) in (1, 2) for st in stages), f"{name}: arity"
            depths.append(len(stages))
        assert depths[0] == cfg.n_layers, "dense must be the full stack"
        assert depths[0] > depths[1] > depths[2], f"{cfg.name}: {depths}"
        # lp keeps the head/tail sequential (the paper's band placement);
        # lp_aggr pairs from layer 0
        assert variants["lp"][0] == [0]
        assert len(variants["lp_aggr"][0]) == 2


def test_variant_stages_only_reference_existing_executables(specs):
    """Variants add no artifacts: every stage kind they can produce maps to
    an executable family the inventory already carries."""
    for stages in plan_variants(SMALL).values():
        for st in stages:
            mode = "tp" if len(st) == 1 else "lp"
            assert f"{mode}attn_decode" in specs
            assert f"{mode}attn_chunk" in specs


def test_source_hash_is_stable():
    assert aot._source_hash("pallas") == aot._source_hash("pallas")
    assert aot._source_hash("pallas") != aot._source_hash("jnp")


def test_built_manifest_matches_inventory():
    """If `make artifacts` has run, the manifest on disk must cover the
    current inventory for every model (guards stale artifacts)."""
    mpath = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not mpath.exists():
        pytest.skip("artifacts not built yet")
    manifest = json.loads(mpath.read_text())
    inv = set(aot.artifact_specs(SMALL, impl=manifest["impl"]).keys())
    for model, entry in manifest["models"].items():
        have = set(entry["artifacts"].keys())
        assert inv == have, f"{model}: missing {inv - have}, extra {have - inv}"
        for a in entry["artifacts"].values():
            assert (mpath.parent / a["file"]).exists()
        assert entry["batch_buckets"] == list(
            batch_buckets(entry["config"]["slots"])
        ), f"{model}: manifest batch_buckets out of date"
        from compile.modelcfg import CONFIGS
        assert entry.get("variants") == {
            vname: {"stages": stages}
            for vname, stages in plan_variants(CONFIGS[model]).items()
        }, f"{model}: manifest variants out of date"
    assert manifest.get("prefill_chunk") == PREFILL_CHUNK, \
        "manifest prefill_chunk out of date (re-run `make artifacts`)"
