"""Batch-bucketed decode: the bit-exactness contract the rust runtime's
shape-bucket dispatch relies on.

`ServingModel::decode_active` routes a round with L live lanes to the
smallest covering bucket B and maps lane i -> slot lanes[i]. Because both
the full-[S] and bucketed attention makers unroll the *same* per-lane step
(`model._decode_step_one`) and XLA CPU keeps row-wise reductions
batch-size-independent, the bucketed outputs must equal the corresponding
full-batch rows bit for bit — asserted here at the JAX level so a kernel or
lowering change that breaks the contract fails before artifacts ship.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import tok
from compile.modelcfg import ModelConfig, batch_buckets

CFG = ModelConfig(name="t", vocab=tok.VOCAB_SIZE, d_model=64, n_layers=4,
                  n_heads=4, head_dim=16, d_ff=128, ctx=64, slots=4)


@pytest.fixture(scope="module", params=["jnp", "pallas"])
def impl(request):
    return request.param


@pytest.fixture(scope="module")
def inputs():
    rng = np.random.default_rng(7)
    d, c, s = CFG.d_model, CFG.ctx, CFG.slots
    w = d  # full (lp) width; the tp half-width path shares the same maker

    def t(*shape, scale=0.1):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)

    return {
        "x": t(s, d, scale=1.0),
        "ln": t(d, scale=1.0),
        "wq": t(d, w), "wk": t(d, w), "wv": t(d, w), "wo": t(w, d),
        "kc": t(s, c, w), "vc": t(s, c, w),
        "pos": jnp.asarray(np.array([5, 9, 0, 3], np.int32)),
    }


def test_bucketed_attn_rows_bit_identical(impl, inputs):
    i = inputs
    full = jax.jit(M.make_shard_attn_decode(CFG, impl))
    parts_full, kc_full, vc_full = full(i["x"], i["ln"], i["wq"], i["wk"],
                                        i["wv"], i["wo"], i["kc"], i["vc"],
                                        i["pos"])

    lanes = np.array([1, 3], np.int32)  # non-contiguous live slots
    b = len(lanes)
    assert b in batch_buckets(CFG.slots)
    bucket = jax.jit(M.make_shard_attn_decode_bucket(CFG, impl, b))
    parts_b, kc_b, vc_b = bucket(
        i["x"][jnp.asarray(lanes)], i["ln"], i["wq"], i["wk"], i["wv"],
        i["wo"], i["kc"], i["vc"], i["pos"][jnp.asarray(lanes)],
        jnp.asarray(lanes))

    assert np.array_equal(np.asarray(parts_b), np.asarray(parts_full)[lanes])
    # gathered rows updated exactly as the full path updates them
    assert np.array_equal(np.asarray(kc_b)[lanes], np.asarray(kc_full)[lanes])
    assert np.array_equal(np.asarray(vc_b)[lanes], np.asarray(vc_full)[lanes])
    # untouched slots' cache rows pass through unmodified
    idle = [s for s in range(CFG.slots) if s not in lanes]
    assert np.array_equal(np.asarray(kc_b)[idle], np.asarray(i["kc"])[idle])
    assert np.array_equal(np.asarray(vc_b)[idle], np.asarray(i["vc"])[idle])


def test_padded_lane_duplicating_live_lane_is_idempotent(impl, inputs):
    """The rust coordinator pads a short round by repeating its first live
    lane. A duplicate recomputes the same per-lane step from identical
    inputs, so it must rewrite the same cache row with identical bits and
    leave every other slot untouched."""
    i = inputs
    b = 4
    lanes = np.array([1, 3, 1, 1], np.int32)  # two pad lanes duplicate slot 1
    bucket = jax.jit(M.make_shard_attn_decode_bucket(CFG, impl, b))
    x = i["x"][jnp.asarray(lanes)]
    pos = i["pos"][jnp.asarray(lanes)]
    parts, kc, vc = bucket(x, i["ln"], i["wq"], i["wk"], i["wv"], i["wo"],
                           i["kc"], i["vc"], pos, jnp.asarray(lanes))
    full = jax.jit(M.make_shard_attn_decode(CFG, impl))
    parts_full, kc_full, vc_full = full(i["x"], i["ln"], i["wq"], i["wk"],
                                        i["wv"], i["wo"], i["kc"], i["vc"],
                                        i["pos"])
    # live lanes bit-match the full path; the duplicates equal lane 0
    assert np.array_equal(np.asarray(parts)[0], np.asarray(parts_full)[1])
    assert np.array_equal(np.asarray(parts)[1], np.asarray(parts_full)[3])
    assert np.array_equal(np.asarray(parts)[2], np.asarray(parts)[0])
    assert np.array_equal(np.asarray(parts)[3], np.asarray(parts)[0])
    assert np.array_equal(np.asarray(kc)[[1, 3]], np.asarray(kc_full)[[1, 3]])
    assert np.array_equal(np.asarray(vc)[[1, 3]], np.asarray(vc_full)[[1, 3]])
    # slots not addressed by any lane pass through unmodified
    assert np.array_equal(np.asarray(kc)[[0, 2]], np.asarray(i["kc"])[[0, 2]])
    assert np.array_equal(np.asarray(vc)[[0, 2]], np.asarray(i["vc"])[[0, 2]])

    # a lane addressing a free slot is equally benign: it writes only that row
    lanes_free = np.array([1, 3, 0, 0], np.int32)
    pos_free = jnp.asarray(np.array([9, 3, 0, 0], np.int32))  # pos[slot] 1, 3
    parts2, kc2, _ = bucket(i["x"][jnp.asarray(lanes_free)], i["ln"], i["wq"],
                            i["wk"], i["wv"], i["wo"], i["kc"], i["vc"],
                            pos_free, jnp.asarray(lanes_free))
    assert np.isfinite(np.asarray(parts2)).all()
    assert np.array_equal(np.asarray(kc2)[[1, 3]], np.asarray(kc_full)[[1, 3]])
    assert np.array_equal(np.asarray(kc2)[2], np.asarray(i["kc"])[2])


def test_rowwise_entrypoints_bit_identical_across_widths(impl, inputs):
    """ffn / logits / embed lowered at bucket width B must reproduce the
    corresponding rows of the full-[S] lowering exactly."""
    i = inputs
    rng = np.random.default_rng(11)
    d, f, v = CFG.d_model, CFG.d_ff, CFG.vocab

    def t(*shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 0.1)

    wg, wu, wd = t(d, f), t(d, f), t(f, d)
    ffn = M.make_shard_ffn_decode(CFG, impl)
    f_full = jax.jit(ffn)(i["x"], i["ln"], wg, wu, wd)[0]
    f_b = jax.jit(ffn)(i["x"][1:3], i["ln"], wg, wu, wd)[0]
    assert np.array_equal(np.asarray(f_full)[1:3], np.asarray(f_b))

    wout = t(d, v)
    logits = M.make_logits_decode(CFG, impl)
    l_full = jax.jit(logits)(i["x"], i["ln"], wout)[0]
    l_b = jax.jit(logits)(i["x"][2:3], i["ln"], wout)[0]
    assert np.array_equal(np.asarray(l_full)[2:3], np.asarray(l_b))

    emb = t(v, d)
    tokens = jnp.asarray(np.array([4, 250, 7, 19], np.int32))
    embed = M.make_embed_decode(CFG)
    e_full = jax.jit(embed)(tokens, emb)[0]
    e_b = jax.jit(embed)(tokens[1:2], emb)[0]
    assert np.array_equal(np.asarray(e_full)[1:2], np.asarray(e_b))
