"""Paged KV cache: the bit-exactness contract between the paged attention
executables and the dense-cache oracles.

The paged makers (`model.make_shard_attn_chunk_paged`,
`make_shard_attn_decode_paged_bucket`) materialize a sequence's `[C, w]`
cache stripe by gathering its page table out of the shared pool, run the
*same* insert/attend math as the dense chunk / bucketed-decode kernels, and
scatter the stripe back page by page. Asserted here at the JAX level:

* a prompt prefilled through the paged chunk path reproduces the dense
  chunk path bit for bit (partials, logits, and K/V page contents);
* a paged bucketed decode step reproduces the dense bucketed step bit for
  bit, whatever the page-id permutation;
* unmapped page-table entries (the reserved scratch page 0) and garbage in
  allocated-but-unwritten rows are masked to exact zeros by the causal
  softmax — outputs are invariant to pool garbage;
* pages shared by several lanes (copy-on-write prefix forks) are rewritten
  bit-identically by the scatter, so sharing never corrupts a neighbour.
"""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import tok
from compile.modelcfg import ModelConfig

CFG = ModelConfig(name="t", vocab=tok.VOCAB_SIZE, d_model=64, n_layers=3,
                  n_heads=4, head_dim=16, d_ff=128, ctx=64, slots=2)
K = 16          # chunk == page size under test (ctx % K == 0)
NB = CFG.ctx // K
P = 1 + CFG.slots * NB      # scratch page 0 + a dense-equivalent pool
L = 39          # 3 chunks, final one partial (valid = 7)


@pytest.fixture(scope="module", params=["jnp", "pallas"])
def impl(request):
    return request.param


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(3), CFG)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(5)
    return jnp.asarray(rng.integers(0, 256, size=(L,)).astype(np.int32))


def garbage_pool(seed, scale=3.0):
    """A pool whose every page (scratch included) holds finite garbage —
    outputs must be invariant to all of it."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((P, K, CFG.d_model)).astype(np.float32) * scale)


def dense_chunked_prefill(p, tokens, impl, slot=0):
    """The dense chunk oracle (slot-indexed [S, C, w] caches)."""
    attn = M.make_shard_attn_chunk(CFG, impl, K)
    ffn = M.make_shard_ffn(CFG, impl)
    kcs = [jnp.zeros((CFG.slots, CFG.ctx, CFG.d_model), jnp.float32)
           for _ in p["layers"]]
    vcs = [jnp.zeros_like(kcs[0]) for _ in p["layers"]]
    parts = []
    for j in range(math.ceil(len(tokens) / K)):
        off = j * K
        valid = min(len(tokens) - off, K)
        chunk = jnp.concatenate(
            [tokens[off:off + valid],
             jnp.full((K - valid,), tok.PAD, jnp.int32)])
        h = M.make_embed(CFG)(chunk, p["emb"])[0]
        for i, lp in enumerate(p["layers"]):
            part, kcs[i], vcs[i] = attn(
                h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                kcs[i], vcs[i], jnp.int32(slot), jnp.int32(off),
                jnp.int32(valid))
            parts.append(np.asarray(part)[:valid])
            h = h + part
            h = h + ffn(h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])[0]
    logits = M.make_logits(CFG, impl)(h, p["lnf"], p["wout"])[0]
    return logits, kcs, vcs, parts


def paged_chunked_prefill(p, tokens, impl):
    """The paged path: per-layer pools seeded with garbage, blocks mapped
    lazily in cursor order (block j appears right before chunk j, exactly
    the runtime's ensure-before-dispatch protocol; later blocks stay on the
    scratch page 0)."""
    attn = M.make_shard_attn_chunk_paged(CFG, impl, K)
    ffn = M.make_shard_ffn(CFG, impl)
    kps = [garbage_pool(100 + i) for i in range(CFG.n_layers)]
    vps = [garbage_pool(200 + i) for i in range(CFG.n_layers)]
    parts = []
    for j in range(math.ceil(len(tokens) / K)):
        off = j * K
        valid = min(len(tokens) - off, K)
        chunk = jnp.concatenate(
            [tokens[off:off + valid],
             jnp.full((K - valid,), tok.PAD, jnp.int32)])
        pt = np.zeros(NB, np.int32)
        pt[:j + 1] = np.arange(1, j + 2)        # blocks 0..j mapped
        pt = jnp.asarray(pt)
        h = M.make_embed(CFG)(chunk, p["emb"])[0]
        for i, lp in enumerate(p["layers"]):
            part, kps[i], vps[i] = attn(
                h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                kps[i], vps[i], pt, jnp.int32(off), jnp.int32(valid))
            parts.append(np.asarray(part)[:valid])
            h = h + part
            h = h + ffn(h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])[0]
    logits = M.make_logits(CFG, impl)(h, p["lnf"], p["wout"])[0]
    return logits, kps, vps, parts


def test_paged_chunk_prefill_bit_identical_to_dense(impl, params, tokens):
    d_logits, d_k, d_v, d_parts = dense_chunked_prefill(params, tokens, impl)
    p_logits, p_k, p_v, p_parts = paged_chunked_prefill(params, tokens, impl)

    # valid rows only: PAD rows of the final partial chunk attend unwritten
    # columns (dense zeros vs pool garbage) and are discarded by callers
    for n, (a, b) in enumerate(zip(d_parts, p_parts)):
        assert np.array_equal(a, b), f"attention partial {n} diverged"
    valid = L - (L // K) * K
    assert np.array_equal(np.asarray(d_logits)[:valid],
                          np.asarray(p_logits)[:valid])

    # the K/V rows reachable through the page table match the dense cache
    pt = jnp.asarray(np.arange(1, NB + 1, dtype=np.int32))
    for i in range(CFG.n_layers):
        for dense, pool in ((d_k[i], p_k[i]), (d_v[i], p_v[i])):
            view = np.asarray(pool[pt].reshape(CFG.ctx, CFG.d_model))
            assert np.array_equal(view[:L], np.asarray(dense)[0, :L]), \
                f"layer {i} paged K/V diverged"


def pool_from_dense(kc, seed):
    """Pack a dense [S, C, w] cache into a pool: slot s block j -> page
    1 + s·NB + j; scratch keeps garbage."""
    pool = np.asarray(garbage_pool(seed)).copy()
    kc = np.asarray(kc)
    for s in range(CFG.slots):
        for j in range(NB):
            pool[1 + s * NB + j] = kc[s, j * K:(j + 1) * K]
    return jnp.asarray(pool)


def full_pt():
    return jnp.asarray(
        np.stack([1 + s * NB + np.arange(NB, dtype=np.int32)
                  for s in range(CFG.slots)]))


def test_paged_decode_bit_identical_to_dense_bucket(impl, params, tokens):
    """B = 2 bucketed decode: dense lanes[] gather vs page-table gather must
    produce the same partials and write the same K/V bits."""
    _, d_k, d_v, _ = dense_chunked_prefill(params, tokens, impl, slot=0)
    # slot 1 carries a second, different sequence
    _, d_k1, d_v1, _ = dense_chunked_prefill(params, tokens[:20], impl,
                                             slot=1)
    kc = jnp.asarray(np.where(
        np.arange(CFG.slots)[:, None, None] == 0,
        np.asarray(d_k[0]), np.asarray(d_k1[0])))
    vc = jnp.asarray(np.where(
        np.arange(CFG.slots)[:, None, None] == 0,
        np.asarray(d_v[0]), np.asarray(d_v1[0])))

    dense = M.make_shard_attn_decode_bucket(CFG, impl, b=2)
    paged = M.make_shard_attn_decode_paged_bucket(CFG, impl, b=2, page=K)
    lp = params["layers"][0]
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((2, CFG.d_model)).astype(np.float32))
    pos = jnp.asarray(np.array([L, 20], np.int32))
    lanes = jnp.asarray(np.array([0, 1], np.int32))

    d_part, d_kc2, d_vc2 = dense(x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                                 lp["wo"], kc, vc, pos, lanes)
    kp, vp = pool_from_dense(kc, 31), pool_from_dense(vc, 37)
    p_part, kp2, vp2 = paged(x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                             lp["wo"], kp, vp, pos, full_pt())

    assert np.array_equal(np.asarray(d_part), np.asarray(p_part))
    pt = full_pt()
    for s in range(CFG.slots):
        view_k = np.asarray(kp2[pt[s]].reshape(CFG.ctx, CFG.d_model))
        view_v = np.asarray(vp2[pt[s]].reshape(CFG.ctx, CFG.d_model))
        assert np.array_equal(view_k, np.asarray(d_kc2)[s]), f"slot {s} K"
        assert np.array_equal(view_v, np.asarray(d_vc2)[s]), f"slot {s} V"


def test_shared_prefix_pages_rewritten_bit_identically(params, tokens):
    """Two lanes whose tables share block-0 pages (a copy-on-write prefix
    fork): the shared pages' bits must survive the decode scatter, and each
    lane's output must equal its dense single-slot computation."""
    impl = "jnp"
    _, d_k, d_v, _ = dense_chunked_prefill(params, tokens, impl, slot=0)
    kc, vc = d_k[0], d_v[0]
    # both slots carry the SAME sequence (the fork): dense duplicates it
    kc = jnp.asarray(np.stack([np.asarray(kc)[0]] * 2))
    vc = jnp.asarray(np.stack([np.asarray(vc)[0]] * 2))

    # paged: block 0 shared (page 1), later blocks private per lane
    pt = np.zeros((2, NB), np.int32)
    pt[0] = [1, 2, 3, 0]
    pt[1] = [1, 4, 5, 0]
    kp = np.asarray(garbage_pool(41)).copy()
    vp = np.asarray(garbage_pool(43)).copy()
    for lane in range(2):
        for j in range(3):
            kp[pt[lane, j]] = np.asarray(kc)[lane, j * K:(j + 1) * K]
            vp[pt[lane, j]] = np.asarray(vc)[lane, j * K:(j + 1) * K]
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    shared_k = np.asarray(kp[1]).copy()
    shared_v = np.asarray(vp[1]).copy()

    dense = M.make_shard_attn_decode_bucket(CFG, impl, b=2)
    paged = M.make_shard_attn_decode_paged_bucket(CFG, impl, b=2, page=K)
    lp = params["layers"][0]
    rng = np.random.default_rng(47)
    x = jnp.asarray(rng.standard_normal((2, CFG.d_model)).astype(np.float32))
    pos = jnp.asarray(np.array([L, L], np.int32))        # both write block 2
    lanes = jnp.asarray(np.array([0, 1], np.int32))

    d_part, _, _ = dense(x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                         lp["wo"], kc, vc, pos, lanes)
    p_part, kp2, vp2 = paged(x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                             lp["wo"], kp, vp, jnp.asarray(pos),
                             jnp.asarray(pt))

    assert np.array_equal(np.asarray(d_part), np.asarray(p_part))
    assert np.array_equal(np.asarray(kp2[1]), shared_k), \
        "shared K page bits changed"
    assert np.array_equal(np.asarray(vp2[1]), shared_v), \
        "shared V page bits changed"
    # each lane's write landed in its own private block-2 page
    assert not np.array_equal(np.asarray(kp2[3]), np.asarray(kp[3]))
    assert not np.array_equal(np.asarray(kp2[5]), np.asarray(kp[5]))


def test_outputs_invariant_to_pool_garbage(params, tokens):
    """Scratch-page and unwritten-row garbage must be exactly masked: the
    same decode over two pools differing only in garbage is bit-equal."""
    impl = "jnp"
    _, d_k, d_v, _ = dense_chunked_prefill(params, tokens, impl, slot=0)
    paged = M.make_shard_attn_decode_paged_bucket(CFG, impl, b=1, page=K)
    lp = params["layers"][0]
    rng = np.random.default_rng(53)
    x = jnp.asarray(rng.standard_normal((1, CFG.d_model)).astype(np.float32))
    pos = jnp.asarray(np.array([L], np.int32))
    pt = jnp.asarray(np.array([[1, 2, 3, 0]], np.int32))  # block 3 unmapped

    outs = []
    for seed in (61, 67):
        kp = np.asarray(garbage_pool(seed)).copy()
        vp = np.asarray(garbage_pool(seed + 1)).copy()
        for j in range(3):
            kp[1 + j] = np.asarray(d_k[0])[0, j * K:(j + 1) * K]
            vp[1 + j] = np.asarray(d_v[0])[0, j * K:(j + 1) * K]
        part, _, _ = paged(x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                           lp["wo"], jnp.asarray(kp), jnp.asarray(vp),
                           pos, pt)
        outs.append(np.asarray(part))
    assert np.array_equal(outs[0], outs[1]), "pool garbage leaked into output"
