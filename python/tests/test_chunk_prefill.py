"""Chunked streaming prefill: the bit-exactness contract the rust runtime's
resumable-prefill protocol relies on.

A prompt of L tokens prefilled in ceil(L / K) fixed-[K] chunk steps
(`model.make_shard_attn_chunk` + the chunk ffn/embed/logits lowerings) must
reproduce the monolithic fixed-T prefill (`make_shard_attn_prefill` et al.)
bit for bit: the projections/RoPE/softmax are the same row-wise math (XLA
CPU keeps row-wise ops batch-size-invariant) and every masked cache column
is an exact zero after the softmax, so widening the reduction from T to C
columns cannot change any row. Asserted here at the JAX level so a kernel
or lowering change that breaks the contract fails before artifacts ship.

Also pinned: the final partial chunk masks its K/V insert by the true
length (no PAD-token K/V in the cache), and decode never attends to cache
positions >= L — the monolithic path's padded K/V tail is dead state.
"""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import tok
from compile.kernels import chunk_attention, ref
from compile.modelcfg import ModelConfig

CFG = ModelConfig(name="t", vocab=tok.VOCAB_SIZE, d_model=64, n_layers=3,
                  n_heads=4, head_dim=16, d_ff=128, ctx=64, slots=2)
K = 16          # chunk size under test (ctx % K == 0, mirroring aot.py)
T = 64          # monolithic prefill bucket
L = 39          # true prompt length: 3 chunks, final one partial (valid=7)


@pytest.fixture(scope="module", params=["jnp", "pallas"])
def impl(request):
    return request.param


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(3), CFG)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(5)
    return jnp.asarray(rng.integers(0, 256, size=(L,)).astype(np.int32))


def monolithic_prefill(p, tokens, impl):
    """The serving executor's fixed-T path, single-rank full width: embed ->
    per layer (attn partial + residual, cache insert, ffn partial +
    residual) -> logits. Returns (logits [T,V], kcaches, vcaches)."""
    padded = jnp.concatenate(
        [tokens, jnp.full((T - len(tokens),), tok.PAD, jnp.int32)])
    h = M.make_embed(CFG)(padded, p["emb"])[0]
    attn = M.make_shard_attn_prefill(CFG, impl)
    ffn = M.make_shard_ffn(CFG, impl)
    insert = M.make_cache_insert(CFG)
    kcs, vcs = [], []
    for lp in p["layers"]:
        part, k, v = attn(h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"])
        h = h + part
        kc = jnp.zeros((CFG.slots, CFG.ctx, CFG.d_model), jnp.float32)
        vc = jnp.zeros_like(kc)
        kcs.append(insert(kc, k, jnp.int32(0))[0])
        vcs.append(insert(vc, v, jnp.int32(0))[0])
        h = h + ffn(h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])[0]
    logits = M.make_logits(CFG, impl)(h, p["lnf"], p["wout"])[0]
    return logits, kcs, vcs


def chunked_prefill(p, tokens, impl, slot=0):
    """The resumable chunk protocol: ceil(L/K) chunk steps against live
    caches. Returns (last-chunk logits [K,V], kcaches, vcaches, valid)."""
    attn = M.make_shard_attn_chunk(CFG, impl, K)
    ffn = M.make_shard_ffn(CFG, impl)
    kcs = [jnp.zeros((CFG.slots, CFG.ctx, CFG.d_model), jnp.float32)
           for _ in p["layers"]]
    vcs = [jnp.zeros_like(kcs[0]) for _ in p["layers"]]
    n = math.ceil(len(tokens) / K)
    logits = valid = None
    for j in range(n):
        off = j * K
        valid = min(len(tokens) - off, K)
        chunk = jnp.concatenate(
            [tokens[off:off + valid],
             jnp.full((K - valid,), tok.PAD, jnp.int32)])
        h = M.make_embed(CFG)(chunk, p["emb"])[0]
        for i, lp in enumerate(p["layers"]):
            part, kcs[i], vcs[i] = attn(
                h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                kcs[i], vcs[i], jnp.int32(slot), jnp.int32(off),
                jnp.int32(valid))
            h = h + part
            h = h + ffn(h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"])[0]
        if j == n - 1:
            logits = M.make_logits(CFG, impl)(h, p["lnf"], p["wout"])[0]
    return logits, kcs, vcs, valid


def test_chunked_prefill_bit_identical_to_monolithic(impl, params, tokens):
    mono_logits, mono_k, mono_v = monolithic_prefill(params, tokens, impl)
    chunk_logits, chunk_k, chunk_v, valid = chunked_prefill(
        params, tokens, impl)

    # the serving executor reads the last real token's logits row
    expect = np.asarray(mono_logits)[L - 1]
    got = np.asarray(chunk_logits)[valid - 1]
    assert np.array_equal(expect, got), \
        f"last-token logits diverged (impl={impl})"

    # every real position's K/V in every layer matches the monolithic insert
    for i in range(CFG.n_layers):
        assert np.array_equal(np.asarray(chunk_k[i])[0, :L],
                              np.asarray(mono_k[i])[0, :L]), f"layer {i} K"
        assert np.array_equal(np.asarray(chunk_v[i])[0, :L],
                              np.asarray(mono_v[i])[0, :L]), f"layer {i} V"


def test_final_partial_chunk_masks_pad_kv(impl, params, tokens):
    """Rows >= L keep the cache's prior contents: the PAD tail of the final
    partial chunk must not write K/V (poisoned sentinels survive)."""
    attn = M.make_shard_attn_chunk(CFG, impl, K)
    sentinel = jnp.full((CFG.slots, CFG.ctx, CFG.d_model), 7.5, jnp.float32)
    lp = params["layers"][0]
    off = (L // K) * K           # final chunk
    valid = L - off
    chunk = jnp.concatenate(
        [tokens[off:], jnp.full((K - valid,), tok.PAD, jnp.int32)])
    h = M.make_embed(CFG)(chunk, params["emb"])[0]
    part, kc, vc = attn(h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                        sentinel, sentinel, jnp.int32(1), jnp.int32(off),
                        jnp.int32(valid))
    for c in (kc, vc):
        c = np.asarray(c)
        # written rows: [off, off+valid) of slot 1 only
        assert not np.any(c[1, off:off + valid] == 7.5)
        assert np.all(c[1, off + valid:] == 7.5), "PAD rows were written"
        assert np.all(c[0] == 7.5), "other slot touched"
    assert np.isfinite(np.asarray(part)).all()


def test_decode_never_attends_past_prompt_length(impl, params, tokens):
    """The monolithic path writes PAD-token K/V at rows [L, T); decode at
    pos >= L must mask them (its own insert overwrites row pos before
    attending), so corrupting every row >= L changes nothing."""
    _, mono_k, mono_v = monolithic_prefill(params, tokens, impl)
    step = M._decode_step_one(CFG, impl)
    lp = params["layers"][0]
    x = jnp.asarray(np.random.default_rng(9).standard_normal(
        (CFG.d_model,)).astype(np.float32))

    kc = np.asarray(mono_k[0])[0]
    vc = np.asarray(mono_v[0])[0]
    assert np.any(kc[L:T] != 0.0), "PAD K/V expected in the padded tail"
    kc_bad, vc_bad = kc.copy(), vc.copy()
    kc_bad[L:] = 1e9
    vc_bad[L:] = -1e9

    # run a short decode sequence over both caches: each step overwrites
    # row `pos` before attending (cols <= pos), so the corrupted tail must
    # never leak into any step's output
    kc_a, vc_a = jnp.asarray(kc), jnp.asarray(vc)
    kc_b, vc_b = jnp.asarray(kc_bad), jnp.asarray(vc_bad)
    for pos in range(L, L + 4):
        part_a, kc_a, vc_a = step(
            x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
            kc_a, vc_a, jnp.int32(pos))
        part_b, kc_b, vc_b = step(
            x, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
            kc_b, vc_b, jnp.int32(pos))
        assert np.array_equal(np.asarray(part_a), np.asarray(part_b)), \
            f"decode at pos {pos} attended to positions >= L"


def test_chunk_attention_kernel_matches_ref():
    rng = np.random.default_rng(11)
    h, hd, c, k = 4, 16, 64, 16
    q = jnp.asarray(rng.standard_normal((k, h, hd)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((c, h, hd)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((c, h, hd)).astype(np.float32))
    for off in (0, 16, 48):
        got = chunk_attention(q, kc, vc, jnp.int32(off))
        want = ref.chunk_attention(q, kc, vc, jnp.int32(off))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_chunk_attention_masks_future_columns():
    """Columns > off+row must not influence the output at all."""
    rng = np.random.default_rng(13)
    h, hd, c, k = 2, 16, 64, 16
    off = 16
    q = jnp.asarray(rng.standard_normal((k, h, hd)).astype(np.float32))
    kc = np.asarray(rng.standard_normal((c, h, hd)), np.float32)
    vc = np.asarray(rng.standard_normal((c, h, hd)), np.float32)
    a = ref.chunk_attention(q, jnp.asarray(kc), jnp.asarray(vc),
                            jnp.int32(off))
    kc[off + k:] = 1e9
    vc[off + k:] = -1e9
    b = ref.chunk_attention(q, jnp.asarray(kc), jnp.asarray(vc),
                            jnp.int32(off))
    assert np.array_equal(np.asarray(a), np.asarray(b))
