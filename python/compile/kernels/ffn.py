"""Fused SwiGLU FFN Pallas kernel.

TPU mapping: one grid step per row-block of x. The weight matrices
(D×F, D×F, F×D; worst case 256×512 f32 = 512 KiB each) sit in VMEM for the
whole kernel; activations stream through in [Br, D] tiles. Gate and up
projections read the x tile once (fused), matching the paper's observation
that LP-style fusion raises arithmetic density.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    a = g * (1.0 / (1.0 + jnp.exp(-g))) * u          # silu(g) * u
    o_ref[...] = jnp.dot(a, wd_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_r",))
def swiglu_ffn(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
               wd: jnp.ndarray, block_r: int = 128) -> jnp.ndarray:
    """SwiGLU MLP: (silu(x@wg) * (x@wu)) @ wd. x: [T, D] -> [T, D]."""
    t, d = x.shape
    f = wg.shape[1]
    br = min(block_r, t)
    assert t % br == 0, f"T={t} must divide block_r={br}"
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(t // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, wg, wu, wd)
