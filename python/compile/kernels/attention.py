"""Causal attention Pallas kernels (prefill and decode).

TPU mapping (the paper targets CUDA threadblocks; we re-derive the schedule
for the TPU memory hierarchy — DESIGN.md §Hardware-Adaptation):

* ``flash_attention`` — grid over (head, query-block). Each grid step holds
  one query tile [Bq, hd] plus the full K/V stripes [T, hd] for that head in
  VMEM (T ≤ 256, hd = 32 → 2·32 KiB — far under budget, so no K/V streaming
  loop is needed at this scale; the BlockSpec already expresses the
  HBM→VMEM schedule that would stream for larger T). Scores use the MXU via
  jnp.dot with f32 accumulation.

* ``cached_attention`` — decode step: one token's query against a cache
  stripe [C, hd]; grid over heads. Positions beyond `pos` are masked, so a
  statically-shaped cache (C = ctx) serves every sequence length.

* ``chunk_attention`` — streaming prefill: a chunk of K queries at global
  positions off..off+K-1 against the full cache stripe [C, hd] (earlier
  chunks already inserted). Same grid/tiling as ``flash_attention`` with
  the causal mask shifted by ``off``; masked columns are exact zeros after
  the softmax, which keeps chunked prefill bit-identical to the monolithic
  kernel (trailing zeros drop out of row-wise reductions).

Both are numerically checked against kernels.ref by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int):
    # q_ref: [block_q, hd] for (head h, q-block i); k/v_ref: [T, hd] for h.
    i = pl.program_id(1)
    q = q_ref[:, 0, :]          # squeeze the blocked head axis: [Bq, hd]
    k = k_ref[:, 0, :]          # [T, hd]
    v = v_ref[:, 0, :]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    # MXU matmul, f32 accumulate.
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [Bq,T]
    rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols <= rows, s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[:, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_q",))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    block_q: int = 64) -> jnp.ndarray:
    """Causal MHA. q,k,v: [T, H, hd] with RoPE pre-applied. -> [T, H, hd]."""
    t, h, hd = q.shape
    bq = min(block_q, t)
    assert t % bq == 0, f"T={t} must divide block_q={bq}"
    grid = (h, t // bq)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, hd), lambda h_, i: (i, h_, 0)),
            pl.BlockSpec((t, 1, hd), lambda h_, i: (0, h_, 0)),
            pl.BlockSpec((t, 1, hd), lambda h_, i: (0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, hd), lambda h_, i: (i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, hd), jnp.float32),
        interpret=True,
    )(q, k, v)
    return out


def _chunk_kernel(q_ref, k_ref, v_ref, off_ref, o_ref, *, block_q: int):
    # q_ref: [block_q, 1, hd] for (head h, q-block i); k/v_ref: [C, 1, hd].
    i = pl.program_id(1)
    q = q_ref[:, 0, :]          # [Bq, hd]
    k = k_ref[:, 0, :]          # [C, hd]
    v = v_ref[:, 0, :]
    off = off_ref[0]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [Bq,C]
    rows = off + i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols <= rows, s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[:, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_q",))
def chunk_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                    off: jnp.ndarray, block_q: int = 64) -> jnp.ndarray:
    """Chunked-prefill MHA. q: [K, H, hd] (RoPE at positions off..off+K-1);
    k_cache/v_cache: [C, H, hd] with rows [off, off+K) freshly inserted;
    off: int32 scalar. Row i attends to cache columns j <= off+i. -> [K,H,hd].
    """
    t, h, hd = q.shape
    c = k_cache.shape[0]
    bq = min(block_q, t)
    assert t % bq == 0, f"block_q={bq} must divide K={t}"
    off_arr = jnp.broadcast_to(jnp.asarray(off, jnp.int32).reshape(1), (1,))
    grid = (h, t // bq)
    out = pl.pallas_call(
        functools.partial(_chunk_kernel, block_q=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, hd), lambda h_, i: (i, h_, 0)),
            pl.BlockSpec((c, 1, hd), lambda h_, i: (0, h_, 0)),
            pl.BlockSpec((c, 1, hd), lambda h_, i: (0, h_, 0)),
            pl.BlockSpec((1,), lambda h_, i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, 1, hd), lambda h_, i: (i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, hd), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, off_arr)
    return out


def _cached_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    # q_ref: [1, 1, hd] for head h; k/v_ref: [C, 1, hd]; pos_ref: [1] int32.
    q = q_ref[:, 0, :]          # [1, hd]
    k = k_ref[:, 0, :]          # [C, hd]
    v = v_ref[:, 0, :]
    pos = pos_ref[0]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [1,C]
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols <= pos, s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[:, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def cached_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Decode attention. q: [H, hd]; caches: [C, H, hd]; pos: int32 scalar."""
    h, hd = q.shape
    c = k_cache.shape[0]
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(1), (1,))
    out = pl.pallas_call(
        _cached_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda h_: (0, h_, 0)),
            pl.BlockSpec((c, 1, hd), lambda h_: (0, h_, 0)),
            pl.BlockSpec((c, 1, hd), lambda h_: (0, h_, 0)),
            pl.BlockSpec((1,), lambda h_: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda h_: (0, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((1, h, hd), jnp.float32),
        interpret=True,
    )(q.reshape(1, h, hd), k_cache, v_cache, pos_arr)
    return out.reshape(h, hd)
