"""L1 — Pallas kernels for the paper's compute hot-spots.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is how the kernels lower into plain
HLO that the rust runtime can load (see /opt/xla-example/README.md). The
BlockSpec structure is written for the real TPU memory hierarchy anyway —
VMEM-resident tiles, MXU-shaped matmuls — and DESIGN.md §Perf carries the
analytic VMEM/MXU estimates.

Kernels:
  rmsnorm        — row-wise RMSNorm
  dual_rmsnorm   — LP fused dual-path norm (one HBM read of x, two outputs)
  flash_attention— causal attention, grid over (head, q-block)
  cached_attention — decode-step attention against a KV cache slot
  chunk_attention — streaming-prefill chunk against a KV cache slot
  swiglu_ffn     — fused SwiGLU MLP
"""

from .rmsnorm import rmsnorm, dual_rmsnorm            # noqa: F401
from .attention import (                              # noqa: F401
    flash_attention,
    cached_attention,
    chunk_attention,
)
from .ffn import swiglu_ffn                           # noqa: F401
