"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematical definition; the Pallas kernels in
this package must match to float32 tolerance. `python/tests/test_kernels.py`
sweeps shapes with hypothesis and asserts allclose.
"""

from __future__ import annotations

import jax.numpy as jnp


def softmax(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis: x * rsqrt(mean(x^2)+eps) * w."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * w


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float = 10000.0):
    """cos/sin tables for rotary embedding, half-split (Llama) convention.

    positions: int32 [...]; returns (cos, sin) of shape [..., head_dim//2].
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [..., head_dim]; cos/sin broadcastable to [..., head_dim//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Multi-head causal attention. q,k,v: [T,H,hd] (RoPE applied). -> [T,H,hd]."""
    t, h, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("thd,shd->hts", q, k) * scale          # [H,T,T]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    return jnp.einsum("hts,shd->thd", softmax(scores, axis=-1), v)


def cached_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray) -> jnp.ndarray:
    """Single-token attention against a KV cache.

    q: [H,hd]; k_cache/v_cache: [C,H,hd]; pos: scalar int32 — index of the
    current token (cache already holds K/V at `pos`). Attends to j <= pos.
    """
    c, h, hd = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("hd,chd->hc", q, k_cache) * scale      # [H,C]
    valid = jnp.arange(c) <= pos
    scores = jnp.where(valid[None, :], scores, -1e30)
    return jnp.einsum("hc,chd->hd", softmax(scores, axis=-1), v_cache)


def chunk_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                    off: jnp.ndarray) -> jnp.ndarray:
    """Chunked-prefill attention against a KV cache stripe.

    q: [K,H,hd] — a chunk of K queries at global positions off..off+K-1;
    k_cache/v_cache: [C,H,hd] with rows < off filled by earlier chunks and
    rows [off, off+K) holding this chunk's freshly inserted K/V; off: scalar
    int32. Row i attends to cache columns j <= off + i.
    """
    c, h, hd = k_cache.shape
    k = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("khd,chd->hkc", q, k_cache) * scale    # [H,K,C]
    rows = off + jnp.arange(k, dtype=jnp.int32)
    valid = jnp.arange(c)[None, :] <= rows[:, None]            # [K,C]
    scores = jnp.where(valid[None, :, :], scores, -1e30)
    return jnp.einsum("hkc,chd->khd", softmax(scores, axis=-1), v_cache)


def swiglu_ffn(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
               wd: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU: (silu(x@wg) * (x@wu)) @ wd. x: [T,D]."""
    return (silu(x @ wg) * (x @ wu)) @ wd


def dual_rmsnorm(x: jnp.ndarray, wa: jnp.ndarray, wb: jnp.ndarray,
                 eps: float = 1e-5):
    """LP dual-path norm: one read of x, two weighted outputs."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(ms + eps)
    return x * inv * wa, x * inv * wb
