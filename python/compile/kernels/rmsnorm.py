"""RMSNorm Pallas kernels.

TPU mapping: a [T, D] activation tile is far below the ~16 MiB VMEM budget
for every configuration in this repo (256×256 f32 = 256 KiB), so the whole
tensor is a single block and the grid is trivial. The interesting kernel is
``dual_rmsnorm``: the LP transform needs the *same* hidden state normalized
with *two different* weight vectors (each divergent path keeps its original
layer's norm). Fusing both into one kernel reads x from HBM once instead of
twice — the TPU analogue of the paper's fused-projection trick.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _rmsnorm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(ms + EPS)) * w_ref[...]


@functools.partial(jax.jit, static_argnames=())
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: [T, D]; w: [D]. Single-block kernel (fits VMEM at all our sizes)."""
    return pl.pallas_call(
        _rmsnorm_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, w)


def _dual_rmsnorm_kernel(x_ref, wa_ref, wb_ref, oa_ref, ob_ref):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(ms + EPS)
    xn = x * inv                       # shared normalization, computed once
    oa_ref[...] = xn * wa_ref[...]
    ob_ref[...] = xn * wb_ref[...]


def dual_rmsnorm(x: jnp.ndarray, wa: jnp.ndarray, wb: jnp.ndarray):
    """LP dual-path norm. x: [T, D]; wa, wb: [D] -> (xa, xb)."""
    shape = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(
        _dual_rmsnorm_kernel,
        out_shape=(shape, shape),
        interpret=True,
    )(x, wa, wb)
