"""Model configurations (the two build-time-trained Llama-architecture models).

`td-small` and `td-base` play the roles of Llama 3.2 3B / Llama 2 7B in the
paper's experiments: same block structure (pre-RMSNorm, RoPE MHA, SwiGLU),
scaled to what trains in minutes on this testbed. The *relative* claims
(larger model tolerates more LP; speedup ∝ Δ) are architecture-level and
survive the scaling — see DESIGN.md §Substitutions.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

from . import tok


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    d_ff: int
    ctx: int                      # max context / KV-cache length
    slots: int = 4                # decode batch slots (continuous batching)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def width(self) -> int:
        return self.n_heads * self.head_dim

    def to_dict(self) -> dict:
        return asdict(self)


SMALL = ModelConfig(
    name="td-small", vocab=tok.VOCAB_SIZE, d_model=128, n_layers=12,
    n_heads=4, head_dim=32, d_ff=256, ctx=256,
)

BASE = ModelConfig(
    name="td-base", vocab=tok.VOCAB_SIZE, d_model=256, n_layers=16,
    n_heads=8, head_dim=32, d_ff=512, ctx=256,
)

CONFIGS = {c.name: c for c in (SMALL, BASE)}

# Sequence-length buckets compiled AOT. Requests are padded up to the
# nearest bucket by the rust coordinator.
SEQ_BUCKETS = (32, 128, 256)

# Chunked streaming prefill: one fixed-shape executable per stage consumes
# PREFILL_CHUNK tokens at a position offset against the live KV cache, so a
# prompt of L tokens costs ceil(L / PREFILL_CHUNK) chunk steps instead of
# padding to the covering SEQ_BUCKET. Must divide every model ctx (the last
# chunk's cache window [off, off+chunk) must stay in bounds).
PREFILL_CHUNK = 32

# Paged KV cache: page granularity in tokens (the vLLM block size). Equal to
# PREFILL_CHUNK so one chunk step fills exactly one page, and the chunk
# cursor's `off` scalar doubles as the page boundary. Must divide
# PREFILL_CHUNK and every model ctx.
PAGE_TOKENS = PREFILL_CHUNK


def _pair_stages(n: int, s: int, e: int) -> list[list[int]]:
    """Stage list of contiguous 2-parallel LP over the window [s, e) —
    mirror of the rust `transform::pair_parallel` (an odd trailing layer
    stays sequential)."""
    stages: list[list[int]] = [[i] for i in range(s)]
    i = s
    while i + 1 < e:
        stages.append([i, i + 1])
        i += 2
    if i < e:
        stages.append([i])
    stages.extend([i] for i in range(e, n))
    return stages


def plan_variants(cfg: ModelConfig) -> dict[str, list[list[int]]]:
    """Named plan variants compiled into the manifest's per-model
    ``variants`` section — the serving tiers one weight set supports.

    Each variant is a stage list: ``[i]`` is a TP-sharded single layer,
    ``[a, b]`` an LP pair (rank r runs layer r of the pair at full width).
    All variants reuse the same stage/embed/logits/chunk executables (the
    artifacts are weight- and plan-agnostic); the manifest entry only
    records *which* stages each tier walks.

    * ``dense``  — the untransformed sequential model (full quality);
    * ``lp``     — LP pairs over the paper's best contiguous band (first
      and last ~n/6 layers stay sequential, the placement Fig. 6's PPL
      sweep favours);
    * ``lp_aggr``— LP over the whole stack (max speed, lowest depth).
    """
    n = cfg.n_layers
    lo = max(1, round(n / 6))
    return {
        "dense": [[i] for i in range(n)],
        "lp": _pair_stages(n, lo, n - lo),
        "lp_aggr": _pair_stages(n, 0, n),
    }


def batch_buckets(slots: int) -> tuple[int, ...]:
    """Decode batch-shape buckets for a model with `slots` KV slots.

    Powers of two up to (and always including) `slots`: the runtime's
    `BucketSet` selects the smallest bucket covering the live-lane count, so
    a 1-live-slot round dispatches the B=1 executables instead of paying the
    full-[S] compute and logits download. Mirrors SEQ_BUCKETS for prefill.
    """
    ladder = []
    b = 1
    while b < slots:
        ladder.append(b)
        b *= 2
    ladder.append(slots)
    return tuple(ladder)


def kv_pages(cfg: ModelConfig) -> dict:
    """Paged-KV pool geometry for the manifest's per-model ``kv_pages``
    section (parsed by rust ``runtime::artifacts``).

    KV lives in two per-rank page pools — one per cache width — shared by
    every plan variant, instead of one dense ``[S, C, w]`` cache per stage
    per tier. A page holds PAGE_TOKENS K (or V) rows of one stage of one
    sequence; per-slot page tables (the ``pt`` i32 operand of the paged
    executables) map block index -> page id.

    Pool sizing is the dense-equivalent worst case: every stage of every
    variant can hold every slot at full context simultaneously (the dense
    layout's capacity, so paging alone never rejects what dense admitted),
    plus page 0 — reserved scratch that unmapped page-table entries point
    at. Anything tighter is a runtime *policy* (`set_page_capacity`), not a
    compiled shape.
    """
    page = PAGE_TOKENS
    assert cfg.ctx % page == 0, f"ctx {cfg.ctx} not a multiple of {page}"
    assert PREFILL_CHUNK % page == 0
    blocks = cfg.ctx // page
    half = full = 0
    for stages in plan_variants(cfg).values():
        for st in stages:
            if len(st) == 1:
                half += 1       # TP-sharded layer: w = D/2 per rank
            else:
                full += 1       # LP pair: each rank holds a full-width cache
    return {
        "page_tokens": page,
        "blocks_per_slot": blocks,
        "pool_pages_half": half * cfg.slots * blocks + 1,
        "pool_pages_full": full * cfg.slots * blocks + 1,
    }


def n_params(cfg: ModelConfig) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    per_layer = 2 * d + 4 * d * d + 3 * d * f
    return v * d + cfg.n_layers * per_layer + d + d * v
