"""Deterministic synthetic corpus (substrate — mirrored by rust/src/text/corpus.rs).

Stands in for RedPajama (perplexity set) and for the lm-eval ICL suites: the
corpus interleaves templated natural-language sentences, arithmetic facts,
relation ("capital of") facts, and the ICL task formats, so that (a) a tiny
model trained on it acquires measurable in-context skills and (b) held-out
perplexity reacts to computational-graph damage the same ordered way the
paper reports (prune > merge > shuffle > parallel).

Everything is driven by SplitMix64 so the rust mirror reproduces the exact
byte stream given the same seed — parity is asserted by golden tests on both
sides (`python/tests/test_data.py`, `rust/src/text/corpus.rs`).
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1


class SplitMix64:
    """SplitMix64 PRNG; bit-exact twin of rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def below(self, n: int) -> int:
        """Uniform integer in [0, n) (modulo method; fine for corpus use)."""
        return self.next_u64() % n


# --- fixed word tables (identical constants in the rust mirror) ------------

ADJECTIVES = [
    "red", "small", "quiet", "bright", "old", "swift", "calm", "brave",
    "green", "tall", "soft", "sharp", "young", "cold", "warm", "plain",
]
NOUNS = [
    "fox", "river", "stone", "bird", "tree", "cloud", "wolf", "lamp",
    "ship", "tower", "field", "storm", "book", "road", "horse", "flame",
]
VERBS = [
    "watches", "follows", "finds", "passes", "guards", "carries", "meets",
    "crosses", "holds", "leaves", "seeks", "joins", "greets", "trails",
    "lifts", "turns",
]
COUNTRIES = [
    "avaria", "belmora", "cassia", "dorvan", "elyna", "fermont", "galdia",
    "harwick", "isolde", "jorvik", "kelmar", "lorvina", "mendia", "norwell",
    "ostrava", "pellia", "quorath", "rivona", "selwick", "tormund",
    "ulvania", "verdane", "wystan", "xanthe", "yorvale", "zembla",
    "ardenne", "brovia", "cathmor", "drellin", "eswick", "farlone",
]
CAPITALS = [
    "avaport", "belcity", "casburg", "dorhaven", "elyton", "fermouth",
    "galford", "harmont", "isoton", "jorholm", "kelport", "lorgrad",
    "menfort", "norbury", "ostwick", "pelgrove", "quorton", "rivgate",
    "selmora", "torvale", "ulham", "verdun", "wysport", "xanburg",
    "yorford", "zemholm", "ardfell", "broville", "cathwick", "drelport",
    "esgard", "farmont",
]
LETTERS = "abcdefghijklmnopqrstuvwxyz"


def capital_of(country_idx: int) -> str:
    """The relation is positional: COUNTRIES[i] -> CAPITALS[i]."""
    return CAPITALS[country_idx]


# --- atomic item generators -------------------------------------------------

def gen_sentence(rng: SplitMix64) -> str:
    a = ADJECTIVES[rng.below(len(ADJECTIVES))]
    n1 = NOUNS[rng.below(len(NOUNS))]
    v = VERBS[rng.below(len(VERBS))]
    n2 = NOUNS[rng.below(len(NOUNS))]
    return f"the {a} {n1} {v} the {n2} ."


def gen_arith(rng: SplitMix64) -> str:
    # single-digit operands: answers stay <= 2 digits, so a ~2M-param model
    # can actually acquire the circuit (the GSM-8K-analogue must be above
    # chance at baseline for the paper's fragility story to be testable)
    a = rng.below(10)
    b = rng.below(10)
    if rng.below(2) == 0:
        return f"{a} + {b} = {a + b} ."
    hi, lo = max(a, b), min(a, b)
    return f"{hi} - {lo} = {hi - lo} ."


def gen_relation(rng: SplitMix64) -> str:
    i = rng.below(len(COUNTRIES))
    return f"the capital of {COUNTRIES[i]} is {capital_of(i)} ."


def _rand_letters(rng: SplitMix64, lo: int, hi: int) -> str:
    k = lo + rng.below(hi - lo + 1)
    return "".join(LETTERS[rng.below(26)] for _ in range(k))


def gen_copy(rng: SplitMix64) -> str:
    w = _rand_letters(rng, 3, 6)
    return f"copy : {w} -> {w} ."


def gen_reverse(rng: SplitMix64) -> str:
    w = _rand_letters(rng, 3, 6)
    return f"rev : {w} -> {w[::-1]} ."


def gen_pattern(rng: SplitMix64) -> str:
    start = rng.below(22)
    seq = [LETTERS[start + j] for j in range(4)]
    return f"next : {' '.join(seq[:3])} -> {seq[3]} ."


ITEM_KINDS = [gen_sentence, gen_arith, gen_relation, gen_copy, gen_reverse,
              gen_pattern]
# sampling weights out of 16 (sentence-heavy, like natural text)
ITEM_WEIGHTS = [6, 3, 3, 1, 1, 2]
_CUM = [sum(ITEM_WEIGHTS[: i + 1]) for i in range(len(ITEM_WEIGHTS))]


def gen_item(rng: SplitMix64) -> str:
    r = rng.below(_CUM[-1])
    for k, c in enumerate(_CUM):
        if r < c:
            return ITEM_KINDS[k](rng)
    raise AssertionError("unreachable")


def gen_document(rng: SplitMix64, n_items: int = 8) -> str:
    return " ".join(gen_item(rng) for _ in range(n_items))


def gen_corpus(seed: int, n_docs: int) -> list[str]:
    """n_docs documents; doc i uses its own stream seeded with seed ^ i*GOLDEN
    so rust and python can generate disjoint slices independently."""
    docs = []
    for i in range(n_docs):
        rng = SplitMix64((seed ^ (i * 0x9E3779B97F4A7C15)) & MASK64)
        docs.append(gen_document(rng))
    return docs


# Train/eval split convention shared with rust: documents with index
# < 0x4000_0000 are train; eval uses indices starting at EVAL_BASE.
EVAL_BASE = 0x40000000


def train_doc(seed: int, i: int) -> str:
    return gen_corpus_doc(seed, i)


def eval_doc(seed: int, i: int) -> str:
    return gen_corpus_doc(seed, EVAL_BASE + i)


def gen_corpus_doc(seed: int, i: int) -> str:
    rng = SplitMix64((seed ^ (i * 0x9E3779B97F4A7C15)) & MASK64)
    return gen_document(rng)


if __name__ == "__main__":
    rng = SplitMix64(7)
    for _ in range(4):
        print(gen_item(rng))
