"""Byte-level tokenizer (substrate — mirrored by rust/src/text/tokenizer.rs).

Vocabulary layout (V = 260):
  0..255  raw bytes
  256     BOS
  257     EOS
  258     PAD
  259     reserved (keeps V even / alignment-friendly)

The rust implementation must agree exactly; `python/tests/test_data.py`
checks golden encodings shared with `rust/src/text/tokenizer.rs` tests.
"""

from __future__ import annotations

VOCAB_SIZE = 260
BOS = 256
EOS = 257
PAD = 258


def encode(text: str, bos: bool = False, eos: bool = False) -> list[int]:
    """UTF-8 bytes to token ids, optionally wrapped in BOS/EOS."""
    ids = list(text.encode("utf-8"))
    if bos:
        ids.insert(0, BOS)
    if eos:
        ids.append(EOS)
    return ids


def decode(ids: list[int]) -> str:
    """Token ids back to text; specials are dropped."""
    return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")


def pad_to(ids: list[int], length: int) -> list[int]:
    """Right-pad to exactly `length` tokens.

    `length < len(ids)` used to silently truncate — dropping the prompt
    tail; it is a caller bug (a mis-sized bucket) and now raises.
    """
    if length < len(ids):
        raise ValueError(
            f"pad_to: {len(ids)} tokens do not fit length {length} "
            "(would silently drop the tail)"
        )
    return ids + [PAD] * (length - len(ids))
