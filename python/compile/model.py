"""L2 — the JAX model: Llama-style decoder + every AOT-exported entrypoint.

Two kernel implementations are selectable per call:
  impl="pallas" — the L1 Pallas kernels (interpret mode). Used for all AOT
                  inference artifacts, so the kernels lower into the HLO the
                  rust runtime executes.
  impl="jnp"    — the pure-jnp reference path. Used for training (fast,
                  differentiable) and as the oracle in tests.

Graph modes implemented here (training/fine-tuning side):
  forward_seq    — standard sequential model.
  forward_lp     — the deployed LP-TP form over chosen pair windows
                   (m = x + A_k(x) + A_{k+1}(x); y = m + F_k(m) + F_{k+1}(m)),
                   used for Table-2 fine-tuning.

The rust coordinator composes all §3 transforms (shuffle/prune/merge/
parallel/2-parallel) at runtime from the per-sub-block artifacts exported by
aot.py, so the heatmap experiments need no per-config compilation.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import tok
from .modelcfg import ModelConfig
from .kernels import (
    rmsnorm as pl_rmsnorm,
    flash_attention as pl_flash,
    cached_attention as pl_cached,
    chunk_attention as pl_chunk,
    swiglu_ffn as pl_ffn,
)
from .kernels import ref

Params = dict[str, Any]


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """He/Glorot-ish init matching Llama conventions (scaled residual outs)."""
    d, f, v, n = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    keys = jax.random.split(key, 3 + 7 * n)
    ki = iter(range(len(keys)))

    def dense(k, fan_in, shape, scale=1.0):
        return (jax.random.normal(keys[k], shape, jnp.float32)
                * scale / jnp.sqrt(jnp.float32(fan_in)))

    p: Params = {
        "emb": jax.random.normal(keys[next(ki)], (v, d), jnp.float32) * 0.02,
        "lnf": jnp.ones((d,), jnp.float32),
        "wout": dense(next(ki), d, (d, v)),
    }
    _ = next(ki)
    out_scale = 1.0 / jnp.sqrt(jnp.float32(2 * n))  # residual-stream scaling
    layers = []
    for _i in range(n):
        layers.append({
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": dense(next(ki), d, (d, d)),
            "wk": dense(next(ki), d, (d, d)),
            "wv": dense(next(ki), d, (d, d)),
            "wo": dense(next(ki), d, (d, d), scale=out_scale),
            "ln2": jnp.ones((d,), jnp.float32),
            "wg": dense(next(ki), d, (d, f)),
            "wu": dense(next(ki), d, (d, f)),
            "wd": dense(next(ki), f, (f, d), scale=out_scale),
        })
    p["layers"] = layers
    return p


# --------------------------------------------------------------------------
# Sub-block primitives (both impls)
# --------------------------------------------------------------------------

def _norm(x, w, impl):
    return pl_rmsnorm(x, w) if impl == "pallas" else ref.rmsnorm(x, w)


def _attention(q, k, v, impl):
    return pl_flash(q, k, v) if impl == "pallas" else ref.causal_attention(q, k, v)


def _swiglu(x, wg, wu, wd, impl):
    return pl_ffn(x, wg, wu, wd) if impl == "pallas" else ref.swiglu_ffn(x, wg, wu, wd)


def attn_delta(cfg: ModelConfig, h, ln, wq, wk, wv, wo, impl="jnp",
               pos_offset=0):
    """A(x): pre-norm causal attention sub-block *delta* (no residual add).

    h: [T, D]; weight widths may be sharded: wq/wk/wv: [D, w], wo: [w, D]
    with w a multiple of head_dim. Positions are 0..T-1 (+offset).
    """
    t = h.shape[0]
    hd = cfg.head_dim
    xn = _norm(h, ln, impl)
    w = wq.shape[1]
    nh = w // hd
    q = (xn @ wq).reshape(t, nh, hd)
    k = (xn @ wk).reshape(t, nh, hd)
    v = (xn @ wv).reshape(t, nh, hd)
    posv = jnp.arange(t, dtype=jnp.int32) + pos_offset
    cos, sin = ref.rope_angles(posv, hd, cfg.rope_theta)
    q = ref.apply_rope(q, cos[:, None, :], sin[:, None, :])
    k = ref.apply_rope(k, cos[:, None, :], sin[:, None, :])
    att = _attention(q, k, v, impl).reshape(t, w)
    return att @ wo


def ffn_delta(cfg: ModelConfig, h, ln, wg, wu, wd, impl="jnp"):
    """F(x): pre-norm SwiGLU sub-block delta. Sharded widths allowed."""
    xn = _norm(h, ln, impl)
    return _swiglu(xn, wg, wu, wd, impl)


# --------------------------------------------------------------------------
# Full forwards (training / fine-tuning)
# --------------------------------------------------------------------------

def forward_seq(cfg: ModelConfig, p: Params, tokens, impl="jnp"):
    """Sequential forward. tokens: int32 [T] -> logits [T, V]."""
    h = p["emb"][tokens]
    for lp in p["layers"]:
        h = h + attn_delta(cfg, h, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                           lp["wo"], impl)
        h = h + ffn_delta(cfg, h, lp["ln2"], lp["wg"], lp["wu"], lp["wd"], impl)
    return _norm(h, p["lnf"], impl) @ p["wout"]


def lp_pairs_for_window(n_layers: int, start: int, end: int) -> list[tuple[int, int]]:
    """Consecutive disjoint pairs covering [start, end) (paper's contiguous
    2-parallel): (s,s+1), (s+2,s+3), ... A trailing odd layer stays sequential."""
    pairs = []
    i = start
    while i + 1 < end:
        pairs.append((i, i + 1))
        i += 2
    return pairs


def forward_lp(cfg: ModelConfig, p: Params, tokens, pairs, impl="jnp"):
    """LP-TP deployed form: paired layers share the post-attention residual.

    pairs: list of (k, k+1) disjoint ascending layer pairs; all other layers
    run sequentially. This is the graph the rust serving path executes, so
    fine-tuning against it (Table 2) optimizes the true deployment numerics.
    """
    pair_first = {a: b for a, b in pairs}
    in_pair_second = {b for _, b in pairs}
    h = p["emb"][tokens]
    i = 0
    layers = p["layers"]
    while i < len(layers):
        if i in pair_first:
            la, lb = layers[i], layers[pair_first[i]]
            m = (h
                 + attn_delta(cfg, h, la["ln1"], la["wq"], la["wk"], la["wv"], la["wo"], impl)
                 + attn_delta(cfg, h, lb["ln1"], lb["wq"], lb["wk"], lb["wv"], lb["wo"], impl))
            h = (m
                 + ffn_delta(cfg, m, la["ln2"], la["wg"], la["wu"], la["wd"], impl)
                 + ffn_delta(cfg, m, lb["ln2"], lb["wg"], lb["wu"], lb["wd"], impl))
            i = pair_first[i] + 1
        else:
            assert i not in in_pair_second
            lp_ = layers[i]
            h = h + attn_delta(cfg, h, lp_["ln1"], lp_["wq"], lp_["wk"], lp_["wv"], lp_["wo"], impl)
            h = h + ffn_delta(cfg, h, lp_["ln2"], lp_["wg"], lp_["wu"], lp_["wd"], impl)
            i += 1
    return _norm(h, p["lnf"], impl) @ p["wout"]


def loss_fn(cfg: ModelConfig, p: Params, tokens, forward=forward_seq, **fw_kw):
    """Next-token cross-entropy over a [B, T] batch; PAD positions masked."""
    def one(seq):
        logits = forward(cfg, p, seq[:-1], **fw_kw)
        targets = seq[1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
        mask = (targets != tok.PAD).astype(jnp.float32)
        return jnp.sum(nll * mask), jnp.sum(mask)

    losses, counts = jax.vmap(one)(tokens)
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)


# --------------------------------------------------------------------------
# AOT-exported entrypoints (closed over nothing; weights are arguments)
# --------------------------------------------------------------------------
# Widths: "full" (w = D, LP paths + scoring) and "half" (w = D/2, TP shards).

def make_embed(cfg: ModelConfig):
    def embed(tokens, emb):
        """tokens: i32 [T]; emb: [V, D] -> h [T, D]."""
        return (emb[tokens],)
    return embed


def make_attn_delta(cfg: ModelConfig, impl="pallas"):
    def attn(h, ln, wq, wk, wv, wo):
        """Scoring/LP-path attention delta at full width."""
        return (attn_delta(cfg, h, ln, wq, wk, wv, wo, impl),)
    return attn


def make_ffn_delta(cfg: ModelConfig, impl="pallas"):
    def ffn(h, ln, wg, wu, wd):
        return (ffn_delta(cfg, h, ln, wg, wu, wd, impl),)
    return ffn


def make_logits(cfg: ModelConfig, impl="pallas"):
    def logits(h, lnf, wout):
        return (_norm(h, lnf, impl) @ wout,)
    return logits


def make_shard_attn_prefill(cfg: ModelConfig, impl="pallas"):
    def attn(h, ln, wq, wk, wv, wo):
        """TP/LP prefill shard: returns the partial output (to be
        all-reduced by the coordinator) and this shard's K/V stripes.

        h: [T, D]; wq/wk/wv: [D, w]; wo: [w, D] -> (part [T,D], k [T,w], v [T,w]).
        """
        t = h.shape[0]
        hd = cfg.head_dim
        xn = _norm(h, ln, impl)
        w = wq.shape[1]
        nh = w // hd
        q = (xn @ wq).reshape(t, nh, hd)
        k = (xn @ wk).reshape(t, nh, hd)
        v = (xn @ wv).reshape(t, nh, hd)
        posv = jnp.arange(t, dtype=jnp.int32)
        cos, sin = ref.rope_angles(posv, hd, cfg.rope_theta)
        qr = ref.apply_rope(q, cos[:, None, :], sin[:, None, :])
        kr = ref.apply_rope(k, cos[:, None, :], sin[:, None, :])
        att = _attention(qr, kr, v, impl).reshape(t, w)
        return att @ wo, kr.reshape(t, w), v.reshape(t, w)
    return attn


def make_shard_attn_chunk(cfg: ModelConfig, impl="pallas", chunk=32):
    """Chunked streaming-prefill attention shard: `chunk` tokens at position
    offset `off` against the live `[S, C, w]` KV caches, with the fresh K/V
    rows inserted in the same pass (no separate cache_insert step).

    Bit-exactness contract with `make_shard_attn_prefill`: the projections,
    RoPE and full-row softmax are the same row-wise math (XLA CPU keeps
    row-wise ops batch-size-invariant), and every masked cache column is an
    exact zero after the softmax (exp(-1e30 - m) underflows to 0.0), so a
    prompt prefilled in chunks reproduces the monolithic fixed-T lowering
    bit for bit — pinned by `python/tests/test_chunk_prefill.py` and the
    rust serving test `chunked_prefill_bit_identical_to_monolithic`.

    K/V insertion is masked by `valid`: rows >= valid (the PAD tail of the
    final partial chunk) keep the cache's previous contents, so PAD-token
    K/V never lands in the cache. Pad rows still compute (discarded)
    attention outputs against whatever the unwritten columns hold — finite
    garbage, never read by callers.
    """
    C, hd = cfg.ctx, cfg.head_dim
    K = chunk

    def attn(h, ln, wq, wk, wv, wo, kcache, vcache, slot, off, valid):
        """h: [K, D]; caches: [S, C, w]; slot/off/valid: scalar i32 ->
        (partial [K, D], kcache', vcache')."""
        w = wq.shape[1]
        nh = w // hd
        xn = _norm(h, ln, impl)
        q = (xn @ wq).reshape(K, nh, hd)
        k = (xn @ wk).reshape(K, nh, hd)
        v = (xn @ wv).reshape(K, nh, hd)
        posv = jnp.arange(K, dtype=jnp.int32) + off
        cos, sin = ref.rope_angles(posv, hd, cfg.rope_theta)
        qr = ref.apply_rope(q, cos[:, None, :], sin[:, None, :])
        kr = ref.apply_rope(k, cos[:, None, :], sin[:, None, :])
        kslot = jax.lax.dynamic_slice(kcache, (slot, 0, 0), (1, C, w))[0]
        vslot = jax.lax.dynamic_slice(vcache, (slot, 0, 0), (1, C, w))[0]
        rows = jnp.arange(K, dtype=jnp.int32)[:, None]
        ins_k = jnp.where(rows < valid, kr.reshape(K, w),
                          jax.lax.dynamic_slice(kslot, (off, 0), (K, w)))
        ins_v = jnp.where(rows < valid, v.reshape(K, w),
                          jax.lax.dynamic_slice(vslot, (off, 0), (K, w)))
        kslot = jax.lax.dynamic_update_slice(kslot, ins_k, (off, 0))
        vslot = jax.lax.dynamic_update_slice(vslot, ins_v, (off, 0))
        if impl == "pallas":
            att = pl_chunk(qr, kslot.reshape(C, nh, hd),
                           vslot.reshape(C, nh, hd), off)
        else:
            att = ref.chunk_attention(qr, kslot.reshape(C, nh, hd),
                                      vslot.reshape(C, nh, hd), off)
        part = att.reshape(K, w) @ wo
        kc2 = jax.lax.dynamic_update_slice(kcache, kslot[None], (slot, 0, 0))
        vc2 = jax.lax.dynamic_update_slice(vcache, vslot[None], (slot, 0, 0))
        return part, kc2, vc2
    return attn


def make_shard_attn_chunk_paged(cfg: ModelConfig, impl="pallas", chunk=32):
    """Paged-KV chunked-prefill attention shard: same math as
    `make_shard_attn_chunk`, but K/V live in a shared page pool `[P, page, w]`
    and the sequence's rows are reached through an i32 page table `pt[nb]`
    (block j of the context -> pool page `pt[j]`) instead of a dense slot
    offset. `page == chunk`, so each chunk step fills exactly one page.

    Bit-exactness contract with the dense chunk path: the slot view is
    materialized by gathering `pool[pt]` into the same `[C, w]` stripe the
    dense kernel slices, the identical insert/attend math runs on it, and
    the stripe is scattered back page by page. Unmapped blocks point at the
    reserved scratch page 0: those columns sit strictly above the causal
    frontier (blocks are mapped in cursor order), so the softmax masks them
    to exact zeros — whatever finite garbage scratch holds. Untouched
    blocks scatter back the bits they gathered, so shared (copy-on-write)
    pages are rewritten bit-identically — benign for prefix sharing.
    """
    C, hd = cfg.ctx, cfg.head_dim
    K = chunk
    nb = C // K

    def attn(h, ln, wq, wk, wv, wo, kpool, vpool, pt, off, valid):
        """h: [K, D]; pools: [P, K, w]; pt: i32 [nb]; off/valid: scalar i32
        -> (partial [K, D], kpool', vpool')."""
        w = wq.shape[1]
        nh = w // hd
        xn = _norm(h, ln, impl)
        q = (xn @ wq).reshape(K, nh, hd)
        k = (xn @ wk).reshape(K, nh, hd)
        v = (xn @ wv).reshape(K, nh, hd)
        posv = jnp.arange(K, dtype=jnp.int32) + off
        cos, sin = ref.rope_angles(posv, hd, cfg.rope_theta)
        qr = ref.apply_rope(q, cos[:, None, :], sin[:, None, :])
        kr = ref.apply_rope(k, cos[:, None, :], sin[:, None, :])
        kslot = kpool[pt].reshape(C, w)          # gather the slot view
        vslot = vpool[pt].reshape(C, w)
        rows = jnp.arange(K, dtype=jnp.int32)[:, None]
        ins_k = jnp.where(rows < valid, kr.reshape(K, w),
                          jax.lax.dynamic_slice(kslot, (off, 0), (K, w)))
        ins_v = jnp.where(rows < valid, v.reshape(K, w),
                          jax.lax.dynamic_slice(vslot, (off, 0), (K, w)))
        kslot = jax.lax.dynamic_update_slice(kslot, ins_k, (off, 0))
        vslot = jax.lax.dynamic_update_slice(vslot, ins_v, (off, 0))
        if impl == "pallas":
            att = pl_chunk(qr, kslot.reshape(C, nh, hd),
                           vslot.reshape(C, nh, hd), off)
        else:
            att = ref.chunk_attention(qr, kslot.reshape(C, nh, hd),
                                      vslot.reshape(C, nh, hd), off)
        part = att.reshape(K, w) @ wo
        kp2 = kpool.at[pt].set(kslot.reshape(nb, K, w))
        vp2 = vpool.at[pt].set(vslot.reshape(nb, K, w))
        return part, kp2, vp2
    return attn


def make_shard_ffn(cfg: ModelConfig, impl="pallas"):
    def ffn(h, ln, wg, wu, wd):
        """TP/LP FFN shard partial: h [T,D], wg/wu [D,fw], wd [fw,D]."""
        return (ffn_delta(cfg, h, ln, wg, wu, wd, impl),)
    return ffn


def _decode_step_one(cfg: ModelConfig, impl: str):
    """Per-lane cached-attention decode step, shared by the full-[S] and
    batch-bucketed decode makers so both lower the *same* per-lane HLO —
    the bit-exactness contract between `decode_step` and the bucketed path
    in the rust serving executor."""
    C, hd = cfg.ctx, cfg.head_dim

    def step_one(x, ln, wq, wk, wv, wo, kc, vc, pos):
        """One slot. x: [D]; kc/vc: [C, w]; pos: scalar i32 (current index)."""
        w = wq.shape[1]
        nh = w // hd
        xn = _norm(x[None, :], ln, impl)[0]
        q = (xn @ wq).reshape(nh, hd)
        k = (xn @ wk).reshape(nh, hd)
        v = (xn @ wv).reshape(nh, hd)
        cos, sin = ref.rope_angles(pos, hd, cfg.rope_theta)
        qr = ref.apply_rope(q, cos[None, :], sin[None, :])
        kr = ref.apply_rope(k, cos[None, :], sin[None, :])
        kc2 = jax.lax.dynamic_update_slice(kc, kr.reshape(1, w), (pos, 0))
        vc2 = jax.lax.dynamic_update_slice(vc, v.reshape(1, w), (pos, 0))
        if impl == "pallas":
            att = pl_cached(qr, kc2.reshape(C, nh, hd), vc2.reshape(C, nh, hd), pos)
        else:
            att = ref.cached_attention(qr, kc2.reshape(C, nh, hd),
                                       vc2.reshape(C, nh, hd), pos)
        return att.reshape(w) @ wo, kc2, vc2

    return step_one


def make_shard_attn_decode(cfg: ModelConfig, impl="pallas"):
    S = cfg.slots
    step_one = _decode_step_one(cfg, impl)

    def attn(x, ln, wq, wk, wv, wo, kcache, vcache, pos):
        """All S slots. x: [S,D]; caches: [S,C,w]; pos: i32 [S].

        Slots are independent sequences (continuous batching); inactive
        slots simply carry pos of their last real token and are ignored by
        the coordinator.
        """
        parts, kcs, vcs = [], [], []
        for s in range(S):          # static unroll; S is small
            part, kc2, vc2 = step_one(x[s], ln, wq, wk, wv, wo,
                                      kcache[s], vcache[s], pos[s])
            parts.append(part)
            kcs.append(kc2)
            vcs.append(vc2)
        return (jnp.stack(parts), jnp.stack(kcs), jnp.stack(vcs))
    return attn


def make_shard_attn_decode_bucket(cfg: ModelConfig, impl="pallas", b=1):
    """Batch-bucketed decode attention: B compute lanes over the full [S]
    KV cache. Lane i serves slot `lanes[i]` — its cache row is gathered,
    stepped with the shared per-lane kernel, and scattered back — so device
    compute (and the partial handed to the all-reduce) scales with B, not S.

    Padded lanes duplicate a live lane (the rust coordinator repeats lane
    0): the scatter loop is sequential and a duplicate recomputes the same
    per-lane step from identical inputs, so it rewrites the same cache row
    with identical bits — benign whatever the other slots hold. Lanes
    addressing a free slot are equally safe (the next prefill's
    cache_insert overwrites the whole row).
    """
    step_one = _decode_step_one(cfg, impl)

    def attn(x, ln, wq, wk, wv, wo, kcache, vcache, pos, lanes):
        """x: [B,D]; caches: [S,C,w]; pos, lanes: i32 [B]."""
        parts = []
        kc, vc = kcache, vcache
        for i in range(b):          # static unroll; B is small
            lane = lanes[i]
            part, kc2, vc2 = step_one(x[i], ln, wq, wk, wv, wo,
                                      kc[lane], vc[lane], pos[i])
            parts.append(part)
            kc = jax.lax.dynamic_update_slice(kc, kc2[None], (lane, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, vc2[None], (lane, 0, 0))
        return (jnp.stack(parts), kc, vc)
    return attn


def make_shard_attn_decode_paged_bucket(cfg: ModelConfig, impl="pallas", b=1,
                                        page=32):
    """Paged-KV batch-bucketed decode attention: lane i's cache row is
    assembled by gathering its page table `pt[i]` out of the shared pool,
    stepped with the *same* per-lane kernel as the dense bucketed path
    (`_decode_step_one` — the bit-exactness contract), and scattered back
    page by page.

    Pages the step did not touch scatter back the bits they gathered, so a
    prefix page shared by several lanes (copy-on-write forks) is rewritten
    bit-identically by each — the sequential loop makes that an idempotent
    rewrite, the same argument that makes padded duplicate lanes benign in
    the dense bucketed kernel. The freshly written row `pos` always lands in
    a private page (the runtime only shares fully-frozen prefix blocks).
    """
    C, hd = cfg.ctx, cfg.head_dim
    nb = C // page
    step_one = _decode_step_one(cfg, impl)

    def attn(x, ln, wq, wk, wv, wo, kpool, vpool, pos, pt):
        """x: [B,D]; pools: [P, page, w]; pos: i32 [B]; pt: i32 [B, nb]."""
        w = wq.shape[1]
        parts = []
        kp, vp = kpool, vpool
        for i in range(b):          # static unroll; B is small
            t = pt[i]
            kc = kp[t].reshape(C, w)
            vc = vp[t].reshape(C, w)
            part, kc2, vc2 = step_one(x[i], ln, wq, wk, wv, wo, kc, vc,
                                      pos[i])
            parts.append(part)
            kp = kp.at[t].set(kc2.reshape(nb, page, w))
            vp = vp.at[t].set(vc2.reshape(nb, page, w))
        return (jnp.stack(parts), kp, vp)
    return attn


def make_shard_ffn_decode(cfg: ModelConfig, impl="pallas"):
    def ffn(x, ln, wg, wu, wd):
        """x: [S, D] -> partial [S, D]."""
        return (ffn_delta(cfg, x, ln, wg, wu, wd, impl),)
    return ffn


def make_cache_insert(cfg: ModelConfig):
    def insert(cache, stripe, slot):
        """Write a prefill K/V stripe into a cache slot.

        cache: [S, C, w]; stripe: [T, w]; slot: scalar i32 -> cache'.
        """
        t, w = stripe.shape
        padded = jnp.zeros((cfg.ctx, w), jnp.float32).at[:t].set(stripe)
        return (jax.lax.dynamic_update_slice(cache, padded[None], (slot, 0, 0)),)
    return insert


def make_embed_decode(cfg: ModelConfig):
    def embed(tokens, emb):
        """tokens: i32 [S] -> x [S, D]."""
        return (emb[tokens],)
    return embed


def make_logits_decode(cfg: ModelConfig, impl="pallas"):
    def logits(x, lnf, wout):
        """x: [S, D] -> logits [S, V]."""
        return (_norm(x, lnf, impl) @ wout,)
    return logits


def make_lp_fused_attn(cfg: ModelConfig, impl="pallas"):
    """Single-device fused LP pair attention (ablation abl2 — paper §4's
    'naive fusion on one GPU yields no gain'): both layers' Q/K/V come from
    ONE widened matmul [T,D]x[D,6D] and one flash_attention call over 2·H
    heads; the two output projections are similarly concatenated."""
    def attn(h, ln_a, ln_b, wqkv2, wo2):
        """h: [T,D]; wqkv2: [D, 6D] (qa|ka|va|qb|kb|vb); wo2: [2D, D]."""
        t = h.shape[0]
        d, hd = cfg.d_model, cfg.head_dim
        nh = cfg.n_heads
        xna = _norm(h, ln_a, impl)
        xnb = _norm(h, ln_b, impl)
        # widened projection: one MXU pass over the concatenated weights
        qkv_a = xna @ wqkv2[:, : 3 * d]
        qkv_b = xnb @ wqkv2[:, 3 * d:]
        def split(qkv):
            q = qkv[:, :d].reshape(t, nh, hd)
            k = qkv[:, d:2 * d].reshape(t, nh, hd)
            v = qkv[:, 2 * d:].reshape(t, nh, hd)
            return q, k, v
        qa, ka, va = split(qkv_a)
        qb, kb, vb = split(qkv_b)
        posv = jnp.arange(t, dtype=jnp.int32)
        cos, sin = ref.rope_angles(posv, hd, cfg.rope_theta)
        def rope(x):
            return ref.apply_rope(x, cos[:, None, :], sin[:, None, :])
        q2 = jnp.concatenate([rope(qa), rope(qb)], axis=1)   # [T, 2H, hd]
        k2 = jnp.concatenate([rope(ka), rope(kb)], axis=1)
        v2 = jnp.concatenate([va, vb], axis=1)
        att = _attention(q2, k2, v2, impl).reshape(t, 2 * d)
        return (att @ wo2,)
    return attn
