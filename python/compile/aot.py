"""AOT pipeline: lower every inference entrypoint to HLO *text* + manifest.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla_extension 0.5.1 bundled
with the `xla` rust crate rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts` (no-op when inputs are unchanged — content hash in
the manifest) or directly:

    cd python && python -m compile.aot --out ../artifacts [--force] [--impl pallas]

Artifact inventory (per model, T ∈ SEQ_BUCKETS, S slots, C ctx, w ∈ {D/2, D}):

  scoring (single device, full width — composed per-layer by rust):
    embed_t{T}, attn_t{T}, ffn_t{T}, logits_t{T}
  serving prefill shards (monolithic fixed-T path — the bit-exactness
  oracle for the chunked protocol, and the legacy-manifest fallback):
    tpattn_prefill_t{T} (w=D/2), tpffn_prefill_t{T} (fw=F/2),
    lpattn_prefill_t{T} (w=D)   [LP FFN prefill reuses ffn_t{T}]
  chunked streaming prefill (K = PREFILL_CHUNK tokens per step at a
  position offset against the live [S,C,w] caches; fresh K/V rows are
  inserted in the same pass, masked by the true valid length — see
  rust model::prefill for the runtime half):
    {tp|lp}attn_chunk (h [K,D] + caches + slot/off/valid scalars),
    {tp|lp}ffn_chunk, embed_chunk, logits_chunk
  serving decode shards (KV caches in/out as PJRT buffers):
    tpattn_decode, tpffn_decode, lpattn_decode, lpffn_decode
  batch-bucketed decode shards (B ∈ batch_buckets(S) = {1, 2, 4, …, S};
  occupancy-proportional dispatch — see rust runtime::buckets):
    {tp|lp}attn_decode_b{B} (full [S,C,w] caches + i32 lanes[B] gather/
    scatter), {tp|lp}ffn_decode_b{B}, embed_decode_b{B}, logits_decode_b{B}
    (B = S duplicates the fixed-shape non-attention entrypoints; accepted
    so every bucket carries the same uniform six-key set)
  paged-KV variants (opt-in at runtime; K/V in shared per-width page pools
  [P, page, w] indexed through i32 page tables instead of dense [S,C,w]
  slot caches — see rust model::kvcache for the allocator half):
    {tp|lp}attn_chunk_paged (pt[nb] replaces the slot scalar),
    {tp|lp}attn_decode_paged_b{B} (pt[B,nb] replaces lanes[B])
  cache plumbing: cache_insert_{half|full}_t{T}, embed_decode, logits_decode
  ablation: lpfused_attn_t128 (single-device fused dual-layer attention)

The manifest carries a per-model "batch_buckets" list naming the compiled
B values (the rust BucketSet keys the per-bucket executables off it), a
top-level "prefill_chunk" giving the chunk token count K, and a per-model
"kv_pages" section (modelcfg.kv_pages: page_tokens, blocks_per_slot and
the per-width pool page counts the paged executables were lowered
against); manifests predating any section fall back to the dense
fixed-shape paths.

Plan-variant registry: the per-model "variants" section names the serving
tiers one weight set supports (`dense`, `lp`, `lp_aggr` — see
modelcfg.plan_variants). Each variant is a stage list ([i] = TP-sharded
layer, [a, b] = LP pair); no extra executables are emitted because every
stage executable above is plan-agnostic — variants only select which
stages the rust runtime walks (runtime::artifacts parses the section,
model::serving serves all tiers concurrently from one resident weight
set). Manifests predating the section serve a single synthesized `dense`
tier.
"""

from __future__ import annotations

import argparse
import hashlib
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .modelcfg import (
    CONFIGS,
    PREFILL_CHUNK,
    SEQ_BUCKETS,
    ModelConfig,
    batch_buckets,
    kv_pages,
    plan_variants,
)

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(fn, arg_specs) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_specs(cfg: ModelConfig, impl: str) -> dict[str, tuple]:
    """name -> (fn, [arg ShapeDtypeStructs], [human arg names])."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    s, c = cfg.slots, cfg.ctx
    dh, fh = d // 2, f // 2
    arts: dict[str, tuple] = {}

    for t in SEQ_BUCKETS:
        arts[f"embed_t{t}"] = (
            M.make_embed(cfg),
            [spec([t], I32), spec([v, d])],
            ["tokens", "emb"],
        )
        arts[f"attn_t{t}"] = (
            M.make_attn_delta(cfg, impl),
            [spec([t, d]), spec([d]), spec([d, d]), spec([d, d]),
             spec([d, d]), spec([d, d])],
            ["h", "ln1", "wq", "wk", "wv", "wo"],
        )
        arts[f"ffn_t{t}"] = (
            M.make_ffn_delta(cfg, impl),
            [spec([t, d]), spec([d]), spec([d, f]), spec([d, f]), spec([f, d])],
            ["h", "ln2", "wg", "wu", "wd"],
        )
        arts[f"logits_t{t}"] = (
            M.make_logits(cfg, impl),
            [spec([t, d]), spec([d]), spec([d, v])],
            ["h", "lnf", "wout"],
        )
        arts[f"tpattn_prefill_t{t}"] = (
            M.make_shard_attn_prefill(cfg, impl),
            [spec([t, d]), spec([d]), spec([d, dh]), spec([d, dh]),
             spec([d, dh]), spec([dh, d])],
            ["h", "ln1", "wq_sh", "wk_sh", "wv_sh", "wo_sh"],
        )
        arts[f"tpffn_prefill_t{t}"] = (
            M.make_shard_ffn(cfg, impl),
            [spec([t, d]), spec([d]), spec([d, fh]), spec([d, fh]), spec([fh, d])],
            ["h", "ln2", "wg_sh", "wu_sh", "wd_sh"],
        )
        arts[f"lpattn_prefill_t{t}"] = (
            M.make_shard_attn_prefill(cfg, impl),
            [spec([t, d]), spec([d]), spec([d, d]), spec([d, d]),
             spec([d, d]), spec([d, d])],
            ["h", "ln1", "wq", "wk", "wv", "wo"],
        )
        for wname, w in (("half", dh), ("full", d)):
            arts[f"cache_insert_{wname}_t{t}"] = (
                M.make_cache_insert(cfg),
                [spec([s, c, w]), spec([t, w]), spec([], I32)],
                ["cache", "stripe", "slot"],
            )

    for mode, w, fw in (("tp", dh, fh), ("lp", d, f)):
        arts[f"{mode}attn_decode"] = (
            M.make_shard_attn_decode(cfg, impl),
            [spec([s, d]), spec([d]), spec([d, w]), spec([d, w]),
             spec([d, w]), spec([w, d]), spec([s, c, w]), spec([s, c, w]),
             spec([s], I32)],
            ["x", "ln1", "wq", "wk", "wv", "wo", "kcache", "vcache", "pos"],
        )
        arts[f"{mode}ffn_decode"] = (
            M.make_shard_ffn_decode(cfg, impl),
            [spec([s, d]), spec([d]), spec([d, fw]), spec([d, fw]), spec([fw, d])],
            ["x", "ln2", "wg", "wu", "wd"],
        )

    # Batch-bucketed decode: one executable set per B ∈ batch_buckets(S).
    # Attention carries the full [S, C, w] caches plus a lanes[B] mapping
    # (gather row, step, scatter back); embed/ffn/logits are simply the
    # same entrypoints lowered at batch shape B.
    for b in batch_buckets(s):
        for mode, w, fw in (("tp", dh, fh), ("lp", d, f)):
            arts[f"{mode}attn_decode_b{b}"] = (
                M.make_shard_attn_decode_bucket(cfg, impl, b),
                [spec([b, d]), spec([d]), spec([d, w]), spec([d, w]),
                 spec([d, w]), spec([w, d]), spec([s, c, w]), spec([s, c, w]),
                 spec([b], I32), spec([b], I32)],
                ["x", "ln1", "wq", "wk", "wv", "wo", "kcache", "vcache",
                 "pos", "lanes"],
            )
            arts[f"{mode}ffn_decode_b{b}"] = (
                M.make_shard_ffn_decode(cfg, impl),
                [spec([b, d]), spec([d]), spec([d, fw]), spec([d, fw]),
                 spec([fw, d])],
                ["x", "ln2", "wg", "wu", "wd"],
            )
        arts[f"embed_decode_b{b}"] = (
            M.make_embed_decode(cfg),
            [spec([b], I32), spec([v, d])],
            ["tokens", "emb"],
        )
        arts[f"logits_decode_b{b}"] = (
            M.make_logits_decode(cfg, impl),
            [spec([b, d]), spec([d]), spec([d, v])],
            ["x", "lnf", "wout"],
        )

    # Paged-KV attention variants: K/V in one shared page pool per cache
    # width ([P, page, w], resident per rank) instead of dense [S, C, w]
    # slot caches; the i32 page-table operand replaces the dense paths'
    # slot/lanes indexing. Pool page counts come from modelcfg.kv_pages
    # (dense-equivalent worst case + the reserved scratch page 0) and are
    # recorded in the manifest's kv_pages section, which the rust runtime
    # validates against these lowered shapes.
    kvp = kv_pages(cfg)
    page, nb = kvp["page_tokens"], kvp["blocks_per_slot"]
    for mode, w, pp in (("tp", dh, kvp["pool_pages_half"]),
                        ("lp", d, kvp["pool_pages_full"])):
        arts[f"{mode}attn_chunk_paged"] = (
            M.make_shard_attn_chunk_paged(cfg, impl, PREFILL_CHUNK),
            [spec([PREFILL_CHUNK, d]), spec([d]), spec([d, w]), spec([d, w]),
             spec([d, w]), spec([w, d]), spec([pp, page, w]),
             spec([pp, page, w]), spec([nb], I32), spec([], I32),
             spec([], I32)],
            ["h", "ln1", "wq", "wk", "wv", "wo", "kpool", "vpool",
             "pt", "off", "valid"],
        )
        for b in batch_buckets(s):
            arts[f"{mode}attn_decode_paged_b{b}"] = (
                M.make_shard_attn_decode_paged_bucket(cfg, impl, b, page),
                [spec([b, d]), spec([d]), spec([d, w]), spec([d, w]),
                 spec([d, w]), spec([w, d]), spec([pp, page, w]),
                 spec([pp, page, w]), spec([b], I32), spec([b, nb], I32)],
                ["x", "ln1", "wq", "wk", "wv", "wo", "kpool", "vpool",
                 "pos", "pt"],
            )

    # Chunked streaming prefill: one fixed-[K] executable per stage kind,
    # consuming K tokens at offset `off` against the live [S, C, w] caches.
    # Attention inserts this chunk's K/V rows itself (masked by `valid` so
    # the PAD tail of a final partial chunk never lands in the cache) and
    # attends over the cache prefix — the resumable-prefill contract of
    # rust model::prefill.
    k_ = PREFILL_CHUNK
    assert c % k_ == 0, f"ctx {c} must be a multiple of PREFILL_CHUNK {k_}"
    for mode, w, fw in (("tp", dh, fh), ("lp", d, f)):
        arts[f"{mode}attn_chunk"] = (
            M.make_shard_attn_chunk(cfg, impl, k_),
            [spec([k_, d]), spec([d]), spec([d, w]), spec([d, w]),
             spec([d, w]), spec([w, d]), spec([s, c, w]), spec([s, c, w]),
             spec([], I32), spec([], I32), spec([], I32)],
            ["h", "ln1", "wq", "wk", "wv", "wo", "kcache", "vcache",
             "slot", "off", "valid"],
        )
        arts[f"{mode}ffn_chunk"] = (
            M.make_shard_ffn(cfg, impl),
            [spec([k_, d]), spec([d]), spec([d, fw]), spec([d, fw]),
             spec([fw, d])],
            ["h", "ln2", "wg", "wu", "wd"],
        )
    arts["embed_chunk"] = (
        M.make_embed(cfg),
        [spec([k_], I32), spec([v, d])],
        ["tokens", "emb"],
    )
    arts["logits_chunk"] = (
        M.make_logits(cfg, impl),
        [spec([k_, d]), spec([d]), spec([d, v])],
        ["h", "lnf", "wout"],
    )

    arts["embed_decode"] = (
        M.make_embed_decode(cfg),
        [spec([s], I32), spec([v, d])],
        ["tokens", "emb"],
    )
    arts["logits_decode"] = (
        M.make_logits_decode(cfg, impl),
        [spec([s, d]), spec([d]), spec([d, v])],
        ["x", "lnf", "wout"],
    )
    arts["lpfused_attn_t128"] = (
        M.make_lp_fused_attn(cfg, impl),
        [spec([128, d]), spec([d]), spec([d]), spec([d, 6 * d]), spec([2 * d, d])],
        ["h", "ln_a", "ln_b", "wqkv2", "wo2"],
    )
    return arts


def _source_hash(impl: str) -> str:
    h = hashlib.sha256()
    pkg = Path(__file__).parent
    for p in sorted(list(pkg.glob("*.py")) + list((pkg / "kernels").glob("*.py"))):
        h.update(p.read_bytes())
    h.update(impl.encode())
    h.update(json.dumps({k: v.to_dict() for k, v in CONFIGS.items()}).encode())
    return h.hexdigest()


def build(out_dir: Path, impl: str = "pallas", force: bool = False,
          models: list[str] | None = None) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    src_hash = _source_hash(impl)
    if manifest_path.exists() and not force:
        old = json.loads(manifest_path.read_text())
        if old.get("source_hash") == src_hash:
            print(f"artifacts up to date ({src_hash[:12]}) — skipping")
            return

    manifest = {
        "format": 1,
        "source_hash": src_hash,
        "impl": impl,
        "seq_buckets": list(SEQ_BUCKETS),
        "prefill_chunk": PREFILL_CHUNK,
        "models": {},
    }
    for name, cfg in CONFIGS.items():
        if models and name not in models:
            continue
        mdir = out_dir / name
        mdir.mkdir(exist_ok=True)
        arts = artifact_specs(cfg, impl)
        entry = {
            "config": cfg.to_dict(),
            "batch_buckets": list(batch_buckets(cfg.slots)),
            "kv_pages": kv_pages(cfg),
            "variants": {
                vname: {"stages": stages}
                for vname, stages in plan_variants(cfg).items()
            },
            "artifacts": {},
        }
        for aname, (fn, arg_specs, arg_names) in arts.items():
            text = to_hlo_text(fn, arg_specs)
            rel = f"{name}/{aname}.hlo.txt"
            (out_dir / rel).write_text(text)
            entry["artifacts"][aname] = {
                "file": rel,
                "args": [
                    {"name": n, "dtype": str(sp.dtype), "shape": list(sp.shape)}
                    for n, sp in zip(arg_names, arg_specs)
                ],
            }
            print(f"  {name}/{aname}: {len(text)} chars")
        manifest["models"][name] = entry
    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"wrote {manifest_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--impl", default="pallas", choices=["pallas", "jnp"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()
    build(Path(args.out), args.impl, args.force, args.models)


if __name__ == "__main__":
    main()
