"""Weight-store I/O: the `.tdw` exchange format (python writer, rust reader).

Layout (all little-endian):
  magic   4 bytes  b"TDW1"
  count   u32      number of tensors
  per tensor:
    name_len u16, name utf-8,
    dtype    u8   (0 = f32, 1 = i32),
    ndim     u8, dims u32 × ndim,
    nbytes   u64, raw data (row-major, LE)

Tensor names: "emb", "lnf", "wout", "layers.<i>.<ln1|wq|wk|wv|wo|ln2|wg|wu|wd>".
Mirrored by rust/src/model/weights.rs (reader + tests on a golden file).
"""

from __future__ import annotations

import json
import struct
from pathlib import Path

import numpy as np

from .modelcfg import ModelConfig

MAGIC = b"TDW1"
DTYPES = {np.dtype("float32"): 0, np.dtype("int32"): 1}
DTYPES_INV = {0: np.dtype("float32"), 1: np.dtype("int32")}


def flatten_params(params: dict) -> dict[str, np.ndarray]:
    """Model pytree -> flat name->array dict (the .tdw tensor set)."""
    out: dict[str, np.ndarray] = {}
    for k, v in params.items():
        if k == "layers":
            for i, layer in enumerate(v):
                for n, a in layer.items():
                    out[f"layers.{i}.{n}"] = np.asarray(a)
        else:
            out[k] = np.asarray(v)
    return out


def unflatten_params(flat: dict[str, np.ndarray], n_layers: int) -> dict:
    layers = [dict() for _ in range(n_layers)]
    out: dict = {"layers": layers}
    for name, arr in flat.items():
        if name.startswith("layers."):
            _, idx, field = name.split(".")
            layers[int(idx)][field] = arr
        else:
            out[name] = arr
    return out


def save_tdw(path: str | Path, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in sorted(tensors.items()):
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load_tdw(path: str | Path) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        out = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            (nbytes,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(nbytes), dtype=DTYPES_INV[dt])
            out[name] = arr.reshape(dims)
        return out


def save_checkpoint(ckpt_dir: str | Path, cfg: ModelConfig, params: dict,
                    meta: dict | None = None) -> None:
    """weights.tdw + config.json under ckpt_dir."""
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    save_tdw(d / "weights.tdw", flatten_params(params))
    blob = {"model": cfg.to_dict()}
    if meta:
        blob["meta"] = meta
    (d / "config.json").write_text(json.dumps(blob, indent=2))


def load_checkpoint(ckpt_dir: str | Path, cfg: ModelConfig) -> dict:
    flat = load_tdw(Path(ckpt_dir) / "weights.tdw")
    return unflatten_params(flat, cfg.n_layers)
