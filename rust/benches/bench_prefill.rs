//! Prefill latency across sequence buckets, TP vs LP (Fig. 7 prefill task),
//! a prompt-length sweep of the chunked streaming prefill (modelled flops
//! must scale with ceil(L / chunk) rather than the covering bucket T),
//! plus the abl2 single-device fused-pair kernel ablation (paper §4: naive
//! fusion on one device yields no meaningful gain — the win is in the sync
//! count, not the kernel).

use truedepth::bench::Bench;
use truedepth::cli::Args;
use truedepth::config::ServerConfig;
use truedepth::api::CompletionRequest;
use truedepth::coordinator::Server;
use truedepth::harness::{default_net, no_net};
use truedepth::model::{transform, ServingModel, Weights};
use truedepth::obs::{MetricsSnapshot, Tracer};
use truedepth::runtime::pjrt::HostValue;
use truedepth::runtime::{Engine, Manifest};

fn main() {
    // cargo passes `--bench` to harness-less bench binaries; accept it as
    // a flag. --trace-out / --metrics-out override the default export
    // paths under target/bench-reports.
    let args = Args::from_env(&["bench"]);
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("bench_prefill: artifacts missing (run `make artifacts`) — skipping");
        return;
    };
    let entry = manifest.model("td-small").expect("td-small");
    let cfg = entry.config.clone();
    let weights = Weights::random(&cfg, 17);
    let n = cfg.n_layers;

    let mut b = Bench::new("bench_prefill");
    for (plan_name, plan) in [
        ("tp_seq", transform::sequential(n)),
        ("lp_d8", transform::pair_parallel(n, 2, 10, true)),
    ] {
        let serving =
            ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
        for t in [32usize, 128, 224] {
            let prompt: Vec<i32> = (0..t as i32).map(|i| 97 + (i % 26)).collect();
            serving.prefill(0, &prompt).unwrap(); // warm
            b.bench_timed(&format!("prefill_{plan_name}_T{t}"), 8, || {
                let t0 = std::time::Instant::now();
                serving.prefill(0, &prompt).unwrap();
                t0.elapsed()
            });
            // resident pipeline: prefill host traffic is O(1) in depth too
            serving.mesh.metrics.reset();
            serving.prefill(0, &prompt).unwrap();
            let h = serving.mesh.metrics.host_transfers();
            println!(
                "   host transfers/prefill [{plan_name}_T{t}]: {} ops ({} KiB)",
                h.ops(),
                h.bytes() / 1024,
            );
        }
    }

    // Prompt-length sweep: the chunked streaming protocol bills modelled
    // compute for the ceil(L / K) chunks actually run; the monolithic path
    // pays the covering bucket T (plus its full [T, V] logits block). The
    // two are bit-identical in output — only the cost scales differently.
    // Wall-clock samples stay on the no_net model (pure executor time);
    // the deterministic modelled metrics (modelled prefill time ∝
    // ceil(L / K), modelled TTFT) come from a default_net twin so the
    // timeline includes the α–β term — the figures the CI perf gate
    // compares against rust/bench-baseline.json.
    {
        let plan = transform::pair_parallel(n, 2, 10, true);
        let serving =
            ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
        let sim =
            ServingModel::new(&manifest, "td-small", &weights, &plan, default_net()).unwrap();
        match serving.prefill_chunk() {
            None => eprintln!("   (no prefill_chunk in manifest — sweep skipped)"),
            Some(k) => {
                println!("   prompt-length sweep (chunk K={k}):");
                for l in [8usize, 33, 77, 150, 224] {
                    let prompt: Vec<i32> = (0..l as i32).map(|i| 97 + (i % 26)).collect();
                    sim.mesh.metrics.reset();
                    sim.prefill(0, &prompt).unwrap();
                    let mono = sim.mesh.metrics.modelled_flops();
                    sim.mesh.metrics.reset();
                    sim.prefill_chunked(0, &prompt).unwrap();
                    let chunked = sim.mesh.metrics.modelled_flops();
                    let prefill_ms = sim.mesh.metrics.modelled_total_ms();
                    let payload = sim.mesh.metrics.sync_bytes();
                    let chunks = l.div_ceil(k);
                    println!(
                        "     L={l:>3}: monolithic {:>7.2} Mflop (bucket pad) vs chunked {:>7.2} Mflop ({chunks} chunks, x{:.2}) — {prefill_ms:.3} ms modelled",
                        mono as f64 / 1e6,
                        chunked as f64 / 1e6,
                        mono as f64 / chunked as f64,
                    );
                    b.metric(&format!("modelled_prefill_ms_L{l}"), prefill_ms);
                    b.metric(&format!("prefill_chunks_L{l}"), chunks as f64);
                    b.metric(&format!("prefill_mflop_L{l}"), chunked as f64 / 1e6);
                    b.metric(
                        &format!("prefill_allreduce_bytes_L{l}"),
                        payload as f64,
                    );
                    b.bench_timed(&format!("prefill_chunked_L{l}"), 8, || {
                        let t0 = std::time::Instant::now();
                        serving.prefill_chunked(0, &prompt).unwrap();
                        t0.elapsed()
                    });
                }

                // observability export: one traced L=224 chunked prefill
                // on the simulated clock (ceil(224/K) chunk dispatches +
                // their collectives on the mesh track). Lands next to the
                // bench report so CI uploads it; --trace-out /
                // --metrics-out override (README "Observability").
                let reports = truedepth::repo_root().join("target/bench-reports");
                let trace_path = args
                    .get("trace-out")
                    .map(std::path::PathBuf::from)
                    .unwrap_or_else(|| reports.join("bench_prefill.trace.json"));
                let snap_path = args
                    .get("metrics-out")
                    .map(std::path::PathBuf::from)
                    .unwrap_or_else(|| reports.join("bench_prefill.metrics.json"));
                let prompt: Vec<i32> = (0..224).map(|i| 97 + (i % 26)).collect();
                let tracer = Tracer::new();
                sim.mesh.metrics.reset();
                sim.mesh.begin_trace();
                sim.prefill_chunked(0, &prompt).unwrap();
                tracer.record_mesh_events(sim.mesh.take_timed_trace());
                tracer.write_chrome(&trace_path).unwrap();
                MetricsSnapshot::new("bench_prefill")
                    .with_mesh(&sim.mesh.metrics)
                    .write(&snap_path)
                    .unwrap();
                println!(
                    "   trace: {} ({} events); metrics snapshot: {}",
                    trace_path.display(),
                    tracer.len(),
                    snap_path.display(),
                );
            }
        }
    }

    // Paged shared-prefix reuse gate: two slots prefill the SAME 77-token
    // prompt under the paged KV cache. The leader pays the full 3-chunk
    // walk; the follower attaches the two shareable blocks from the prefix
    // index and bills only the final chunk — the modelled-flop delta is the
    // prefix prefill charged exactly once, gated against bench-baseline.
    {
        let plan = transform::pair_parallel(n, 2, 10, true);
        let mut paged =
            ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
        if entry.kv_pages.is_none() || paged.prefill_chunk().is_none() {
            eprintln!("   (no kv_pages in manifest — paged prefix-reuse section skipped)");
        } else {
            paged.enable_paging().unwrap();
            let prompt: Vec<i32> = (0..77).map(|i| 97 + (i % 26)).collect();
            paged.mesh.metrics.reset();
            paged.prefill_chunked(0, &prompt).unwrap();
            let lead = paged.mesh.metrics.modelled_flops();
            paged.mesh.metrics.reset();
            paged.prefill_chunked(1, &prompt).unwrap();
            let follow = paged.mesh.metrics.modelled_flops();
            let ks = paged.kv_stats().expect("paging enabled");
            assert!(follow < lead, "prefix reuse must be cheaper than the full walk");
            assert_eq!(ks.prefix_hits, 1, "follower must hit the prefix index");
            println!(
                "   paged prefix reuse (2x L=77): leader {:.2} Mflop, follower {:.2} Mflop — {} tokens shared, {:.2} Mflop saved",
                lead as f64 / 1e6,
                follow as f64 / 1e6,
                ks.prefix_shared_tokens,
                (lead - follow) as f64 / 1e6,
            );
            b.metric("prefix_shared_tokens_2x77", ks.prefix_shared_tokens as f64);
            b.metric("prefix_saved_mflop_2x77", (lead - follow) as f64 / 1e6);
            b.metric("prefix_follower_mflop_2x77", follow as f64 / 1e6);
        }
    }

    // End-to-end scheduler-attribution gate: one request through the real
    // Server/Scheduler over a default_net model. On an idle server the
    // first token samples from the FINAL prefill chunk's logits, so the
    // scheduler's modelled TTFT (admission → first token on the simulated
    // clock) must equal the 77-token chunked prefill cost, and its
    // modelled decode throughput must match the B = 1 bucketed round —
    // gating the attribution path itself, not just the raw cost formulas.
    {
        let plan = transform::pair_parallel(n, 2, 10, true);
        let sim =
            ServingModel::new(&manifest, "td-small", &weights, &plan, default_net()).unwrap();
        if sim.prefill_chunk().is_some() {
            let server = Server::start(sim, &ServerConfig::default());
            // BOS + 76 bytes = 77 prompt tokens (3 chunks of K = 32)
            let req = CompletionRequest::new("x".repeat(76)).max_tokens(4);
            let resp = server.request(req).unwrap().wait().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            let ttft = server.metrics.modelled_ttft_summary().unwrap().p50;
            let tps = server.metrics.modelled_decode_tok_per_s().unwrap();
            println!(
                "   scheduler attribution: modelled ttft {ttft:.3} ms, decode {tps:.1} tok/s"
            );
            b.metric("modelled_sched_ttft_ms_77tok", ttft);
            b.metric("modelled_sched_decode_tok_per_s", tps);
            server.shutdown();
        }
    }

    // abl2: fused dual-layer attention kernel vs two separate attn calls on
    // ONE device (no mesh, no all-reduce): expect ≈ no speedup.
    let engine = Engine::cpu().unwrap();
    let d = cfg.d_model;
    let attn = engine.load(&entry.artifact("attn_t128").unwrap().file).unwrap();
    let fused = engine.load(&entry.artifact("lpfused_attn_t128").unwrap().file).unwrap();
    let h = HostValue::f32(vec![128, d], vec![0.01; 128 * d]);
    let w = |r: usize, c: usize| HostValue::f32(vec![r, c], vec![0.02; r * c]);
    let ln = HostValue::f32(vec![d], vec![1.0; d]);
    let attn_args = [h.clone(), ln.clone(), w(d, d), w(d, d), w(d, d), w(d, d)];
    b.bench_timed("abl2_two_attn_calls_1dev", 8, || {
        let t0 = std::time::Instant::now();
        engine.call(&attn, &attn_args).unwrap();
        engine.call(&attn, &attn_args).unwrap();
        t0.elapsed()
    });
    let fused_args = [h, ln.clone(), ln, w(d, 6 * d), w(2 * d, d)];
    b.bench_timed("abl2_fused_dual_attn_1dev", 8, || {
        let t0 = std::time::Instant::now();
        engine.call(&fused, &fused_args).unwrap();
        t0.elapsed()
    });

    b.finish();
}
