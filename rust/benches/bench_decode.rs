//! Decode-step latency: vanilla TP vs Layer Parallelism, with and without
//! the interconnect cost model — the per-token numbers behind Fig. 7's
//! 1-token task and Table 3.
//!
//! Also reports the tentpole metric of the resident-activation pipeline:
//! host↔device transfers per decode token (O(1) — token ids + positions
//! in, embed shadow + logits out) against the pre-refactor host-round-trip
//! reference path (O(stages)).
//!
//! The occupancy sweep at the end shows shape-bucket dispatch at work: a
//! round with L live slots runs the smallest covering batch bucket, so
//! modelled device compute and the logits download scale with L (a
//! 1-live-slot round on an S-slot model dispatches B=1, not B=S).

use truedepth::bench::Bench;
use truedepth::cli::Args;
use truedepth::harness::{default_net, no_net};
use truedepth::model::{transform, ServingModel, Weights};
use truedepth::obs::{MetricsSnapshot, Tracer};
use truedepth::runtime::Manifest;

fn main() {
    // cargo passes `--bench` to harness-less bench binaries; accept it as
    // a flag. --trace-out / --metrics-out override the default export
    // paths under target/bench-reports.
    let args = Args::from_env(&["bench"]);
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("bench_decode: artifacts missing (run `make artifacts`) — skipping");
        return;
    };
    let entry = manifest.model("td-small").expect("td-small");
    let cfg = entry.config.clone();
    let weights = Weights::random(&cfg, 13);
    let n = cfg.n_layers;

    let mut b = Bench::new("bench_decode");
    for (net_name, net) in [("simnet", default_net()), ("nonet", no_net())] {
        for (plan_name, plan) in [
            ("tp_seq", transform::sequential(n)),
            ("lp_d8", transform::pair_parallel(n, 2, 10, true)),
            ("lp_full", transform::pair_parallel(n, 0, n, true)),
        ] {
            let serving =
                ServingModel::new(&manifest, "td-small", &weights, &plan, net.clone()).unwrap();
            let prompt: Vec<i32> = (0..64).map(|i| 97 + (i % 26)).collect();
            serving.prefill(0, &prompt).unwrap();
            let tok = vec![65i32; cfg.slots];
            let pos = vec![64i32; cfg.slots];
            for _ in 0..3 {
                serving.decode_step(&tok, &pos).unwrap();
            }
            b.bench_timed(
                &format!("decode_{plan_name}_{net_name} (depth {})", plan.effective_depth()),
                12,
                || {
                    let t = std::time::Instant::now();
                    serving.decode_step(&tok, &pos).unwrap();
                    t.elapsed()
                },
            );
            if net_name == "nonet" {
                b.bench_timed(
                    &format!("decode_{plan_name}_{net_name}_hostpath_ref"),
                    12,
                    || {
                        let t = std::time::Instant::now();
                        serving.decode_step_host_reference(&tok, &pos).unwrap();
                        t.elapsed()
                    },
                );
            }

            // host↔device transfers per token: resident vs reference
            serving.mesh.metrics.reset();
            serving.decode_step(&tok, &pos).unwrap();
            let res = serving.mesh.metrics.host_transfers();
            serving.mesh.metrics.reset();
            serving.decode_step_host_reference(&tok, &pos).unwrap();
            let refp = serving.mesh.metrics.host_transfers();
            println!(
                "   host transfers/token [{plan_name}_{net_name}]: resident {} ops ({} KiB) vs hostpath {} ops ({} KiB)",
                res.ops(),
                res.bytes() / 1024,
                refp.ops(),
                refp.bytes() / 1024,
            );
        }
    }

    // --- occupancy-proportional dispatch (shape buckets) -----------------
    // Two models: wall-clock samples stay on no_net (pure executor time,
    // comparable with earlier reports), while the deterministic modelled
    // metrics come from a default_net twin so the timeline includes the
    // α–β term — these are the numbers the CI perf gate compares against
    // rust/bench-baseline.json (see bin/perf_gate.rs).
    let plan = transform::pair_parallel(n, 2, 10, true);
    let serving = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
    let sim =
        ServingModel::new(&manifest, "td-small", &weights, &plan, default_net()).unwrap();
    let s = cfg.slots;
    let prompt: Vec<i32> = (0..16).map(|i| 97 + (i % 26)).collect();
    for slot in 0..s {
        serving.prefill(slot, &prompt).unwrap();
        sim.prefill(slot, &prompt).unwrap();
    }
    println!(
        "   shape buckets {:?} (slots {s}, {} flops/lane/token):",
        serving.bucket_set().buckets(),
        serving.decode_flops_per_lane(),
    );
    b.metric("decode_mflop_per_lane", serving.decode_flops_per_lane() as f64 / 1e6);
    for live in 1..=s {
        let active: Vec<_> = (0..live).map(|slot| (slot, 65i32, prompt.len() as i32)).collect();
        sim.mesh.metrics.reset();
        sim.decode_active(&active).unwrap();
        let flops = sim.mesh.metrics.modelled_flops();
        let out = sim.mesh.metrics.host_transfers().out_bytes;
        let round_ms = sim.mesh.metrics.modelled_total_ms();
        let payload = sim.mesh.metrics.sync_bytes();
        b.bench_timed(&format!("decode_bucketed_live{live}_of_{s}"), 12, || {
            let t = std::time::Instant::now();
            serving.decode_active(&active).unwrap();
            t.elapsed()
        });
        println!(
            "   occupancy {live}/{s}: modelled {:.2} Mflop/token, logits+shadow download {out} B, {round_ms:.3} ms modelled/round",
            flops as f64 / 1e6,
        );
        // Modelled decode throughput must scale with bucket occupancy —
        // the tokens/sec figures the perf gate pins.
        b.metric(
            &format!("modelled_decode_tok_per_s_live{live}"),
            live as f64 / (round_ms / 1e3),
        );
        if live == s {
            b.metric("modelled_decode_round_ms_full", round_ms);
            b.metric("decode_allreduce_bytes_per_round_full", payload as f64);
            b.metric("decode_mflop_per_round_full", flops as f64 / 1e6);
        }
    }
    println!(
        "   bucket dispatch stats (shape -> rounds/live/padded): {:?}",
        serving.bucket_set().stats()
    );

    // --- per-request depth tiers (plan-variant registry) -----------------
    // One manifest, one resident weight set, three computational graphs:
    // each tier's full-occupancy decode round is priced by the cost model
    // at ITS depth, so modelled tokens/sec must strictly order
    // lp_aggr > lp > dense. These are deterministic metrics the perf gate
    // pins against rust/bench-baseline.json.
    match ServingModel::from_manifest(&manifest, "td-small", &weights, default_net()) {
        Err(e) => eprintln!("   (tier sweep skipped: {e})"),
        Ok(tiers) => {
            let ids = tiers.variant_ids();
            println!("   tier sweep ({} variants, one weight set):", ids.len());
            let mut ordered: Vec<(String, usize, f64)> = Vec::new();
            for vid in &ids {
                for slot in 0..s {
                    tiers.prefill_v(vid, slot, &prompt).unwrap();
                }
                let active: Vec<_> =
                    (0..s).map(|slot| (slot, 65i32, prompt.len() as i32)).collect();
                tiers.decode_active_v(vid, &active).unwrap(); // warm (lazy compile)
                tiers.mesh.metrics.reset();
                tiers.decode_active_v(vid, &active).unwrap();
                let round_ms = tiers.mesh.metrics.modelled_total_ms();
                let var = tiers.variant(vid).unwrap();
                let tok_per_s = s as f64 / (round_ms / 1e3);
                println!(
                    "     tier {vid}: depth {} ({} reduces/tok) — {round_ms:.3} ms/round modelled, {tok_per_s:.1} tok/s",
                    var.effective_depth(),
                    var.all_reduces_per_token(),
                );
                b.metric(&format!("modelled_decode_tok_per_s_tier_{vid}"), tok_per_s);
                ordered.push((vid.to_string(), var.effective_depth(), tok_per_s));
            }
            // dense > lp > lp_aggr in depth ⇒ strictly the reverse in tok/s
            for w in ordered.windows(2) {
                assert!(
                    w[0].1 > w[1].1 && w[0].2 < w[1].2,
                    "tier ordering violated: {ordered:?}"
                );
            }
        }
    }
    // --- observability export (README "Observability") -------------------
    // One traced full-occupancy decode round on the simulated clock: the
    // Chrome/Perfetto trace + metrics snapshot land next to the bench
    // report in target/bench-reports, so the CI bench job uploads them as
    // workflow artifacts and the perf gate can read the snapshot.
    let reports = truedepth::repo_root().join("target/bench-reports");
    let trace_path = args
        .get("trace-out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| reports.join("bench_decode.trace.json"));
    let snap_path = args
        .get("metrics-out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| reports.join("bench_decode.metrics.json"));
    let active: Vec<_> = (0..s).map(|slot| (slot, 65i32, prompt.len() as i32)).collect();
    let tracer = Tracer::new();
    sim.mesh.metrics.reset();
    sim.mesh.begin_trace();
    sim.decode_active(&active).unwrap();
    tracer.record_mesh_events(sim.mesh.take_timed_trace());
    tracer.write_chrome(&trace_path).unwrap();
    MetricsSnapshot::new("bench_decode").with_mesh(&sim.mesh.metrics).write(&snap_path).unwrap();
    println!(
        "   trace: {} ({} events); metrics snapshot: {}",
        trace_path.display(),
        tracer.len(),
        snap_path.display(),
    );

    b.finish();
}
