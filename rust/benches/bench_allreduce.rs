//! All-reduce micro-benchmark: host-sum cost plus the α–β interconnect
//! model across payload sizes and network regimes. This is the knob behind
//! the paper's whole speedup — the per-sync overhead that LP halves.

use truedepth::bench::Bench;
use truedepth::config::InterconnectConfig;
use truedepth::parallel::{Mesh, SimNet};
use truedepth::runtime::pjrt::HostValue;

fn payload(n: usize) -> (HostValue, HostValue) {
    let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
    (HostValue::f32(vec![n], a.clone()), HostValue::f32(vec![n], a))
}

fn main() {
    let mut b = Bench::new("bench_allreduce");

    // pure data-plane (no cost model): 4 KiB, 128 KiB, 1 MiB payloads
    let mesh = Mesh::new(1, InterconnectConfig { enabled: false, ..Default::default() });
    for n in [1024usize, 32 * 1024, 256 * 1024] {
        let (pa, pb) = payload(n);
        b.bench(&format!("host_sum_{}kB", n * 4 / 1024), || {
            let _ = mesh.all_reduce(vec![pa.clone(), pb.clone()]).unwrap();
        });
    }

    // cost-model regimes over the decode payload [4, 256] = 4 KiB
    for (name, alpha_us, beta_gbs) in
        [("nvlink_like", 10.0, 300.0), ("default", 30.0, 25.0), ("pcie_like", 50.0, 12.0)]
    {
        let mesh = Mesh::new(
            1,
            InterconnectConfig {
                alpha_s: alpha_us * 1e-6,
                beta_bytes_per_s: beta_gbs * 1e9,
                enabled: true,
            },
        );
        let (pa, pb) = payload(1024);
        b.bench_timed(&format!("allreduce_4kB_{name}"), 15, || {
            let t = std::time::Instant::now();
            let _ = mesh.all_reduce(vec![pa.clone(), pb.clone()]).unwrap();
            t.elapsed()
        });
    }

    // the cost model itself (pure function)
    let net = SimNet::new(InterconnectConfig::default());
    b.bench("cost_model_eval", || {
        let _ = net.all_reduce_cost(128 * 256 * 4, 2);
    });

    b.finish();
}
