//! Coordinator data-structure benchmarks: batcher submit/drain throughput
//! and slot-manager churn — L3 bookkeeping must be negligible next to a
//! decode step (~ms), i.e. well under a microsecond per op.

use std::time::Instant;

use truedepth::bench::Bench;
use truedepth::coordinator::batcher::Batcher;
use truedepth::coordinator::request::{Job, Request, RequestOptions};
use truedepth::model::kvcache::SlotManager;

fn job(id: u64) -> Job {
    let (tx, rx) = std::sync::mpsc::channel();
    Box::leak(Box::new(rx)); // keep the channel alive without a receiver loop
    Job {
        request: Request {
            id,
            prompt: "bench prompt".into(),
            opts: RequestOptions::default(),
            submitted_at: Instant::now(),
        },
        reply: tx,
    }
}

fn main() {
    let mut b = Bench::new("bench_coordinator");

    let batcher = Batcher::new(1 << 14);
    let mut id = 0u64;
    b.bench("batcher_submit_drain_pair", || {
        id += 1;
        batcher.submit(job(id)).ok().unwrap();
        let got = batcher.drain(1, std::time::Duration::from_millis(1));
        assert_eq!(got.len(), 1);
    });

    let mut slots = SlotManager::new(4, 256);
    b.bench("slotmgr_alloc_advance_free", || {
        let s = slots.alloc(1, 16, 4, 10).unwrap();
        slots.advance(s, 11, 999);
        slots.free(s);
    });

    let mut slots4 = SlotManager::new(4, 256);
    for i in 0..4 {
        slots4.alloc(i, 8, 100, 42).unwrap();
    }
    b.bench("slotmgr_step_inputs_full", || {
        let _ = slots4.step_inputs();
    });

    b.finish();
}
