//! Host-tensor hot-path micro-benchmarks: the residual add / all-reduce sum
//! loops must sit at memory-bandwidth roofline (they are on the per-token
//! critical path between executable calls).

use truedepth::bench::Bench;
use truedepth::tensor::{add_slices, argmax, log_softmax_at, sum_slices};

fn main() {
    let mut b = Bench::new("bench_hostops");

    // [T=128, D=256] activation — the largest per-stage reduce payload.
    let src: Vec<f32> = (0..128 * 256).map(|i| i as f32 * 0.001).collect();
    let mut dst = src.clone();
    b.bench("add_slices_128x256", || {
        add_slices(&mut dst, &src);
    });

    // decode-sized payload [S=4, D=256]
    let s2: Vec<f32> = (0..4 * 256).map(|i| i as f32).collect();
    let mut d2 = s2.clone();
    b.bench("add_slices_4x256", || {
        add_slices(&mut d2, &s2);
    });

    let p0 = src.clone();
    let p1 = src.clone();
    b.bench("allreduce_sum_2rank_128x256", || {
        let _ = sum_slices(&[&p0, &p1]);
    });

    // logits row of V=260
    let logits: Vec<f32> = (0..260).map(|i| ((i * 37) % 100) as f32 * 0.1).collect();
    b.bench("argmax_v260", || {
        let _ = argmax(&logits);
    });
    b.bench("log_softmax_at_v260", || {
        let _ = log_softmax_at(&logits, 42);
    });

    b.finish();
}
