//! L3 coordinator: the serving stack around the LP/TP executor.
//!
//! Shape follows the vLLM-router architecture: a [`batcher`] with bounded
//! admission and a continuous-batching [`scheduler`] that interleaves
//! prefills with multi-slot decode steps over the simulated tensor-parallel
//! mesh. Multi-replica routing lives one layer up, in [`crate::cluster`]:
//! a cost-model router fronting R independent scheduler/batcher pairs.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use metrics::{ServerMetrics, TierStats};
pub use request::{Request, RequestOptions, Response, TokenEvent};
pub use server::{ResponseHandle, Server};
