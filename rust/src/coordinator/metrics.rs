//! Serving metrics: counters + latency reservoirs, shared via Arc.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

#[derive(Default)]
pub struct ServerMetrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    /// Live-lane count of the most recent decode round (gauge).
    pub live_lanes_last_round: AtomicU64,
    /// Occupancy histogram: `hist[k]` = decode rounds with k live lanes.
    /// Together with the gauge this makes bucket-selection quality
    /// observable: rounds clustered at low occupancy should dispatch small
    /// buckets (see `runtime::buckets`).
    occupancy_hist: Mutex<Vec<u64>>,
    ttft_ms: Mutex<Vec<f64>>,
    latency_ms: Mutex<Vec<f64>>,
}

impl ServerMetrics {
    pub fn record_completion(&self, ttft_ms: f64, latency_ms: f64, tokens: usize) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.ttft_ms.lock().unwrap().push(ttft_ms);
        self.latency_ms.lock().unwrap().push(latency_ms);
    }

    /// Record one decode round with `live` occupied lanes.
    pub fn record_decode_round(&self, live: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.live_lanes_last_round.store(live as u64, Ordering::Relaxed);
        let mut hist = self.occupancy_hist.lock().unwrap();
        if hist.len() <= live {
            hist.resize(live + 1, 0);
        }
        hist[live] += 1;
    }

    /// Snapshot of the occupancy histogram (index = live lanes per round).
    pub fn occupancy_histogram(&self) -> Vec<u64> {
        self.occupancy_hist.lock().unwrap().clone()
    }

    pub fn ttft_summary(&self) -> Option<Summary> {
        let v = self.ttft_ms.lock().unwrap();
        (!v.is_empty()).then(|| Summary::from(&v))
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        let v = self.latency_ms.lock().unwrap();
        (!v.is_empty()).then(|| Summary::from(&v))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests: {} submitted, {} completed, {} rejected; tokens: {} generated, {} prefilled; decode steps: {}",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
        );
        let hist = self.occupancy_histogram();
        if hist.iter().any(|&n| n > 0) {
            let cells: Vec<String> = hist
                .iter()
                .enumerate()
                .filter(|(_, &n)| n > 0)
                .map(|(k, n)| format!("{k}×{n}"))
                .collect();
            s += &format!(
                "\ndecode occupancy (live lanes × rounds): {}; last round: {} live",
                cells.join(" "),
                self.live_lanes_last_round.load(Ordering::Relaxed),
            );
        }
        if let Some(t) = self.ttft_summary() {
            s += &format!("\nttft ms: p50 {:.1} p90 {:.1} p99 {:.1}", t.p50, t.p90, t.p99);
        }
        if let Some(l) = self.latency_summary() {
            s += &format!("\nlatency ms: p50 {:.1} p90 {:.1} p99 {:.1}", l.p50, l.p90, l.p99);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = ServerMetrics::default();
        m.requests_submitted.store(3, Ordering::Relaxed);
        m.record_completion(10.0, 50.0, 8);
        m.record_completion(20.0, 70.0, 8);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 16);
        let t = m.ttft_summary().unwrap();
        assert!((t.p50 - 15.0).abs() < 1e-9);
        assert!(m.report().contains("2 completed"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = ServerMetrics::default();
        assert!(m.ttft_summary().is_none());
        assert!(m.latency_summary().is_none());
        assert!(m.occupancy_histogram().is_empty());
        assert!(!m.report().contains("decode occupancy"));
    }

    #[test]
    fn occupancy_histogram_and_gauge_track_rounds() {
        let m = ServerMetrics::default();
        m.record_decode_round(2);
        m.record_decode_round(2);
        m.record_decode_round(4);
        m.record_decode_round(1);
        assert_eq!(m.occupancy_histogram(), vec![0, 1, 2, 0, 1]);
        assert_eq!(m.live_lanes_last_round.load(Ordering::Relaxed), 1);
        assert_eq!(m.decode_steps.load(Ordering::Relaxed), 4);
        let r = m.report();
        assert!(r.contains("1×1 2×2 4×1"), "{r}");
        assert!(r.contains("last round: 1 live"), "{r}");
    }
}
