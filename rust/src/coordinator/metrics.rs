//! Serving metrics: counters + latency reservoirs, shared via Arc.
//!
//! Two parallel sets of latency figures coexist here:
//!
//! * **wall-clock** TTFT/latency — what a caller experienced on this
//!   machine, inherently load-dependent;
//! * **modelled** TTFT/latency/throughput — deltas of the mesh's simulated
//!   clock (`MeshMetrics::modelled_total_ns`: roofline compute + α–β
//!   collectives + host link), attributed to requests and decode rounds by
//!   the scheduler. Deterministic: two identical runs report bit-identical
//!   modelled figures, which is what lets CI gate on them
//!   (`bin/perf_gate.rs`) where wall-clock would flake.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::{Reservoir, Summary};

/// Capacity of each latency reservoir. Under sustained load the metrics
/// footprint stays fixed at 4 × this many `f64`s; percentiles come from a
/// deterministic uniform sample (see [`Reservoir`]) while n/min/max stay
/// exact.
const RESERVOIR_CAP: usize = 1024;

/// Per-tier decode accounting (plan-variant serving): each entry is one
/// serving tier's share of the decode rounds, keyed by `VariantId` name.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Decode rounds dispatched for this tier (one bucketed dispatch per
    /// tier per scheduler round).
    pub rounds: u64,
    /// Tokens those rounds produced (= Σ live lanes).
    pub tokens: u64,
    /// Modelled simulated-clock time those rounds cost, ns.
    pub modelled_ns: u64,
}

impl TierStats {
    /// Modelled decode throughput of this tier, tokens per simulated
    /// second (`None` until a round has been attributed).
    pub fn modelled_tok_per_s(&self) -> Option<f64> {
        (self.modelled_ns > 0).then(|| self.tokens as f64 / (self.modelled_ns as f64 / 1e9))
    }
}

pub struct ServerMetrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Requests cancelled mid-stream (the reply receiver was dropped —
    /// e.g. an HTTP client disconnected); the slot is reclaimed at the
    /// next token boundary.
    pub requests_cancelled: AtomicU64,
    /// KV slots claimed since startup (monotone). A rejected request must
    /// never move this counter — the load-shedding tests assert zero slot
    /// churn by comparing it against completions.
    pub slot_allocs: AtomicU64,
    /// Requests parked at admission because the paged-KV pools were
    /// transiently full (back-pressure instead of rejection); each parked
    /// request re-admits once siblings retire and free pages.
    pub admission_waits: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    /// Live-lane count of the most recent decode round (gauge).
    pub live_lanes_last_round: AtomicU64,
    /// Modelled device time spent in decode rounds, ns (simulated-clock
    /// deltas recorded by the scheduler around each round).
    pub modelled_decode_ns: AtomicU64,
    /// Tokens produced by those rounds (= Σ live lanes per round); with
    /// `modelled_decode_ns` this yields modelled decode throughput.
    pub modelled_decode_tokens: AtomicU64,
    /// Modelled device time spent in prefill passes/chunks, ns.
    pub modelled_prefill_ns: AtomicU64,
    /// Executables evicted from the serving model's exec cache so far
    /// (gauge, mirrored from `runtime::buckets::ExecCacheStats` by the
    /// scheduler; non-zero only under a `[runtime] max_cached_execs` cap).
    pub exec_cache_evictions: AtomicU64,
    /// Paged-KV counters (all zero while paging is off), mirrored from
    /// `ServingModel::kv_stats` by the scheduler once per decode round —
    /// same pattern as `exec_cache_evictions`. `kv_pages_in_use` is a
    /// gauge; the rest are monotone counters. Deterministic under a fixed
    /// request sequence, so the bench baselines can gate on them.
    pub kv_pages_in_use: AtomicU64,
    pub kv_prefix_lookups: AtomicU64,
    pub kv_prefix_hits: AtomicU64,
    pub kv_prefix_shared_tokens: AtomicU64,
    pub kv_evictions: AtomicU64,
    /// Per-tier decode attribution (see [`TierStats`]); keyed by tier name.
    tier_stats: Mutex<BTreeMap<String, TierStats>>,
    /// Occupancy histogram: `hist[k]` = decode rounds with k live lanes.
    /// Together with the gauge this makes bucket-selection quality
    /// observable: rounds clustered at low occupancy should dispatch small
    /// buckets (see `runtime::buckets`).
    occupancy_hist: Mutex<Vec<u64>>,
    /// Latency reservoirs: bounded at [`RESERVOIR_CAP`] samples each via
    /// deterministic reservoir sampling, so sustained load cannot grow them.
    ttft_ms: Mutex<Reservoir>,
    latency_ms: Mutex<Reservoir>,
    modelled_ttft_ms: Mutex<Reservoir>,
    modelled_latency_ms: Mutex<Reservoir>,
}

impl Default for ServerMetrics {
    fn default() -> ServerMetrics {
        // Fixed distinct seeds keep the four sampling streams independent
        // AND reproducible: identical runs yield bit-identical summaries,
        // the property `obs::MetricsSnapshot` and the perf gate rely on.
        ServerMetrics {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_rejected: AtomicU64::new(0),
            requests_cancelled: AtomicU64::new(0),
            slot_allocs: AtomicU64::new(0),
            admission_waits: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            decode_steps: AtomicU64::new(0),
            live_lanes_last_round: AtomicU64::new(0),
            modelled_decode_ns: AtomicU64::new(0),
            modelled_decode_tokens: AtomicU64::new(0),
            modelled_prefill_ns: AtomicU64::new(0),
            exec_cache_evictions: AtomicU64::new(0),
            kv_pages_in_use: AtomicU64::new(0),
            kv_prefix_lookups: AtomicU64::new(0),
            kv_prefix_hits: AtomicU64::new(0),
            kv_prefix_shared_tokens: AtomicU64::new(0),
            kv_evictions: AtomicU64::new(0),
            tier_stats: Mutex::new(BTreeMap::new()),
            occupancy_hist: Mutex::new(Vec::new()),
            ttft_ms: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0x7f71)),
            latency_ms: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0x1a7e)),
            modelled_ttft_ms: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0x0de1_7f71)),
            modelled_latency_ms: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0x0de1_1a7e)),
        }
    }
}

impl ServerMetrics {
    /// Record a finished request: wall-clock TTFT/latency plus the modelled
    /// (simulated-clock) equivalents attributed by the scheduler.
    pub fn record_completion(
        &self,
        ttft_ms: f64,
        latency_ms: f64,
        tokens: usize,
        modelled_ttft_ms: f64,
        modelled_latency_ms: f64,
    ) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.ttft_ms.lock().unwrap().push(ttft_ms);
        self.latency_ms.lock().unwrap().push(latency_ms);
        self.modelled_ttft_ms.lock().unwrap().push(modelled_ttft_ms);
        self.modelled_latency_ms.lock().unwrap().push(modelled_latency_ms);
    }

    /// Record one decode round: `live` occupied lanes, `modelled_ns` of
    /// simulated-clock time the round cost.
    pub fn record_decode_round(&self, live: usize, modelled_ns: u64) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.live_lanes_last_round.store(live as u64, Ordering::Relaxed);
        self.modelled_decode_ns.fetch_add(modelled_ns, Ordering::Relaxed);
        self.modelled_decode_tokens.fetch_add(live as u64, Ordering::Relaxed);
        let mut hist = self.occupancy_hist.lock().unwrap();
        if hist.len() <= live {
            hist.resize(live + 1, 0);
        }
        hist[live] += 1;
    }

    /// Record one prefill pass/chunk step's simulated-clock cost.
    pub fn record_prefill_step(&self, modelled_ns: u64) {
        self.modelled_prefill_ns.fetch_add(modelled_ns, Ordering::Relaxed);
    }

    /// Mirror the serving model's paged-KV counters (see
    /// [`crate::model::kvcache::KvStats`]) into the shared metrics.
    pub fn record_kv_stats(&self, ks: &crate::model::kvcache::KvStats) {
        self.kv_pages_in_use.store(ks.pages_in_use, Ordering::Relaxed);
        self.kv_prefix_lookups.store(ks.prefix_lookups, Ordering::Relaxed);
        self.kv_prefix_hits.store(ks.prefix_hits, Ordering::Relaxed);
        self.kv_prefix_shared_tokens.store(ks.prefix_shared_tokens, Ordering::Relaxed);
        self.kv_evictions.store(ks.evictions, Ordering::Relaxed);
    }

    /// Attribute one decode round to a serving tier (called alongside
    /// [`ServerMetrics::record_decode_round`] — the scheduler dispatches
    /// one bucketed round per tier per iteration).
    pub fn record_tier_round(&self, tier: &str, tokens: usize, modelled_ns: u64) {
        let mut m = self.tier_stats.lock().unwrap();
        let s = m.entry(tier.to_string()).or_default();
        s.rounds += 1;
        s.tokens += tokens as u64;
        s.modelled_ns += modelled_ns;
    }

    /// Snapshot of the per-tier decode attribution, in tier-name order.
    pub fn tier_stats(&self) -> Vec<(String, TierStats)> {
        self.tier_stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    }

    /// Snapshot of the occupancy histogram (index = live lanes per round).
    pub fn occupancy_histogram(&self) -> Vec<u64> {
        self.occupancy_hist.lock().unwrap().clone()
    }

    pub fn ttft_summary(&self) -> Option<Summary> {
        self.ttft_ms.lock().unwrap().summary()
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.latency_ms.lock().unwrap().summary()
    }

    /// Modelled admission→first-token latency distribution (deterministic).
    pub fn modelled_ttft_summary(&self) -> Option<Summary> {
        self.modelled_ttft_ms.lock().unwrap().summary()
    }

    /// Modelled end-to-end request latency distribution (deterministic).
    pub fn modelled_latency_summary(&self) -> Option<Summary> {
        self.modelled_latency_ms.lock().unwrap().summary()
    }

    /// Modelled decode throughput: tokens produced per second of simulated
    /// decode-round time. `None` until a round has been recorded.
    pub fn modelled_decode_tok_per_s(&self) -> Option<f64> {
        let ns = self.modelled_decode_ns.load(Ordering::Relaxed);
        let toks = self.modelled_decode_tokens.load(Ordering::Relaxed);
        (ns > 0).then(|| toks as f64 / (ns as f64 / 1e9))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests: {} submitted, {} completed, {} rejected; tokens: {} generated, {} prefilled; decode steps: {}",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
        );
        let cancelled = self.requests_cancelled.load(Ordering::Relaxed);
        if cancelled > 0 {
            s += &format!("\ncancelled mid-stream (client went away): {cancelled}");
        }
        let hist = self.occupancy_histogram();
        if hist.iter().any(|&n| n > 0) {
            let cells: Vec<String> = hist
                .iter()
                .enumerate()
                .filter(|(_, &n)| n > 0)
                .map(|(k, n)| format!("{k}×{n}"))
                .collect();
            s += &format!(
                "\ndecode occupancy (live lanes × rounds): {}; last round: {} live",
                cells.join(" "),
                self.live_lanes_last_round.load(Ordering::Relaxed),
            );
        }
        if let Some(t) = self.ttft_summary() {
            s += &format!("\nttft ms: p50 {:.1} p90 {:.1} p99 {:.1}", t.p50, t.p90, t.p99);
        }
        if let Some(l) = self.latency_summary() {
            s += &format!("\nlatency ms: p50 {:.1} p90 {:.1} p99 {:.1}", l.p50, l.p90, l.p99);
        }
        if let Some(t) = self.modelled_ttft_summary() {
            s += &format!(
                "\nmodelled ttft ms: p50 {:.2} p90 {:.2} p99 {:.2}",
                t.p50, t.p90, t.p99
            );
        }
        if let Some(l) = self.modelled_latency_summary() {
            s += &format!(
                "\nmodelled latency ms: p50 {:.2} p90 {:.2} p99 {:.2}",
                l.p50, l.p90, l.p99
            );
        }
        if let Some(tps) = self.modelled_decode_tok_per_s() {
            s += &format!(
                "\nmodelled decode: {:.1} tok/s ({:.2} ms over {} tokens)",
                tps,
                self.modelled_decode_ns.load(Ordering::Relaxed) as f64 / 1e6,
                self.modelled_decode_tokens.load(Ordering::Relaxed),
            );
        }
        // per-tier attribution: the speed/quality dial in numbers (one
        // line per plan variant that decoded this run)
        for (tier, st) in self.tier_stats() {
            if let Some(tps) = st.modelled_tok_per_s() {
                s += &format!(
                    "\n  tier {tier}: {tps:.1} modelled tok/s ({} rounds, {} tokens)",
                    st.rounds, st.tokens,
                );
            }
        }
        // reported independently of decode: a run can have prefilled
        // without completing a single decode round yet
        let prefill_ns = self.modelled_prefill_ns.load(Ordering::Relaxed);
        if prefill_ns > 0 {
            s += &format!("\nmodelled prefill: {:.2} ms", prefill_ns as f64 / 1e6);
        }
        let evictions = self.exec_cache_evictions.load(Ordering::Relaxed);
        if evictions > 0 {
            s += &format!("\nexec cache evictions: {evictions}");
        }
        // paged-KV line only when paging actually did something (gauge or
        // any probe non-zero); a dense run reports nothing here
        let kv_pages = self.kv_pages_in_use.load(Ordering::Relaxed);
        let kv_lookups = self.kv_prefix_lookups.load(Ordering::Relaxed);
        if kv_pages > 0 || kv_lookups > 0 {
            s += &format!(
                "\npaged kv: {} pages in use; prefix reuse {}/{} hits, {} tokens shared; {} evictions",
                kv_pages,
                self.kv_prefix_hits.load(Ordering::Relaxed),
                kv_lookups,
                self.kv_prefix_shared_tokens.load(Ordering::Relaxed),
                self.kv_evictions.load(Ordering::Relaxed),
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = ServerMetrics::default();
        m.requests_submitted.store(3, Ordering::Relaxed);
        m.record_completion(10.0, 50.0, 8, 9.0, 45.0);
        m.record_completion(20.0, 70.0, 8, 19.0, 65.0);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 16);
        let t = m.ttft_summary().unwrap();
        assert!((t.p50 - 15.0).abs() < 1e-9);
        let mt = m.modelled_ttft_summary().unwrap();
        assert!((mt.p50 - 14.0).abs() < 1e-9);
        let ml = m.modelled_latency_summary().unwrap();
        assert!((ml.p50 - 55.0).abs() < 1e-9);
        assert!(m.report().contains("2 completed"));
        assert!(m.report().contains("modelled ttft"));
        assert!(!m.report().contains("cancelled"), "cancel line is gated on non-zero");
        m.requests_cancelled.store(1, Ordering::Relaxed);
        assert!(m.report().contains("cancelled mid-stream (client went away): 1"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = ServerMetrics::default();
        assert!(m.ttft_summary().is_none());
        assert!(m.latency_summary().is_none());
        assert!(m.modelled_ttft_summary().is_none());
        assert!(m.modelled_latency_summary().is_none());
        assert!(m.modelled_decode_tok_per_s().is_none());
        assert!(m.occupancy_histogram().is_empty());
        assert!(!m.report().contains("decode occupancy"));
        assert!(!m.report().contains("modelled"));
    }

    #[test]
    fn tier_attribution_and_eviction_gauge_appear_in_report() {
        let m = ServerMetrics::default();
        assert!(m.tier_stats().is_empty());
        m.record_tier_round("dense", 4, 2_000_000);
        m.record_tier_round("lp", 4, 1_000_000);
        m.record_tier_round("lp", 4, 1_000_000);
        let stats = m.tier_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].0, "dense");
        assert_eq!(
            stats[0].1,
            TierStats { rounds: 1, tokens: 4, modelled_ns: 2_000_000 }
        );
        // lp: 8 tokens over 2 simulated ms = 4000 tok/s
        assert!((stats[1].1.modelled_tok_per_s().unwrap() - 4000.0).abs() < 1e-9);
        assert!(TierStats::default().modelled_tok_per_s().is_none());
        let r = m.report();
        assert!(r.contains("tier dense: 2000.0 modelled tok/s"), "{r}");
        assert!(r.contains("tier lp: 4000.0 modelled tok/s"), "{r}");
        assert!(!r.contains("exec cache evictions"), "{r}");
        m.exec_cache_evictions.store(3, Ordering::Relaxed);
        assert!(m.report().contains("exec cache evictions: 3"));
    }

    /// Paged-KV counters mirror `KvStats` verbatim, and the report line is
    /// gated on paging having actually done something.
    #[test]
    fn kv_stats_mirror_and_report_gating() {
        use crate::model::kvcache::KvStats;
        let m = ServerMetrics::default();
        assert!(!m.report().contains("paged kv"), "dense runs report no kv line");
        m.record_kv_stats(&KvStats {
            pages_in_use: 24,
            prefix_lookups: 2,
            prefix_hits: 1,
            prefix_shared_tokens: 64,
            evictions: 3,
        });
        assert_eq!(m.kv_pages_in_use.load(Ordering::Relaxed), 24);
        assert_eq!(m.kv_prefix_shared_tokens.load(Ordering::Relaxed), 64);
        let r = m.report();
        assert!(
            r.contains("paged kv: 24 pages in use; prefix reuse 1/2 hits, 64 tokens shared; 3 evictions"),
            "{r}"
        );
        // the gauge can legitimately fall back to zero while counters stay
        m.record_kv_stats(&KvStats { prefix_lookups: 2, ..KvStats::default() });
        assert!(m.report().contains("paged kv: 0 pages in use"), "gated on lookups too");
    }

    /// The latency reservoirs are bounded: far more completions than the
    /// reservoir capacity must not grow memory, exact figures (n/min/max)
    /// must survive sampling, and two identical runs must agree bit for bit.
    #[test]
    fn reservoir_summaries_stay_stable_under_load() {
        let run = || {
            let m = ServerMetrics::default();
            for i in 0..5000 {
                let x = (i % 97) as f64;
                m.record_completion(x, x + 100.0, 1, x + 0.5, x + 100.5);
            }
            m
        };
        let m = run();
        let t = m.ttft_summary().unwrap();
        assert_eq!(t.n, 5000, "count is exact, not the sample size");
        assert_eq!((t.min, t.max), (0.0, 96.0), "min/max are exact");
        let l = m.latency_summary().unwrap();
        assert_eq!((l.min, l.max), (100.0, 196.0));
        // the sampled median of a uniform 0..97 stream stays near 48
        assert!((t.p50 - 48.0).abs() < 15.0, "sampled p50 drifted: {}", t.p50);
        let m2 = run();
        assert_eq!(m2.ttft_summary().unwrap(), t, "summaries must be run-stable");
        assert_eq!(m2.modelled_ttft_summary().unwrap(), m.modelled_ttft_summary().unwrap());
        assert_eq!(m2.modelled_latency_summary().unwrap(), m.modelled_latency_summary().unwrap());
    }

    #[test]
    fn occupancy_histogram_and_gauge_track_rounds() {
        let m = ServerMetrics::default();
        m.record_decode_round(2, 1_000_000);
        m.record_decode_round(2, 1_000_000);
        m.record_decode_round(4, 2_000_000);
        m.record_decode_round(1, 500_000);
        assert_eq!(m.occupancy_histogram(), vec![0, 1, 2, 0, 1]);
        assert_eq!(m.live_lanes_last_round.load(Ordering::Relaxed), 1);
        assert_eq!(m.decode_steps.load(Ordering::Relaxed), 4);
        let r = m.report();
        assert!(r.contains("1×1 2×2 4×1"), "{r}");
        assert!(r.contains("last round: 1 live"), "{r}");
        // modelled throughput: 9 tokens over 4.5 ms simulated = 2000 tok/s
        let tps = m.modelled_decode_tok_per_s().unwrap();
        assert!((tps - 2000.0).abs() < 1e-9, "{tps}");
        assert!(r.contains("modelled decode: 2000.0 tok/s"), "{r}");
    }
}
