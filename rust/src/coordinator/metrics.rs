//! Serving metrics: counters + latency reservoirs, shared via Arc.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

#[derive(Default)]
pub struct ServerMetrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    ttft_ms: Mutex<Vec<f64>>,
    latency_ms: Mutex<Vec<f64>>,
}

impl ServerMetrics {
    pub fn record_completion(&self, ttft_ms: f64, latency_ms: f64, tokens: usize) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.ttft_ms.lock().unwrap().push(ttft_ms);
        self.latency_ms.lock().unwrap().push(latency_ms);
    }

    pub fn ttft_summary(&self) -> Option<Summary> {
        let v = self.ttft_ms.lock().unwrap();
        (!v.is_empty()).then(|| Summary::from(&v))
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        let v = self.latency_ms.lock().unwrap();
        (!v.is_empty()).then(|| Summary::from(&v))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests: {} submitted, {} completed, {} rejected; tokens: {} generated, {} prefilled; decode steps: {}",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
        );
        if let Some(t) = self.ttft_summary() {
            s += &format!("\nttft ms: p50 {:.1} p90 {:.1} p99 {:.1}", t.p50, t.p90, t.p99);
        }
        if let Some(l) = self.latency_summary() {
            s += &format!("\nlatency ms: p50 {:.1} p90 {:.1} p99 {:.1}", l.p50, l.p90, l.p99);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = ServerMetrics::default();
        m.requests_submitted.store(3, Ordering::Relaxed);
        m.record_completion(10.0, 50.0, 8);
        m.record_completion(20.0, 70.0, 8);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 16);
        let t = m.ttft_summary().unwrap();
        assert!((t.p50 - 15.0).abs() < 1e-9);
        assert!(m.report().contains("2 completed"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = ServerMetrics::default();
        assert!(m.ttft_summary().is_none());
        assert!(m.latency_summary().is_none());
    }
}
