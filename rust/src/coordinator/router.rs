//! Router: fronts one or more named server instances (model replicas) and
//! picks a backend per request — least-loaded among the replicas of the
//! requested model (the vLLM-router policy for single-host deployments).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::api::CompletionRequest;
use crate::coordinator::request::{RequestOptions, Response};
use crate::coordinator::server::{ResponseHandle, Server};
use crate::error::{Error, Result};

#[derive(Default)]
pub struct Router {
    backends: BTreeMap<String, Vec<Arc<Server>>>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn add_backend(&mut self, model: &str, server: Arc<Server>) {
        self.backends.entry(model.to_string()).or_default().push(server);
    }

    pub fn models(&self) -> Vec<&str> {
        self.backends.keys().map(|s| s.as_str()).collect()
    }

    /// Pick the least-loaded replica for `model`.
    pub fn pick(&self, model: &str) -> Result<&Arc<Server>> {
        let replicas = self
            .backends
            .get(model)
            .filter(|v| !v.is_empty())
            .ok_or_else(|| Error::Serving(format!("no backend for model `{model}`")))?;
        Ok(replicas
            .iter()
            .min_by_key(|s| s.queue_len())
            .expect("non-empty replicas"))
    }

    /// Route a typed request to the least-loaded replica of `model`,
    /// returning its reply stream.
    pub fn route(&self, model: &str, req: CompletionRequest) -> Result<ResponseHandle> {
        self.pick(model)?.request(req)
    }

    /// Route a blocking request (convenience over [`Router::route`]).
    pub fn submit_blocking(
        &self,
        model: &str,
        prompt: &str,
        opts: RequestOptions,
    ) -> Result<Response> {
        self.route(model, CompletionRequest::from_options(prompt, &opts))?.wait()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_an_error() {
        let r = Router::new();
        assert!(r.pick("nope").is_err());
        assert!(r.models().is_empty());
    }
}
