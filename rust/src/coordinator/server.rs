//! Server: owns the scheduler thread and exposes the typed request API.
//!
//! The entry point is [`Server::request`]: a [`CompletionRequest`] in, a
//! [`ResponseHandle`] out. The handle is both a stream (per-token
//! [`TokenEvent`]s, the same feed the HTTP edge serves as SSE) and a
//! future ([`ResponseHandle::wait`] blocks for the final [`Response`]).
//! The legacy `submit`/`submit_blocking` pair remains as thin deprecated
//! shims over the same path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::CompletionRequest;
use crate::config::ServerConfig;
use crate::coordinator::batcher::{Batcher, SubmitError};
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::request::{Job, Request, RequestOptions, Response, TokenEvent};
use crate::coordinator::scheduler::Scheduler;
use crate::error::{Error, Result};
use crate::model::ServingModel;
use crate::obs::Tracer;

pub struct Server {
    batcher: Arc<Batcher>,
    pub metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    join: Option<JoinHandle<()>>,
}

/// A submitted request's reply stream: iterate per-token events with
/// [`ResponseHandle::next_event`]/[`ResponseHandle::stream`], or block
/// for the final response with [`ResponseHandle::wait`]. Dropping the
/// handle cancels the request at its next token boundary (the scheduler
/// notices the closed channel, reclaims the slot and keeps running).
pub struct ResponseHandle {
    id: u64,
    rx: Receiver<TokenEvent>,
}

impl ResponseHandle {
    /// Crate-internal constructor: the cluster front door builds handles
    /// over its own pump channels (`cluster::Cluster::submit`) instead of
    /// handing out the scheduler's raw reply stream.
    pub(crate) fn new(id: u64, rx: Receiver<TokenEvent>) -> ResponseHandle {
        ResponseHandle { id, rx }
    }

    /// The request id (matches `Response::id` and streamed chunk ids).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` once the stream has ended (after
    /// `Done`, or if the scheduler dropped the request).
    pub fn next_event(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Like [`ResponseHandle::next_event`], but gives up after `timeout`
    /// (returning `None` on both timeout and end-of-stream).
    pub fn next_event_timeout(&self, timeout: Duration) -> Option<TokenEvent> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// The raw event receiver — for callers that need their own
    /// select/timeout control (the HTTP edge probes the client connection
    /// between events).
    pub fn events(&self) -> &Receiver<TokenEvent> {
        &self.rx
    }

    /// Consume the handle as an iterator over the remaining events (ends
    /// after `Done`).
    pub fn stream(self) -> impl Iterator<Item = TokenEvent> {
        self.rx.into_iter()
    }

    /// Block until the request completes and return the final response.
    pub fn wait(self) -> Result<Response> {
        for ev in self.rx.iter() {
            if let TokenEvent::Done(r) = ev {
                return Ok(r);
            }
        }
        Err(Error::Serving("scheduler dropped the request".into()))
    }

    /// Like [`ResponseHandle::wait`], but bounded by an overall deadline.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Response> {
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            let left = deadline.saturating_duration_since(now);
            match self.rx.recv_timeout(left) {
                Ok(TokenEvent::Done(r)) => return Ok(r),
                Ok(TokenEvent::Token { .. }) => continue,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::Serving("timed out waiting for response".into()))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Serving("scheduler dropped the request".into()))
                }
            }
        }
    }
}

impl Server {
    /// Spawn the scheduler thread over a ready serving model.
    pub fn start(model: ServingModel, cfg: &ServerConfig) -> Server {
        Server::spawn(model, cfg, None)
    }

    /// Like [`Server::start`], but with a span recorder (`crate::obs`):
    /// the scheduler emits simulated-clock lifecycle spans into `tracer`
    /// and drains the mesh event track into it on shutdown, so after
    /// [`Server::shutdown`] the tracer holds the complete trace.
    pub fn start_traced(model: ServingModel, cfg: &ServerConfig, tracer: Arc<Tracer>) -> Server {
        Server::spawn(model, cfg, Some(tracer))
    }

    fn spawn(model: ServingModel, cfg: &ServerConfig, tracer: Option<Arc<Tracer>>) -> Server {
        let batcher = Arc::new(Batcher::new(cfg.queue_depth));
        let metrics = Arc::new(ServerMetrics::default());
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let wait = Duration::from_millis(cfg.batch_wait_ms);
        let join = std::thread::Builder::new()
            .name("scheduler".into())
            .spawn(move || {
                let mut sched = Scheduler::with_tracer(model, m2, tracer);
                sched.run(&b2, wait);
            })
            .expect("spawn scheduler");
        Server { batcher, metrics, next_id: AtomicU64::new(1), join: Some(join) }
    }

    /// Submit a typed request; returns its reply stream. Back-pressure is
    /// an [`Error::Overloaded`] (HTTP 429 at the network edge) and never
    /// claims a slot.
    pub fn request(&self, req: CompletionRequest) -> Result<ResponseHandle> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let opts = req.options();
        let job = Job {
            request: Request { id, prompt: req.prompt, opts, submitted_at: Instant::now() },
            reply: tx,
        };
        match self.batcher.submit(job) {
            Ok(()) => Ok(ResponseHandle { id, rx }),
            Err(SubmitError::Full(_)) => {
                self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Overloaded("queue full (back-pressure)".into()))
            }
            Err(SubmitError::Closed(_)) => Err(Error::Serving("server shutting down".into())),
        }
    }

    /// Submit a prompt; returns the reply stream.
    #[deprecated(note = "use Server::request(CompletionRequest) and the ResponseHandle stream")]
    pub fn submit(&self, prompt: &str, opts: RequestOptions) -> Result<ResponseHandle> {
        self.request(CompletionRequest::from_options(prompt, &opts))
    }

    /// Submit and block for the result.
    #[deprecated(note = "use Server::request(CompletionRequest) + ResponseHandle::wait")]
    pub fn submit_blocking(&self, prompt: &str, opts: RequestOptions) -> Result<Response> {
        self.request(CompletionRequest::from_options(prompt, &opts))?.wait()
    }

    pub fn queue_len(&self) -> usize {
        self.batcher.len()
    }

    /// Close the queue and wait for the scheduler to drain.
    pub fn shutdown(mut self) {
        self.batcher.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ErrorCode;
    use crate::config::InterconnectConfig;
    use crate::model::{transform, Weights};
    use crate::runtime::Manifest;

    fn server() -> Option<Server> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 11);
        let plan = transform::pair_parallel(cfg.n_layers, 2, 10, true);
        let model = ServingModel::new(
            &manifest,
            "td-small",
            &weights,
            &plan,
            InterconnectConfig { enabled: false, ..Default::default() },
        )
        .ok()?;
        Some(Server::start(model, &ServerConfig { queue_depth: 8, ..Default::default() }))
    }

    /// Drain a handle's full stream: per-token events (indices checked)
    /// followed by the terminal `Done`. Returns (streamed tokens, final
    /// response) — the streamed tokens are the oracle the HTTP loopback
    /// test compares real-socket SSE output against.
    fn drain(h: ResponseHandle) -> (Vec<i32>, Response) {
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in h.stream() {
            match ev {
                TokenEvent::Token { index, token, .. } => {
                    assert_eq!(index, streamed.len(), "token indices must be contiguous");
                    streamed.push(token);
                }
                TokenEvent::Done(r) => {
                    done = Some(r);
                }
            }
        }
        (streamed, done.expect("stream must end with Done"))
    }

    #[test]
    fn serves_concurrent_requests_end_to_end() {
        let Some(server) = server() else { return };
        let handles: Vec<_> = (0..6)
            .map(|i| {
                server
                    .request(CompletionRequest::new(format!("prompt {i} the red fox")).max_tokens(4))
                    .unwrap()
            })
            .collect();
        for h in handles {
            let (streamed, resp) = drain(h);
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.generated_tokens(), 4);
            assert_eq!(streamed, resp.tokens, "streamed tokens must match the final response");
            assert!(resp.latency_ms >= resp.ttft_ms);
        }
        assert_eq!(server.metrics.requests_completed.load(Ordering::Relaxed), 6);
        // continuous batching must have shared decode steps: 6 requests ×
        // 4 tokens = 24 slot-steps; with 4 slots the step count must be
        // well under 24.
        let steps = server.metrics.decode_steps.load(Ordering::Relaxed);
        assert!(steps < 24, "no batching happened: {steps} steps");
        // every completion claimed exactly one slot (no churn)
        assert_eq!(server.metrics.slot_allocs.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    /// Tentpole acceptance, server half: a single `Server` serves
    /// concurrent requests on all three manifest tiers from one resident
    /// weight set — every request completes on its tier, and the per-tier
    /// attribution shows all three decoded.
    #[test]
    fn serves_three_tiers_concurrently_from_one_manifest() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 11);
        let Ok(model) = ServingModel::from_manifest(
            &manifest,
            "td-small",
            &weights,
            InterconnectConfig { enabled: false, ..Default::default() },
        ) else {
            return;
        };
        if model.variant_ids().len() < 3 {
            return; // legacy artifacts without the variants section
        }
        let server = Server::start(model, &ServerConfig { queue_depth: 16, ..Default::default() });
        let tiers = ["dense", "lp", "lp_aggr"];
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let req = CompletionRequest::new(format!("prompt {i} the red fox"))
                    .max_tokens(3)
                    .tier(tiers[i % tiers.len()]);
                (tiers[i % tiers.len()], server.request(req).unwrap())
            })
            .collect();
        for (tier, h) in handles {
            let resp = h.wait_timeout(Duration::from_secs(120)).unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.generated_tokens(), 3);
            assert_eq!(resp.tier.as_deref(), Some(tier), "response must name its tier");
        }
        let stats = server.metrics.tier_stats();
        let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, tiers, "all three tiers must have decoded");
        for (name, st) in &stats {
            assert_eq!(st.tokens, 6, "tier {name}: 2 requests × 3 tokens");
        }
        // unknown tier: rejected end to end with the available tiers named
        // and the stable machine-readable code
        let resp = server.request(CompletionRequest::new("hi").tier("turbo")).unwrap();
        let resp = resp.wait().unwrap();
        let err = resp.error.clone().expect("must fail");
        assert_eq!(err.code, ErrorCode::UnknownTier);
        assert!(err.message.contains("turbo") && err.message.contains("lp_aggr"), "{err}");
        server.shutdown();
    }

    #[test]
    fn oversized_prompt_fails_cleanly() {
        let Some(server) = server() else { return };
        let long = "x".repeat(400); // > ctx 256
        let resp = server.request(CompletionRequest::new(long)).unwrap().wait().unwrap();
        let err = resp.error.expect("must fail");
        assert_eq!(err.code, ErrorCode::InvalidRequest, "{err}");
        server.shutdown();
    }

    /// The deprecated shims stay functional for external callers until
    /// removal (in-repo callers are all migrated to `request()`).
    #[test]
    #[allow(deprecated)]
    fn deprecated_submit_shims_still_work() {
        let Some(server) = server() else { return };
        let opts = RequestOptions { max_new_tokens: 2, ..Default::default() };
        let h = server.submit("the red fox", opts.clone()).unwrap();
        let resp = h.wait().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.generated_tokens(), 2);
        let resp = server.submit_blocking("the red fox", opts).unwrap();
        assert_eq!(resp.generated_tokens(), 2);
        server.shutdown();
    }
}
