//! Server: owns the scheduler thread and exposes a submit() API.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::coordinator::batcher::{Batcher, SubmitError};
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::request::{Job, Request, RequestOptions, Response};
use crate::coordinator::scheduler::Scheduler;
use crate::error::{Error, Result};
use crate::model::ServingModel;
use crate::obs::Tracer;

pub struct Server {
    batcher: Arc<Batcher>,
    pub metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    join: Option<JoinHandle<()>>,
}

impl Server {
    /// Spawn the scheduler thread over a ready serving model.
    pub fn start(model: ServingModel, cfg: &ServerConfig) -> Server {
        Server::spawn(model, cfg, None)
    }

    /// Like [`Server::start`], but with a span recorder (`crate::obs`):
    /// the scheduler emits simulated-clock lifecycle spans into `tracer`
    /// and drains the mesh event track into it on shutdown, so after
    /// [`Server::shutdown`] the tracer holds the complete trace.
    pub fn start_traced(model: ServingModel, cfg: &ServerConfig, tracer: Arc<Tracer>) -> Server {
        Server::spawn(model, cfg, Some(tracer))
    }

    fn spawn(model: ServingModel, cfg: &ServerConfig, tracer: Option<Arc<Tracer>>) -> Server {
        let batcher = Arc::new(Batcher::new(cfg.queue_depth));
        let metrics = Arc::new(ServerMetrics::default());
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let wait = Duration::from_millis(cfg.batch_wait_ms);
        let join = std::thread::Builder::new()
            .name("scheduler".into())
            .spawn(move || {
                let mut sched = Scheduler::with_tracer(model, m2, tracer);
                sched.run(&b2, wait);
            })
            .expect("spawn scheduler");
        Server { batcher, metrics, next_id: AtomicU64::new(1), join: Some(join) }
    }

    /// Submit a prompt; returns the response receiver (async completion).
    pub fn submit(&self, prompt: &str, opts: RequestOptions) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let job = Job {
            request: Request {
                id,
                prompt: prompt.to_string(),
                opts,
                submitted_at: Instant::now(),
            },
            reply: tx,
        };
        match self.batcher.submit(job) {
            Ok(()) => Ok(rx),
            Err(SubmitError::Full(_)) => {
                self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Serving("queue full (back-pressure)".into()))
            }
            Err(SubmitError::Closed(_)) => Err(Error::Serving("server shutting down".into())),
        }
    }

    /// Submit and block for the result.
    pub fn submit_blocking(&self, prompt: &str, opts: RequestOptions) -> Result<Response> {
        let rx = self.submit(prompt, opts)?;
        rx.recv().map_err(|_| Error::Serving("scheduler dropped the request".into()))
    }

    pub fn queue_len(&self) -> usize {
        self.batcher.len()
    }

    /// Close the queue and wait for the scheduler to drain.
    pub fn shutdown(mut self) {
        self.batcher.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::InterconnectConfig;
    use crate::model::{transform, Weights};
    use crate::runtime::Manifest;

    fn server() -> Option<Server> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 11);
        let plan = transform::pair_parallel(cfg.n_layers, 2, 10, true);
        let model = ServingModel::new(
            &manifest,
            "td-small",
            &weights,
            &plan,
            InterconnectConfig { enabled: false, ..Default::default() },
        )
        .ok()?;
        Some(Server::start(model, &ServerConfig { queue_depth: 8, ..Default::default() }))
    }

    #[test]
    fn serves_concurrent_requests_end_to_end() {
        let Some(server) = server() else { return };
        let opts = RequestOptions { max_new_tokens: 4, ..Default::default() };
        let rxs: Vec<_> = (0..6)
            .map(|i| server.submit(&format!("prompt {i} the red fox"), opts.clone()).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.generated_tokens(), 4);
            assert!(resp.latency_ms >= resp.ttft_ms);
        }
        assert_eq!(server.metrics.requests_completed.load(Ordering::Relaxed), 6);
        // continuous batching must have shared decode steps: 6 requests ×
        // 4 tokens = 24 slot-steps; with 4 slots the step count must be
        // well under 24.
        let steps = server.metrics.decode_steps.load(Ordering::Relaxed);
        assert!(steps < 24, "no batching happened: {steps} steps");
        server.shutdown();
    }

    /// Tentpole acceptance, server half: a single `Server` serves
    /// concurrent requests on all three manifest tiers from one resident
    /// weight set — every request completes on its tier, and the per-tier
    /// attribution shows all three decoded.
    #[test]
    fn serves_three_tiers_concurrently_from_one_manifest() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 11);
        let Ok(model) = ServingModel::from_manifest(
            &manifest,
            "td-small",
            &weights,
            InterconnectConfig { enabled: false, ..Default::default() },
        ) else {
            return;
        };
        if model.variant_ids().len() < 3 {
            return; // legacy artifacts without the variants section
        }
        let server = Server::start(model, &ServerConfig { queue_depth: 16, ..Default::default() });
        let tiers = ["dense", "lp", "lp_aggr"];
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let opts = RequestOptions { max_new_tokens: 3, ..Default::default() }
                    .with_tier(tiers[i % tiers.len()]);
                server.submit(&format!("prompt {i} the red fox"), opts).unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.generated_tokens(), 3);
        }
        let stats = server.metrics.tier_stats();
        let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, tiers, "all three tiers must have decoded");
        for (name, st) in &stats {
            assert_eq!(st.tokens, 6, "tier {name}: 2 requests × 3 tokens");
        }
        // unknown tier: rejected end to end with the available tiers named
        let resp = server
            .submit_blocking("hi", RequestOptions::default().with_tier("turbo"))
            .unwrap();
        let err = resp.error.as_deref().unwrap_or("");
        assert!(err.contains("turbo") && err.contains("lp_aggr"), "{err}");
        server.shutdown();
    }

    #[test]
    fn oversized_prompt_fails_cleanly() {
        let Some(server) = server() else { return };
        let long = "x".repeat(400); // > ctx 256
        let resp = server.submit_blocking(&long, RequestOptions::default()).unwrap();
        assert!(resp.error.is_some());
        server.shutdown();
    }
}
