//! Request/response types crossing the coordinator boundary.

use crate::gen::Sampler;

#[derive(Clone, Debug)]
pub struct RequestOptions {
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Serving tier: names a plan variant of the model's manifest
    /// (`runtime::VariantId` — e.g. `dense`, `lp`, `lp_aggr`), selecting
    /// the speed/quality point this request is decoded at. `None` = the
    /// model's default tier. An unknown tier is rejected at admission,
    /// before any KV slot is claimed.
    pub tier: Option<String>,
}

impl Default for RequestOptions {
    fn default() -> Self {
        RequestOptions { max_new_tokens: 32, sampler: Sampler::Greedy, tier: None }
    }
}

impl RequestOptions {
    /// Convenience: this options set, pinned to a named serving tier.
    pub fn with_tier(mut self, tier: &str) -> RequestOptions {
        self.tier = Some(tier.to_string());
        self
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub opts: RequestOptions,
    /// Submission timestamp (for queueing-delay metrics).
    pub submitted_at: std::time::Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Time to first token (queue + prefill), ms.
    pub ttft_ms: f64,
    /// Total latency, ms.
    pub latency_ms: f64,
    /// Error message if the request failed.
    pub error: Option<String>,
}

/// A request paired with its reply channel — the unit that flows through
/// the batcher into the scheduler.
pub struct Job {
    pub request: Request,
    pub reply: std::sync::mpsc::Sender<Response>,
}

impl Response {
    pub fn failed(id: u64, err: impl Into<String>) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: vec![],
            prompt_tokens: 0,
            ttft_ms: 0.0,
            latency_ms: 0.0,
            error: Some(err.into()),
        }
    }

    pub fn generated_tokens(&self) -> usize {
        self.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let o = RequestOptions::default();
        assert_eq!(o.max_new_tokens, 32);
        assert!(matches!(o.sampler, Sampler::Greedy));
        assert!(o.tier.is_none(), "default tier is the model's default variant");
        assert_eq!(o.with_tier("lp").tier.as_deref(), Some("lp"));
    }

    #[test]
    fn failed_response_carries_error() {
        let r = Response::failed(7, "boom");
        assert_eq!(r.id, 7);
        assert_eq!(r.error.as_deref(), Some("boom"));
        assert_eq!(r.generated_tokens(), 0);
    }
}
