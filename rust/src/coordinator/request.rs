//! Request/response types crossing the coordinator boundary.

use crate::api::ApiError;
use crate::gen::Sampler;

#[derive(Clone, Debug)]
pub struct RequestOptions {
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Serving tier: names a plan variant of the model's manifest
    /// (`runtime::VariantId` — e.g. `dense`, `lp`, `lp_aggr`), selecting
    /// the speed/quality point this request is decoded at. `None` = the
    /// model's default tier. An unknown tier is rejected at admission,
    /// before any KV slot is claimed.
    pub tier: Option<String>,
}

impl Default for RequestOptions {
    fn default() -> Self {
        RequestOptions { max_new_tokens: 32, sampler: Sampler::Greedy, tier: None }
    }
}

impl RequestOptions {
    /// Convenience: this options set, pinned to a named serving tier.
    pub fn with_tier(mut self, tier: &str) -> RequestOptions {
        self.tier = Some(tier.to_string());
        self
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub opts: RequestOptions,
    /// Submission timestamp (for queueing-delay metrics).
    pub submitted_at: std::time::Instant,
}

/// One event on a request's reply stream. The scheduler sends a
/// [`TokenEvent::Token`] the moment each token is sampled and exactly one
/// terminal [`TokenEvent::Done`]; a dropped receiver cancels the request
/// at the next token boundary (the slot is reclaimed, the scheduler keeps
/// running).
#[derive(Clone, Debug)]
pub enum TokenEvent {
    Token {
        /// 0-based position in the completion.
        index: usize,
        /// The sampled token id.
        token: i32,
        /// The token decoded to text (may be empty for special tokens).
        text: String,
    },
    /// Terminal event: the full response (success or failure).
    Done(Response),
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// The serving tier that decoded this request (`None` on failures
    /// that never resolved a tier).
    pub tier: Option<String>,
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Time to first token (queue + prefill), ms.
    pub ttft_ms: f64,
    /// Total latency, ms.
    pub latency_ms: f64,
    /// Modelled (simulated-clock) TTFT, ms — deterministic twin of
    /// `ttft_ms`. Internal: consumed by `cluster::ClusterMetrics`, never
    /// serialized onto the wire (`api::CompletionResponse` omits it).
    pub modelled_ttft_ms: f64,
    /// Modelled (simulated-clock) end-to-end latency, ms (internal, same
    /// contract as `modelled_ttft_ms`).
    pub modelled_latency_ms: f64,
    /// Typed failure (stable `api::ErrorCode` + message) if the request
    /// did not complete.
    pub error: Option<ApiError>,
}

/// A request paired with its reply stream — the unit that flows through
/// the batcher into the scheduler.
pub struct Job {
    pub request: Request,
    pub reply: std::sync::mpsc::Sender<TokenEvent>,
}

impl Response {
    pub fn failed(id: u64, err: ApiError) -> Response {
        Response {
            id,
            tier: None,
            text: String::new(),
            tokens: vec![],
            prompt_tokens: 0,
            ttft_ms: 0.0,
            latency_ms: 0.0,
            modelled_ttft_ms: 0.0,
            modelled_latency_ms: 0.0,
            error: Some(err),
        }
    }

    /// The failure message, if any (convenience for assertion/logging
    /// sites that only care about the text).
    pub fn error_message(&self) -> Option<&str> {
        self.error.as_ref().map(|e| e.message.as_str())
    }

    pub fn generated_tokens(&self) -> usize {
        self.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ErrorCode;

    #[test]
    fn defaults() {
        let o = RequestOptions::default();
        assert_eq!(o.max_new_tokens, 32);
        assert!(matches!(o.sampler, Sampler::Greedy));
        assert!(o.tier.is_none(), "default tier is the model's default variant");
        assert_eq!(o.with_tier("lp").tier.as_deref(), Some("lp"));
    }

    #[test]
    fn failed_response_carries_typed_error() {
        let r = Response::failed(7, ApiError::new(ErrorCode::Overloaded, "boom"));
        assert_eq!(r.id, 7);
        assert_eq!(r.error.as_ref().unwrap().code, ErrorCode::Overloaded);
        assert_eq!(r.error_message(), Some("boom"));
        assert_eq!(r.generated_tokens(), 0);
        assert!(r.tier.is_none());
    }
}
