//! Continuous-batching scheduler: the decode loop at the heart of the
//! serving stack.
//!
//! Policy (vLLM-style chunked admission): each iteration first admits
//! waiting requests — resolving the request's serving **tier** to a plan
//! variant (`ServingModel::resolve_tier`; unknown tiers are rejected here)
//! and validating BOTH admission bounds (prefill-path prompt limit and ctx
//! generation budget) via `ServingModel::check_admission` *before* a KV
//! slot is claimed — then advances the pending-prefill queue by AT MOST
//! ONE chunk (`ServingModel::prefill_step`), then runs the decode round
//! across all fully-prefilled slots, samples each slot's next token, and
//! retires finished sequences.
//!
//! ## Per-request depth tiers
//!
//! Slots of different tiers coexist (KV caches are per-variant but share
//! the slot dimension), so the decode round groups the live slots by tier
//! and dispatches **one bucketed round per tier** (`decode_active_v`), in
//! deterministic `VariantId` order. Each tier's round is charged to the
//! cost model with that tier's own depth scale, and attributed per tier in
//! `ServerMetrics::tier_stats` — modelled tokens/sec per tier is the
//! speed/quality dial the registry exists for.
//!
//! Chunked streaming prefill is what keeps long prompts from stalling the
//! batch: a prompt of L tokens occupies the mesh for `ceil(L / K)` short
//! chunk steps spread over as many iterations, with a full decode round
//! for every live slot between consecutive chunks (see `model::prefill`).
//! The pending-prefill queue is served **round-robin**: the head prompt
//! advances one chunk, then rotates to the back, so several long prompts
//! make interleaved progress instead of one monopolizing the head-of-line
//! chunk (PR 3 follow-up — FIFO used to starve every later prefill until
//! the first prompt finished). On legacy manifests without chunk
//! executables, `prefill_step` degrades to the monolithic single-pass
//! prefill and the loop behaves exactly like the pre-chunking scheduler.
//! Slots being prefilled hold their KV reservation but are skipped by
//! `SlotManager::active_inputs` until their prompt is fully consumed.
//!
//! ## Modelled latency attribution
//!
//! Alongside wall-clock, the scheduler reads the mesh's simulated clock
//! (`MeshMetrics::modelled_total_ns` — roofline compute + α–β collectives
//! + host link, see `parallel::simnet`) and attributes deltas of it: each
//! request's modelled TTFT spans admission → first-token sampling (so
//! interleaved decode rounds and other prompts' chunks count as modelled
//! queueing delay), its modelled latency spans admission → retirement, and
//! every decode round / prefill chunk records its own modelled cost into
//! `ServerMetrics`. All of it is deterministic: two identical runs produce
//! bit-identical modelled timelines (`modelled_timeline_is_deterministic`).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use crate::api::ApiError;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::request::{Job, Request, Response, TokenEvent};
use crate::gen::Sampler;
use crate::model::kvcache::SlotManager;
use crate::model::prefill::ChunkedPrefill;
use crate::model::serving::ActiveSlot;
use crate::model::ServingModel;
use crate::obs::{Tracer, Track};
use crate::runtime::VariantId;
use crate::text::tokenizer::{self, EOS};
use crate::util::rng::SplitMix64;

struct InFlight {
    request: Request,
    reply: Sender<TokenEvent>,
    tokens: Vec<i32>,
    /// Prompt length in tokens, recorded once at admit time (re-encoding
    /// the prompt at completion just to count it was a hot-path bug).
    prompt_tokens: usize,
    /// The serving tier this request decodes at (resolved at admission;
    /// decode rounds group slots by this).
    variant: VariantId,
    ttft_ms: f64,
    /// Simulated-clock reading at admission (see `MeshMetrics::
    /// modelled_total_ns`); deltas of the clock attribute modelled
    /// latency to this request.
    modelled_start_ns: u64,
    /// Modelled admission→first-token latency, fixed when prefill
    /// completed.
    modelled_ttft_ms: f64,
    sampler: Sampler,
    rng: SplitMix64,
}

/// A request that passed validation but cannot map its KV pages *right
/// now* (paged pools transiently full): parked on the batcher's
/// back-pressure seam instead of rejected, re-tried FIFO each iteration
/// once siblings retire and free pages.
struct Parked {
    request: Request,
    reply: Sender<TokenEvent>,
    /// Encoded prompt (kept so retries never re-tokenize).
    ids: Vec<i32>,
    /// Serving tier, already resolved at validation time.
    vid: VariantId,
}

/// An admitted request whose prompt is still streaming into its KV slot,
/// one chunk per scheduler iteration.
struct PendingPrefill {
    state: ChunkedPrefill,
    request: Request,
    reply: Sender<TokenEvent>,
    sampler: Sampler,
    prompt_tokens: usize,
    /// Simulated-clock reading at admission; the request's modelled TTFT
    /// spans from here to the sampling of its first token, so time spent
    /// in interleaved decode rounds counts as modelled queueing delay.
    modelled_start_ns: u64,
}

pub struct Scheduler {
    model: ServingModel,
    slots: SlotManager,
    inflight: HashMap<usize, InFlight>, // slot -> request state
    /// Admitted-but-still-prefilling requests, served round-robin: the
    /// head advances one chunk per iteration, then rotates to the back,
    /// so several long prompts interleave instead of serializing.
    pending: VecDeque<PendingPrefill>,
    /// Validated requests waiting out transient paged-KV pool pressure
    /// (see [`Parked`]); strictly FIFO — the head admits first or nobody
    /// does, so a small request can never starve a parked large one.
    parked: VecDeque<Parked>,
    metrics: Arc<ServerMetrics>,
    /// Optional span recorder (`crate::obs`): when set, the scheduler
    /// emits request-lifecycle spans on the simulated clock and the mesh
    /// recorder is armed so dispatch/collective events land on the mesh
    /// track (drained by [`Scheduler::flush_mesh_trace`]).
    tracer: Option<Arc<Tracer>>,
}

impl Scheduler {
    pub fn new(model: ServingModel, metrics: Arc<ServerMetrics>) -> Scheduler {
        Scheduler::with_tracer(model, metrics, None)
    }

    /// Like [`Scheduler::new`], but recording spans into `tracer`; also
    /// arms the mesh's event recorder so the trace gets a mesh track.
    pub fn with_tracer(
        model: ServingModel,
        metrics: Arc<ServerMetrics>,
        tracer: Option<Arc<Tracer>>,
    ) -> Scheduler {
        if tracer.is_some() {
            model.mesh.begin_trace();
        }
        let cfg = &model.entry.config;
        let slots = SlotManager::new(cfg.slots, cfg.ctx);
        Scheduler {
            model,
            slots,
            inflight: HashMap::new(),
            pending: VecDeque::new(),
            parked: VecDeque::new(),
            metrics,
            tracer,
        }
    }

    pub fn model(&self) -> &ServingModel {
        &self.model
    }

    /// The mesh's simulated clock (total modelled ns so far) — the time
    /// base for all modelled latency attribution below.
    fn modelled_clock_ns(&self) -> u64 {
        self.model.mesh.metrics.modelled_total_ns()
    }

    /// Drain the mesh's timed event log into the tracer (no-op without
    /// one). Called once when the run loop exits; draining disarms the
    /// mesh recorder, so this must come after the last dispatch.
    pub fn flush_mesh_trace(&self) {
        if let Some(tr) = &self.tracer {
            tr.record_mesh_events(self.model.mesh.take_timed_trace());
        }
    }

    /// Run until the batcher closes and all in-flight work drains.
    pub fn run(&mut self, batcher: &Batcher, batch_wait: Duration) {
        loop {
            let free = self.slots.free_count();
            let idle = self.is_idle();
            // Block on the queue only when idle; when working, poll.
            let wait = if idle {
                Duration::from_millis(50)
            } else {
                batch_wait.min(Duration::from_millis(1))
            };
            let admitted = if free > 0 { batcher.drain(free, wait) } else { vec![] };
            for job in admitted {
                self.admit(job);
            }
            if self.is_idle() {
                if batcher.is_closed() && batcher.is_empty() {
                    self.flush_mesh_trace();
                    return;
                }
                continue;
            }
            self.tick();
        }
    }

    /// No admitted work anywhere: nothing parked, prefilling, or decoding.
    pub fn is_idle(&self) -> bool {
        self.inflight.is_empty() && self.pending.is_empty() && self.parked.is_empty()
    }

    /// Requests this scheduler has accepted but not yet retired (parked +
    /// prefilling + decoding) — the replica-local half of the cluster
    /// router's backlog signal (the other half is the batcher's queue).
    pub fn admitted_len(&self) -> usize {
        self.parked.len() + self.pending.len() + self.inflight.len()
    }

    /// One lockstep iteration for an external driver (the cluster): drain
    /// up to the free-slot count from `batcher` *without blocking*, admit,
    /// and run one tick when any work exists. Returns `true` while
    /// admitted work remains. Single-threaded by construction — the
    /// cluster steps its replicas in index order, which is what makes
    /// multi-replica runs bit-reproducible.
    pub fn step(&mut self, batcher: &Batcher) -> bool {
        let free = self.slots.free_count();
        let admitted = if free > 0 { batcher.drain(free, Duration::ZERO) } else { vec![] };
        for job in admitted {
            self.admit(job);
        }
        if self.is_idle() {
            return false;
        }
        self.tick();
        !self.is_idle()
    }

    /// Fence support (cluster drain): strip EVERY accepted-but-unfinished
    /// request — parked, mid-prefill, and in-flight — out of the
    /// scheduler, releasing their slots and pages, and hand them back as
    /// re-submittable [`Job`]s in admission (request-id) order. In-flight
    /// requests may already have streamed tokens; a sibling re-runs them
    /// from scratch and — sampling being deterministic per request id —
    /// re-emits the identical stream, which the cluster's per-request
    /// pump dedups by index contiguity. Zero requests are lost: every
    /// ejected job keeps its original reply channel.
    pub fn eject_all(&mut self) -> Vec<Job> {
        let mut jobs = Vec::new();
        for p in std::mem::take(&mut self.parked) {
            jobs.push(Job { request: p.request, reply: p.reply });
        }
        for p in std::mem::take(&mut self.pending) {
            let slot = p.state.slot();
            self.release_slot(slot);
            jobs.push(Job { request: p.request, reply: p.reply });
        }
        let mut slots: Vec<usize> = self.inflight.keys().copied().collect();
        slots.sort_unstable();
        for slot in slots {
            let inf = self.inflight.remove(&slot).unwrap();
            self.release_slot(slot);
            jobs.push(Job { request: inf.request, reply: inf.reply });
        }
        jobs.sort_by_key(|j| j.request.id);
        jobs
    }

    /// One scheduler iteration: at most one prefill chunk for the head of
    /// the pending queue, then one batched decode round over every live
    /// (fully prefilled) slot. The interleaving contract: a long prompt
    /// adds `ceil(L / K)` iterations, and every one of them still decodes
    /// all live slots.
    fn tick(&mut self) {
        self.retry_parked();
        self.step_pending_prefill();
        self.decode_round();
    }

    /// Re-try parked requests, FIFO: admit from the head while a slot is
    /// free and the head's pages map right now; stop at the first that
    /// still must wait (never skip ahead — a small request queued behind a
    /// large one would otherwise starve it forever). Livelock-free: once
    /// everything in flight retires, every claimed page is either free or
    /// index-held (evictable), so any request that passed the `fits`
    /// check becomes admissible.
    fn retry_parked(&mut self) {
        loop {
            let Some(head) = self.parked.front() else { return };
            if self.slots.free_count() == 0 {
                return;
            }
            let must_wait = self.model.admission_must_wait_v(
                &head.vid,
                head.ids.len(),
                head.request.opts.max_new_tokens,
            );
            if must_wait {
                return;
            }
            let p = self.parked.pop_front().unwrap();
            self.admit_ready(p.request, p.reply, p.ids, p.vid);
        }
    }

    /// Validate + claim a slot + enqueue the prompt for chunked prefill.
    /// The serving tier and both admission bounds are checked before the
    /// slot is touched, so a rejected request — unknown tier included —
    /// never occupies (or churns) KV state.
    fn admit(&mut self, job: Job) {
        let Job { request, reply } = job;
        let ids = tokenizer::encode(&request.prompt, true, false);
        let max_new = request.opts.max_new_tokens;
        let vid = match self.model.resolve_tier(request.opts.tier.as_deref()) {
            Ok(v) => v,
            Err(e) => {
                self.metrics
                    .requests_rejected
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.trace_reject(request.id, &e.to_string());
                let _ =
                    reply.send(TokenEvent::Done(Response::failed(request.id, ApiError::from(&e))));
                return;
            }
        };
        if let Err(e) = self.model.check_admission_v(&vid, ids.len(), max_new) {
            self.metrics
                .requests_rejected
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.trace_reject(request.id, &e.to_string());
            let _ = reply.send(TokenEvent::Done(Response::failed(request.id, ApiError::from(&e))));
            return;
        }
        // Back-pressure seam: the request CAN fit the pools eventually
        // (check_admission_v passed) but not right now — park it instead
        // of rejecting; retry_parked re-admits it once pages free.
        if !self.parked.is_empty()
            || self.model.admission_must_wait_v(&vid, ids.len(), max_new)
        {
            self.metrics
                .admission_waits
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if let Some(tr) = &self.tracer {
                tr.instant(
                    Track::Scheduler,
                    "parked",
                    self.modelled_clock_ns(),
                    &[("request", request.id.to_string()), ("tier", vid.to_string())],
                );
            }
            self.parked.push_back(Parked { request, reply, ids, vid });
            return;
        }
        self.admit_ready(request, reply, ids, vid);
    }

    /// Second half of admission: claim a slot and begin the chunked
    /// prefill. Callers have already validated the request (tier + both
    /// admission bounds) and established that its pages map now.
    fn admit_ready(
        &mut self,
        request: Request,
        reply: Sender<TokenEvent>,
        ids: Vec<i32>,
        vid: VariantId,
    ) {
        let max_new = request.opts.max_new_tokens;
        let sampler = request.opts.sampler.clone();
        let slot = match self.slots.alloc(request.id, ids.len(), max_new, 0) {
            Ok(s) => s,
            Err(e) => {
                let _ =
                    reply.send(TokenEvent::Done(Response::failed(request.id, ApiError::from(&e))));
                return;
            }
        };
        // admission passed and a slot is now held: the alloc/free churn
        // counter is what the 429 load-shed test asserts stays flat on
        // rejected requests
        self.metrics.slot_allocs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let state = match self.model.begin_prefill_v(&vid, slot, &ids) {
            Ok(st) => st,
            Err(e) => {
                self.release_slot(slot);
                let _ =
                    reply.send(TokenEvent::Done(Response::failed(request.id, ApiError::from(&e))));
                return;
            }
        };
        self.slots.set_prefilling(slot, true);
        let modelled_start_ns = self.modelled_clock_ns();
        if let Some(tr) = &self.tracer {
            tr.instant(
                Track::Slot(slot),
                "admit",
                modelled_start_ns,
                &[
                    ("request", request.id.to_string()),
                    ("tier", vid.to_string()),
                    ("prompt_tokens", ids.len().to_string()),
                ],
            );
        }
        self.pending.push_back(PendingPrefill {
            state,
            request,
            reply,
            sampler,
            prompt_tokens: ids.len(),
            modelled_start_ns,
        });
    }

    /// Free a KV slot AND (under paging) return the slot's private pages
    /// to the pool — every scheduler path that gives a slot back goes
    /// through here, so a retired or failed request can never leak pages.
    /// Prefix blocks published to the shared index stay alive (the index
    /// holds its own references) until evicted under pressure.
    fn release_slot(&mut self, slot: usize) {
        self.slots.free(slot);
        self.model.release_pages(slot);
    }

    /// Mark a rejection on the scheduler track (admission control is a
    /// scheduler decision, not tied to any slot).
    fn trace_reject(&self, request_id: u64, error: &str) {
        if let Some(tr) = &self.tracer {
            tr.instant(
                Track::Scheduler,
                "reject",
                self.modelled_clock_ns(),
                &[("request", request_id.to_string()), ("error", error.to_string())],
            );
        }
    }

    /// Advance the head pending prefill by one chunk, then rotate it to
    /// the back of the queue (round-robin fairness: with several long
    /// prompts pending, each gets every len(pending)-th chunk slot instead
    /// of the first prompt monopolizing the head of the line). On
    /// completion the request samples its first token and joins the decode
    /// batch from the same iteration onward.
    fn step_pending_prefill(&mut self) {
        let Some(mut head) = self.pending.pop_front() else { return };
        let first_chunk = head.state.consumed() == 0;
        let clock0 = self.model.mesh.metrics.modelled_total_ns();
        let step = self.model.prefill_step(&mut head.state);
        let clock1 = self.model.mesh.metrics.modelled_total_ns();
        self.metrics.record_prefill_step(clock1 - clock0);
        if let Some(tr) = &self.tracer {
            let slot = head.state.slot();
            let req = head.request.id.to_string();
            if first_chunk {
                // admission → first chunk: time spent waiting behind other
                // prompts' chunks and interleaved decode rounds
                tr.span(
                    Track::Slot(slot),
                    "queued",
                    head.modelled_start_ns,
                    clock0,
                    &[("request", req.clone())],
                );
            }
            tr.span(
                Track::Slot(slot),
                "prefill_chunk",
                clock0,
                clock1,
                &[
                    ("request", req),
                    ("tier", head.state.variant().to_string()),
                    ("consumed", format!("{}/{}", head.state.consumed(), head.prompt_tokens)),
                ],
            );
        }
        match step {
            // chunk consumed; the NEXT pending prompt gets the next
            // iteration's chunk slot
            Ok(None) => self.pending.push_back(head),
            Ok(Some(logits)) => {
                let p = head;
                let slot = p.state.slot();
                let variant = p.state.variant().clone();
                self.metrics
                    .prefill_tokens
                    .fetch_add(p.prompt_tokens as u64, std::sync::atomic::Ordering::Relaxed);
                let mut rng = SplitMix64::new(p.request.id ^ 0x5eed);
                let first = p.sampler.sample(&logits, &mut rng);
                let ttft_ms = p.request.submitted_at.elapsed().as_secs_f64() * 1e3;
                // Admission → first token on the simulated clock: covers
                // this request's own chunk steps plus every decode round
                // and other-prompt chunk interleaved since admit.
                let modelled_ttft_ms = (clock1 - p.modelled_start_ns) as f64 / 1e6;
                if let Some(tr) = &self.tracer {
                    tr.instant(
                        Track::Slot(slot),
                        "first_token",
                        clock1,
                        &[
                            ("request", p.request.id.to_string()),
                            ("tier", variant.to_string()),
                            ("modelled_ttft_ms", format!("{modelled_ttft_ms:.3}")),
                        ],
                    );
                }
                self.slots.set_prefilling(slot, false);
                self.slots.get_mut(slot).unwrap().next_token = first;
                self.inflight.insert(
                    slot,
                    InFlight {
                        request: p.request,
                        reply: p.reply,
                        tokens: vec![],
                        prompt_tokens: p.prompt_tokens,
                        variant,
                        ttft_ms,
                        modelled_start_ns: p.modelled_start_ns,
                        modelled_ttft_ms,
                        sampler: p.sampler,
                        rng,
                    },
                );
            }
            Err(e) => {
                self.release_slot(head.state.slot());
                if let Some(tr) = &self.tracer {
                    tr.instant(
                        Track::Scheduler,
                        "prefill_failed",
                        clock1,
                        &[("request", head.request.id.to_string()), ("error", e.to_string())],
                    );
                }
                let _ = head.reply.send(TokenEvent::Done(Response::failed(
                    head.request.id,
                    ApiError::from(&e).context("prefill failed"),
                )));
            }
        }
    }

    fn decode_round(&mut self) {
        // Compacted batch: only active slots cross the executor boundary;
        // decode_active_v dispatches them at bucket granularity (the
        // device computes — and downloads — the covering bucket, not all
        // [S] lanes; see runtime::buckets). Slots mid-prefill are skipped.
        // Slots are grouped by serving tier: one bucketed dispatch per
        // plan variant per round, in deterministic VariantId order, each
        // charged at ITS depth scale and attributed per tier.
        let active = self.slots.active_inputs();
        if active.is_empty() {
            return;
        }
        let mut groups: BTreeMap<VariantId, Vec<ActiveSlot>> = BTreeMap::new();
        for lane in active {
            let Some(inf) = self.inflight.get(&lane.0) else { continue };
            groups.entry(inf.variant.clone()).or_default().push(lane);
        }
        for (vid, lanes) in groups {
            let clock0 = self.modelled_clock_ns();
            let rows = match self.model.decode_active_v(&vid, &lanes) {
                Ok(r) => r,
                // Failure isolation: a batch error must not fail every
                // in-flight request. Retry each live slot alone; only the
                // slots that still fail are drained, the rest keep
                // decoding.
                Err(e) => self.decode_round_isolated(&vid, &lanes, &e),
            };
            // Rounds that produced nothing (every slot failed) don't count
            // as decode steps, matching the pre-isolation accounting;
            // after a partial failure only the lanes that actually
            // produced a row count toward the occupancy histogram.
            if !rows.is_empty() {
                let clock1 = self.modelled_clock_ns();
                let modelled_ns = clock1 - clock0;
                self.metrics.record_decode_round(rows.len(), modelled_ns);
                self.metrics.record_tier_round(vid.as_str(), rows.len(), modelled_ns);
                if let Some(tr) = &self.tracer {
                    tr.span(
                        Track::Tier(vid.as_str().to_string()),
                        "decode_round",
                        clock0,
                        clock1,
                        &[("tier", vid.to_string()), ("live", rows.len().to_string())],
                    );
                }
            }
            for (slot, row) in rows {
                self.apply_sampled_row(slot, &row);
            }
        }
        // surface exec-cache pressure (non-zero only under a
        // `[runtime] max_cached_execs` cap)
        self.metrics.exec_cache_evictions.store(
            self.model.exec_cache().stats().evictions,
            std::sync::atomic::Ordering::Relaxed,
        );
        // surface paged-KV pressure + prefix-reuse counters (None while
        // paging is off); mirrored after retirement handling so the gauge
        // reflects the post-release page population
        if let Some(ks) = self.model.kv_stats() {
            self.metrics.record_kv_stats(&ks);
        }
    }

    /// Per-slot fallback after a batched decode error: decode each live
    /// slot of the tier in its own round (the B=1 bucket), failing only
    /// the slots whose single-lane step also errors. Returns the
    /// successfully decoded rows.
    fn decode_round_isolated(
        &mut self,
        vid: &VariantId,
        active: &[ActiveSlot],
        batch_err: &crate::Error,
    ) -> Vec<(usize, Vec<f32>)> {
        let mut rows = Vec::new();
        for &lane in active {
            match self.model.decode_active_v(vid, &[lane]) {
                Ok(mut r) => rows.append(&mut r),
                Err(e) => {
                    let slot = lane.0;
                    self.release_slot(slot);
                    if let Some(inf) = self.inflight.remove(&slot) {
                        let api = ApiError::new(
                            ApiError::from(&e).code,
                            format!("decode failed: {e} (batch round failed: {batch_err})"),
                        );
                        let _ = inf
                            .reply
                            .send(TokenEvent::Done(Response::failed(inf.request.id, api)));
                    }
                }
            }
        }
        rows
    }

    /// Fold one sampled logits row back into its slot: extend the output,
    /// stream the token to the caller, sample the next token, retire the
    /// sequence if finished.
    fn apply_sampled_row(&mut self, slot: usize, row: &[f32]) {
        let Some(inf) = self.inflight.get_mut(&slot) else { return };
        // The token just processed at `pos` becomes output history.
        let current = self.slots.get(slot).unwrap().next_token;
        inf.tokens.push(current);
        // Stream the token the moment it exists — this is the feed the
        // HTTP edge serves as SSE. A failed send means the caller dropped
        // its handle (client disconnect): cancel at this token boundary,
        // reclaim the slot, and keep the scheduler running.
        let sent = inf.reply.send(TokenEvent::Token {
            index: inf.tokens.len() - 1,
            token: current,
            text: tokenizer::decode(&[current]),
        });
        if sent.is_err() {
            let inf = self.inflight.remove(&slot).unwrap();
            self.release_slot(slot);
            self.metrics
                .requests_cancelled
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if let Some(tr) = &self.tracer {
                tr.instant(
                    Track::Slot(slot),
                    "cancelled",
                    self.modelled_clock_ns(),
                    &[
                        ("request", inf.request.id.to_string()),
                        ("tokens", inf.tokens.len().to_string()),
                    ],
                );
            }
            return;
        }
        let next = inf.sampler.sample(row, &mut inf.rng);
        let done = self.slots.advance(slot, next, EOS);
        if done {
            let inf = self.inflight.remove(&slot).unwrap();
            self.release_slot(slot);
            let latency = inf.request.submitted_at.elapsed().as_secs_f64() * 1e3;
            let end_ns = self.modelled_clock_ns();
            let modelled_latency_ms = (end_ns - inf.modelled_start_ns) as f64 / 1e6;
            if let Some(tr) = &self.tracer {
                // the whole request as one span: admission → retirement
                tr.span(
                    Track::Slot(slot),
                    format!("req {}", inf.request.id),
                    inf.modelled_start_ns,
                    end_ns,
                    &[
                        ("request", inf.request.id.to_string()),
                        ("tier", inf.variant.to_string()),
                        ("prompt_tokens", inf.prompt_tokens.to_string()),
                        ("tokens", inf.tokens.len().to_string()),
                        ("modelled_ttft_ms", format!("{:.3}", inf.modelled_ttft_ms)),
                    ],
                );
            }
            self.metrics.record_completion(
                inf.ttft_ms,
                latency,
                inf.tokens.len(),
                inf.modelled_ttft_ms,
                modelled_latency_ms,
            );
            let _ = inf.reply.send(TokenEvent::Done(Response {
                id: inf.request.id,
                tier: Some(inf.variant.as_str().to_string()),
                text: tokenizer::decode(&inf.tokens),
                prompt_tokens: inf.prompt_tokens,
                tokens: inf.tokens,
                ttft_ms: inf.ttft_ms,
                latency_ms: latency,
                modelled_ttft_ms: inf.modelled_ttft_ms,
                modelled_latency_ms,
                error: None,
            }));
        }
    }
}

impl ServingModel {
    /// Allocate a slot + prefill as one transaction (slot freed on error).
    /// Single-shot path for callers outside the scheduler loop (benches,
    /// tests); the scheduler itself streams chunks via `begin_prefill` /
    /// `prefill_step` so decode rounds can interleave.
    pub fn prefill_slot_checked(
        &self,
        slots: &mut SlotManager,
        request_id: u64,
        ids: &[i32],
        max_new: usize,
    ) -> crate::Result<(usize, Vec<f32>)> {
        self.check_admission(ids.len(), max_new)?;
        let slot = slots.alloc(request_id, ids.len(), max_new, 0)?;
        match self.prefill_chunked(slot, ids) {
            Ok(logits) => Ok((slot, logits)),
            Err(e) => {
                slots.free(slot);
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::InterconnectConfig;
    use crate::coordinator::request::RequestOptions;
    use crate::model::{transform, Weights};
    use crate::runtime::Manifest;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Instant;

    fn build() -> Option<ServingModel> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 23);
        let plan = transform::pair_parallel(cfg.n_layers, 2, 10, true);
        let net = InterconnectConfig { enabled: false, ..Default::default() };
        ServingModel::new(&manifest, "td-small", &weights, &plan, net).ok()
    }

    /// Multi-variant build over the manifest's registry (None when the
    /// artifacts predate the `variants` section).
    fn build_multi() -> Option<ServingModel> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 23);
        let net = InterconnectConfig { enabled: false, ..Default::default() };
        let m = ServingModel::from_manifest(&manifest, "td-small", &weights, net).ok()?;
        (m.variant_ids().len() >= 3).then_some(m)
    }

    fn job_opts(
        id: u64,
        prompt: &str,
        opts: RequestOptions,
    ) -> (Job, Receiver<TokenEvent>) {
        let (tx, rx) = channel();
        (
            Job {
                request: Request {
                    id,
                    prompt: prompt.into(),
                    opts,
                    submitted_at: Instant::now(),
                },
                reply: tx,
            },
            rx,
        )
    }

    fn job(id: u64, prompt: &str, max_new: usize) -> (Job, Receiver<TokenEvent>) {
        job_opts(
            id,
            prompt,
            RequestOptions { max_new_tokens: max_new, sampler: Sampler::Greedy, tier: None },
        )
    }

    /// Drain whatever the stream already holds; `Some` once the terminal
    /// `Done` event has arrived. Along the way, checks that streamed
    /// token events agree with the final response (index-contiguous, same
    /// token ids) — the streaming protocol's core invariant.
    fn final_response(rx: &Receiver<TokenEvent>) -> Option<Response> {
        let mut streamed = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            match ev {
                TokenEvent::Token { index, token, .. } => {
                    assert_eq!(index, streamed.len(), "token indices must be contiguous");
                    streamed.push(token);
                }
                TokenEvent::Done(r) => {
                    if r.error.is_none() {
                        assert_eq!(streamed, r.tokens, "stream must match the final response");
                    }
                    return Some(r);
                }
            }
        }
        None
    }

    /// The interleaving contract in numbers: while a long prompt streams
    /// in chunk by chunk, the already-live request keeps producing exactly
    /// one token per iteration — no full-prompt stall.
    #[test]
    fn decode_rounds_proceed_between_prefill_chunks() {
        let Some(model) = build() else { return };
        let Some(k) = model.prefill_chunk() else { return };
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics);

        // short prompt A: BOS + 2 bytes = 3 tokens -> a single chunk
        let (job_a, _rx_a) = job(1, "hi", 32);
        sched.admit(job_a);
        assert_eq!(sched.pending.len(), 1);
        sched.tick(); // A finishes prefill and decodes its first token
        assert!(sched.pending.is_empty());
        assert_eq!(sched.inflight.len(), 1);
        let slot_a = *sched.inflight.keys().next().unwrap();
        let a_before = sched.inflight[&slot_a].tokens.len();

        // long prompt B: BOS + 100 bytes = 101 tokens -> 4 chunks of 32
        let long = "y".repeat(100);
        let (job_b, _rx_b) = job(2, &long, 8);
        sched.admit(job_b);
        let chunks = (100 + 1usize).div_ceil(k);
        assert!(chunks > 1, "prompt must span several chunks for this test");
        for i in 0..chunks {
            assert_eq!(sched.pending.len(), 1, "B done prefilling early, at tick {i}");
            sched.tick();
        }
        assert!(sched.pending.is_empty(), "B should be live after {chunks} chunks");
        assert_eq!(sched.inflight.len(), 2);
        let a_after = sched.inflight[&slot_a].tokens.len();
        assert_eq!(
            a_after - a_before,
            chunks,
            "A must decode one token per iteration while B's prompt streams in"
        );
    }

    /// The cost-model acceptance criterion end to end: two identical
    /// scheduler runs must produce bit-identical modelled timelines —
    /// the simulated clock, every per-request modelled TTFT/latency, and
    /// the per-round decode/prefill accounting. Wall-clock fields are
    /// explicitly NOT compared (they are load-dependent by nature).
    #[test]
    fn modelled_timeline_is_deterministic() {
        #[derive(Debug, PartialEq)]
        struct Timeline {
            clock_ns: u64,
            decode_ns: u64,
            prefill_ns: u64,
            ttft_ms: Vec<f64>,
            latency_ms: Vec<f64>,
            occupancy: Vec<u64>,
        }
        let run = || -> Option<Timeline> {
            let model = build()?;
            let metrics = Arc::new(ServerMetrics::default());
            let mut sched = Scheduler::new(model, metrics.clone());
            let mut replies = Vec::new();
            for (id, prompt, max_new) in [
                (1u64, "the red fox", 3usize),
                (2, "a longer prompt, still admissible", 2),
                (3, "hi", 4),
            ] {
                let (j, rx) = job(id, prompt, max_new);
                sched.admit(j);
                replies.push(rx);
            }
            // drive to quiescence (every request retires)
            for _ in 0..200 {
                if sched.inflight.is_empty() && sched.pending.is_empty() {
                    break;
                }
                sched.tick();
            }
            assert!(sched.inflight.is_empty() && sched.pending.is_empty());
            for rx in replies {
                let r = final_response(&rx).expect("request must have completed");
                assert!(r.error.is_none(), "{:?}", r.error);
            }
            // the modelled reservoirs, read through the sorted summaries:
            // min/p50/p99/max pin the full 3-sample distributions exactly
            let mt = metrics.modelled_ttft_summary().unwrap();
            let ml = metrics.modelled_latency_summary().unwrap();
            Some(Timeline {
                clock_ns: sched.model.mesh.metrics.modelled_total_ns(),
                decode_ns: metrics
                    .modelled_decode_ns
                    .load(std::sync::atomic::Ordering::Relaxed),
                prefill_ns: metrics
                    .modelled_prefill_ns
                    .load(std::sync::atomic::Ordering::Relaxed),
                ttft_ms: vec![mt.min, mt.p50, mt.p99, mt.max],
                latency_ms: vec![ml.min, ml.p50, ml.p99, ml.max],
                occupancy: metrics.occupancy_histogram(),
            })
        };
        let Some(a) = run() else { return };
        let b = run().unwrap();
        assert!(a.clock_ns > 0, "clock never ticked");
        assert!(a.decode_ns > 0 && a.prefill_ns > 0, "rounds must be attributed");
        assert_eq!(a, b, "two identical runs must tick the clock identically");
    }

    /// Round-robin fairness (PR 3 follow-up): with several long prompts
    /// pending, each gets every len(pending)-th chunk — one prompt can no
    /// longer starve the others' head-of-line chunk.
    #[test]
    fn pending_prefills_round_robin_one_chunk_each() {
        let Some(model) = build() else { return };
        let Some(k) = model.prefill_chunk() else { return };
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics);

        // two long prompts, each spanning several chunks
        let long = "y".repeat(3 * k);
        let (job_a, _rx_a) = job(1, &long, 4);
        let (job_b, _rx_b) = job(2, &long, 4);
        sched.admit(job_a);
        sched.admit(job_b);
        assert_eq!(sched.pending.len(), 2);

        // tick 1 advances A one chunk and rotates it behind B; tick 2
        // advances B — after two ticks BOTH have consumed exactly one chunk
        sched.tick();
        sched.tick();
        let consumed: Vec<usize> =
            sched.pending.iter().map(|p| p.state.consumed()).collect();
        assert_eq!(consumed, vec![k, k], "chunks must interleave across prompts");

        // drive to completion: both prompts finish despite interleaving
        for _ in 0..50 {
            if sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        assert!(sched.pending.is_empty());
        assert_eq!(sched.inflight.len(), 2);
    }

    /// Tentpole: one scheduler serves concurrent requests on three tiers
    /// from one manifest — each decode round dispatches once per tier, the
    /// per-tier attribution is populated, and two identical runs produce
    /// bit-identical modelled timelines and tokens (mixed-tier rounds are
    /// deterministic).
    #[test]
    fn mixed_tier_rounds_are_deterministic_and_tier_attributed() {
        #[derive(Debug, PartialEq)]
        struct Outcome {
            tiers: Vec<(String, crate::coordinator::metrics::TierStats)>,
            tokens: Vec<Vec<i32>>,
            clock_ns: u64,
        }
        let run = || -> Option<Outcome> {
            let model = build_multi()?;
            let metrics = Arc::new(ServerMetrics::default());
            let mut sched = Scheduler::new(model, metrics.clone());
            let mut replies = Vec::new();
            for (id, tier) in [(1u64, "dense"), (2, "lp"), (3, "lp_aggr")] {
                let opts = RequestOptions {
                    max_new_tokens: 3,
                    sampler: Sampler::Greedy,
                    tier: Some(tier.to_string()),
                };
                let (j, rx) = job_opts(id, "the red fox", opts);
                sched.admit(j);
                replies.push(rx);
            }
            for _ in 0..100 {
                if sched.inflight.is_empty() && sched.pending.is_empty() {
                    break;
                }
                sched.tick();
            }
            assert!(sched.inflight.is_empty() && sched.pending.is_empty());
            let mut tokens = Vec::new();
            for rx in replies {
                let r = final_response(&rx).expect("request must have completed");
                assert!(r.error.is_none(), "{:?}", r.error);
                assert_eq!(r.generated_tokens(), 3);
                tokens.push(r.tokens);
            }
            Some(Outcome {
                tiers: metrics.tier_stats(),
                tokens,
                clock_ns: sched.model.mesh.metrics.modelled_total_ns(),
            })
        };
        let Some(a) = run() else { return };
        let names: Vec<&str> = a.tiers.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["dense", "lp", "lp_aggr"], "all three tiers must decode");
        for (name, st) in &a.tiers {
            assert_eq!(st.tokens, 3, "tier {name} decodes its request's tokens");
            assert!(st.rounds >= 3 && st.modelled_ns > 0, "tier {name}: {st:?}");
        }
        let b = run().unwrap();
        assert_eq!(a, b, "mixed-tier rounds must be deterministic (clock, tokens, tiers)");
        assert!(a.clock_ns > 0, "clock never ticked");
    }

    /// Tentpole acceptance: the export layer inherits the modelled
    /// determinism — two identical mixed-tier scheduler runs emit
    /// byte-identical Chrome trace JSON and metrics snapshots, and the
    /// trace carries per-request spans with tier attributes plus
    /// mesh-track collective events.
    #[test]
    fn trace_and_snapshot_exports_are_byte_identical() {
        use crate::obs::MetricsSnapshot;
        let run = || -> Option<(String, String)> {
            let model = build_multi()?;
            let metrics = Arc::new(ServerMetrics::default());
            let tracer = Arc::new(Tracer::new());
            let mut sched = Scheduler::with_tracer(model, metrics.clone(), Some(tracer.clone()));
            let mut replies = Vec::new();
            for (id, tier) in [(1u64, "dense"), (2, "lp"), (3, "lp_aggr")] {
                let opts = RequestOptions {
                    max_new_tokens: 3,
                    sampler: Sampler::Greedy,
                    tier: Some(tier.to_string()),
                };
                let (j, rx) = job_opts(id, "the red fox", opts);
                sched.admit(j);
                replies.push(rx);
            }
            for _ in 0..100 {
                if sched.inflight.is_empty() && sched.pending.is_empty() {
                    break;
                }
                sched.tick();
            }
            assert!(sched.inflight.is_empty() && sched.pending.is_empty());
            sched.flush_mesh_trace();
            let trace = tracer.to_chrome_json().to_string_pretty();
            let snap = MetricsSnapshot::new("test")
                .with_server(&metrics)
                .with_mesh(&sched.model.mesh.metrics)
                .to_string_pretty();
            Some((trace, snap))
        };
        let Some((trace_a, snap_a)) = run() else { return };
        // the trace parses as trace-event JSON and carries the spans the
        // acceptance criteria name
        let doc = crate::util::json::Value::parse(&trace_a).unwrap();
        assert!(doc.get("traceEvents").is_some());
        assert!(trace_a.contains("\"req 1\""), "per-request span missing");
        assert!(trace_a.contains("\"decode_round\""), "tier decode spans missing");
        assert!(trace_a.contains("\"tier\": \"lp_aggr\""), "tier attribute missing");
        assert!(trace_a.contains("\"first_token\""), "first-token instant missing");
        assert!(trace_a.contains("\"cat\": \"mesh\""), "mesh track missing");
        assert!(
            trace_a.contains("reduce_into") || trace_a.contains("all_reduce"),
            "mesh collective events missing"
        );
        assert!(snap_a.contains(MetricsSnapshot::SCHEMA));
        assert!(snap_a.contains("\"tiers\"") && snap_a.contains("\"mesh\""));
        let (trace_b, snap_b) = run().unwrap();
        assert_eq!(trace_a, trace_b, "identical runs must emit byte-identical traces");
        assert_eq!(snap_a, snap_b, "identical runs must emit byte-identical snapshots");
    }

    /// Satellite: a tier the manifest does not carry is rejected at
    /// admission — immediately, with the available tiers named, and with
    /// zero slot churn.
    #[test]
    fn unknown_tier_rejected_at_admission_without_slot_churn() {
        let Some(model) = build_multi() else { return };
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics.clone());
        let free_before = sched.slots.free_count();

        let opts = RequestOptions {
            max_new_tokens: 4,
            sampler: Sampler::Greedy,
            tier: Some("turbo".to_string()),
        };
        let (j, rx) = job_opts(1, "hello", opts);
        sched.admit(j);
        let r = final_response(&rx).expect("rejection must reply immediately");
        let err = r.error.clone().expect("must carry a typed error");
        assert_eq!(err.code, crate::api::ErrorCode::UnknownTier);
        assert!(err.message.contains("turbo") && err.message.contains("dense"), "{err}");
        assert_eq!(sched.slots.free_count(), free_before, "no slot churn");
        assert!(sched.pending.is_empty() && sched.inflight.is_empty());
        assert_eq!(
            metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
            1
        );

        // a known tier on the same scheduler still admits fine
        let opts = RequestOptions {
            max_new_tokens: 2,
            sampler: Sampler::Greedy,
            tier: Some("lp".to_string()),
        };
        let (j, rx) = job_opts(2, "hello", opts);
        sched.admit(j);
        for _ in 0..50 {
            if sched.inflight.is_empty() && sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        let r = final_response(&rx).expect("lp request must complete");
        assert!(r.error.is_none(), "{:?}", r.error);
    }

    /// Satellite regression: admission validates both bounds before a slot
    /// is claimed — an over-long prompt (or an impossible token budget) is
    /// rejected with one clear error and zero slot churn.
    #[test]
    fn admission_rejects_before_claiming_a_slot() {
        let Some(model) = build() else { return };
        let ctx = model.entry.config.ctx;
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics.clone());
        let free_before = sched.slots.free_count();

        // prompt longer than any admissible bound (ctx bytes + BOS > ctx-1)
        let (job_long, rx_long) = job(1, &"z".repeat(ctx), 4);
        sched.admit(job_long);
        let r = final_response(&rx_long).expect("rejection must reply immediately");
        assert!(r.error_message().unwrap_or("").contains("admission limit"), "{r:?}");

        // budget that can never fit ctx
        let (job_budget, rx_budget) = job(2, "ok", ctx);
        sched.admit(job_budget);
        let r = final_response(&rx_budget).expect("rejection must reply immediately");
        assert!(r.error_message().unwrap_or("").contains("max_new"), "{r:?}");

        assert_eq!(sched.slots.free_count(), free_before, "rejections must not hold slots");
        assert!(sched.pending.is_empty() && sched.inflight.is_empty());
        assert_eq!(
            metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }

    /// Paged serving end to end (tentpole): the second identical prompt
    /// attaches the published prefix blocks at admission — its prefill
    /// cursor starts past them, so the shared chunks never run again —
    /// retirement returns each request's private pages through
    /// `release_slot`, and once the pools are capped, a later prompt's
    /// blocks can only be mapped by LRU-evicting the index-held prefix —
    /// all of it visible through the mirrored `kv_*` server metrics.
    #[test]
    fn paged_scheduler_reuses_prefixes_and_evicts_under_pressure() {
        use std::sync::atomic::Ordering;
        let Some(mut model) = build() else { return };
        if model.entry.kv_pages.is_none() {
            return;
        }
        let Some(k) = model.prefill_chunk() else { return };
        model.enable_paging().unwrap();
        let vid = model.default_variant().id.clone();
        let stages = model.variant(&vid).unwrap().stages.len();
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics.clone());

        // leader: BOS + 100 bytes = 101 tokens -> 4 chunks of 32, of which
        // blocks 0..2 are shareable ((j+1)*k < 101); run it to the point
        // where its prefix blocks are published
        let long = "y".repeat(100);
        let (job_a, rx_a) = job(1, &long, 3);
        sched.admit(job_a);
        for _ in 0..10 {
            if sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        assert!(sched.pending.is_empty(), "leader prefill must finish");

        // follower, same prompt: admission attaches all 3 shared blocks,
        // so the prefill cursor starts at 3k before any tick runs
        let (job_b, rx_b) = job(2, &long, 3);
        sched.admit(job_b);
        assert_eq!(
            sched.pending.front().unwrap().state.consumed(),
            3 * k,
            "follower must start past the shared prefix"
        );
        for _ in 0..50 {
            if sched.inflight.is_empty() && sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        for rx in [rx_a, rx_b] {
            let r = final_response(&rx).expect("request must have completed");
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.generated_tokens(), 3);
        }
        // decode rounds mirror KvStats into the server metrics; after both
        // retirements only the index-held prefix pages remain claimed
        assert_eq!(metrics.kv_prefix_lookups.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.kv_prefix_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.kv_prefix_shared_tokens.load(Ordering::Relaxed), (3 * k) as u64);
        assert_eq!(metrics.kv_pages_in_use.load(Ordering::Relaxed), (3 * stages) as u64);
        assert_eq!(metrics.kv_evictions.load(Ordering::Relaxed), 0);

        // memory pressure: cap both pools to one block's worth of pages
        // (+ scratch). The fresh prompt's block then only maps by evicting
        // the leader's index-held prefix blocks, LRU-first.
        sched.model().set_page_capacity(stages + 1);
        let (job_c, rx_c) = job(3, "hi", 4);
        sched.admit(job_c);
        for _ in 0..50 {
            if sched.inflight.is_empty() && sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        let r = final_response(&rx_c).expect("pressured request must still complete");
        assert!(r.error.is_none(), "eviction must make room: {:?}", r.error);
        assert!(
            metrics.kv_evictions.load(Ordering::Relaxed) >= 1,
            "capped pools must force prefix-block eviction"
        );
    }

    /// Satellite (PR 10): transient page-pool pressure PARKS a request on
    /// the back-pressure seam instead of rejecting it. A leader whose
    /// in-flight pages exactly fill the binding pool forces a different
    /// follower prompt to wait — no rejection, no slot churn — and the
    /// follower admits and completes as soon as the leader retires and
    /// frees pages.
    #[test]
    fn paged_admission_parks_under_transient_pressure_and_admits_after_free() {
        use crate::model::serving::ServeStage;
        use std::sync::atomic::Ordering;
        let Some(mut model) = build() else { return };
        if model.entry.kv_pages.is_none() {
            return;
        }
        let Some(k) = model.prefill_chunk() else { return };
        model.enable_paging().unwrap();
        let vid = model.default_variant().id.clone();
        let stages = &model.variant(&vid).unwrap().stages;
        // Per-block page need per pool: one half-width page per Tp stage,
        // one full-width page per Lp stage. Cap both pools so the larger
        // need is EXACTLY exhausted by the leader's 4 blocks (+ scratch).
        let half_stages = stages.iter().filter(|s| matches!(s, ServeStage::Tp(_))).count();
        let max_stages = half_stages.max(stages.len() - half_stages);
        // leader: BOS + 100 bytes = 101 tokens; + 3 new = 104-token span
        // -> 4 blocks of k=32
        let blocks = (101usize + 3).div_ceil(k);
        model.set_page_capacity(blocks * max_stages + 1);
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics.clone());

        let (job_a, rx_a) = job(1, &"y".repeat(100), 3);
        sched.admit(job_a);
        for _ in 0..10 {
            if sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        assert!(sched.pending.is_empty(), "leader prefill must finish");

        // different prompt, same footprint: the binding pool is full and
        // the leader's pages are slot-held (not evictable) -> must park
        let (job_b, rx_b) = job(2, &"z".repeat(100), 3);
        sched.admit(job_b);
        assert_eq!(sched.parked.len(), 1, "follower must park, not reject");
        assert!(final_response(&rx_b).is_none(), "no reply while parked");
        assert_eq!(metrics.admission_waits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests_rejected.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.slot_allocs.load(Ordering::Relaxed), 1, "parked claims no slot");

        // drive the leader to retirement; the follower then admits off the
        // parked queue (evicting the leader's index-held prefix blocks as
        // needed) and completes
        for _ in 0..100 {
            if sched.is_idle() {
                break;
            }
            sched.tick();
        }
        assert!(sched.is_idle(), "parked request must eventually admit");
        let ra = final_response(&rx_a).expect("leader must complete");
        assert!(ra.error.is_none(), "{:?}", ra.error);
        let rb = final_response(&rx_b).expect("parked follower must complete");
        assert!(rb.error.is_none(), "{:?}", rb.error);
        assert_eq!(rb.generated_tokens(), 3);
        assert_eq!(metrics.slot_allocs.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.requests_rejected.load(Ordering::Relaxed), 0);
    }

    /// Cluster drain support: `eject_all` strips parked + pending +
    /// in-flight requests (freeing every slot), and a fresh scheduler
    /// re-running the ejected jobs from scratch reproduces the identical
    /// token stream — the property that makes replica fail-over dedup-able
    /// by index contiguity.
    #[test]
    fn eject_all_returns_resubmittable_jobs_with_identical_replay() {
        let Some(model) = build() else { return };
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics.clone());
        let free0 = sched.slots.free_count();

        // one request decoding (short prompt), one still prefilling (long)
        let (job_a, rx_a) = job(1, "the red fox", 4);
        let (job_b, rx_b) = job(2, &"y".repeat(100), 3);
        sched.admit(job_a);
        sched.tick(); // A becomes live
        sched.admit(job_b);
        sched.tick(); // A streams a token; B consumes one chunk
        assert_eq!(sched.inflight.len(), 1);
        assert_eq!(sched.pending.len(), 1);
        let a_streamed: Vec<i32> = std::iter::from_fn(|| match rx_a.try_recv() {
            Ok(TokenEvent::Token { token, .. }) => Some(token),
            _ => None,
        })
        .collect();
        assert!(!a_streamed.is_empty(), "A must have streamed before ejection");

        let jobs = sched.eject_all();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].request.id, 1, "admission (request-id) order");
        assert_eq!(jobs[1].request.id, 2);
        assert!(sched.is_idle());
        assert_eq!(sched.slots.free_count(), free0, "ejection must free every slot");

        // replay on a sibling: same request ids -> same sampling streams
        let Some(model2) = build() else { return };
        let mut sibling = Scheduler::new(model2, Arc::new(ServerMetrics::default()));
        for j in jobs {
            sibling.admit(j);
        }
        for _ in 0..100 {
            if sibling.is_idle() {
                break;
            }
            sibling.tick();
        }
        // the original reply channels receive the full re-run; the re-sent
        // prefix duplicates what was streamed before ejection (the cluster
        // pump drops those by contiguity — here we see the raw feed)
        let mut replay = Vec::new();
        while let Ok(ev) = rx_a.try_recv() {
            if let TokenEvent::Token { token, .. } = ev {
                replay.push(token);
            }
        }
        assert!(replay.len() >= a_streamed.len());
        assert_eq!(
            &replay[..a_streamed.len()],
            &a_streamed[..],
            "re-run must re-emit the identical token prefix"
        );
        let rb = final_response(&rx_b).expect("ejected B must complete on the sibling");
        assert!(rb.error.is_none(), "{:?}", rb.error);
        assert_eq!(rb.generated_tokens(), 3);
    }

    /// Satellite: a request whose page footprint can NEVER fit the logical
    /// pools is rejected at admission — before a slot is claimed, with zero
    /// slot or page churn — and the same request admits fine once the cap
    /// is lifted.
    #[test]
    fn paged_admission_rejects_over_pool_requests_without_churn() {
        use crate::model::kvcache::KvStats;
        let Some(mut model) = build() else { return };
        if model.entry.kv_pages.is_none() {
            return;
        }
        model.enable_paging().unwrap();
        model.set_page_capacity(1); // scratch only: nothing can ever fit
        let metrics = Arc::new(ServerMetrics::default());
        let mut sched = Scheduler::new(model, metrics.clone());
        let free_before = sched.slots.free_count();

        let (j, rx) = job(1, "hi", 4);
        sched.admit(j);
        let r = final_response(&rx).expect("rejection must reply immediately");
        assert!(r.error_message().unwrap_or("").contains("page"), "{r:?}");
        assert_eq!(sched.slots.free_count(), free_before, "no slot churn");
        assert!(sched.pending.is_empty() && sched.inflight.is_empty());
        assert_eq!(
            metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            sched.model().kv_stats().unwrap(),
            KvStats::default(),
            "rejection must not touch pages or the prefix index"
        );

        // restore the pools (clamped to the physical tensors): the same
        // request now admits and completes
        sched.model().set_page_capacity(usize::MAX);
        let (j2, rx2) = job(2, "hi", 4);
        sched.admit(j2);
        for _ in 0..50 {
            if sched.inflight.is_empty() && sched.pending.is_empty() {
                break;
            }
            sched.tick();
        }
        let r = final_response(&rx2).expect("request must complete after uncapping");
        assert!(r.error.is_none(), "{:?}", r.error);
    }
}
