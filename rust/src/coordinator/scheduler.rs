//! Continuous-batching scheduler: the decode loop at the heart of the
//! serving stack.
//!
//! Policy (vLLM-style, prefill-prioritized): each iteration first admits
//! waiting requests into free KV slots (prefill runs alone — the AOT
//! prefill executables are batch-1), then runs ONE batched decode step
//! across all active slots, samples each slot's next token, and retires
//! finished sequences.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::request::{Job, Request, Response};
use crate::gen::Sampler;
use crate::model::kvcache::SlotManager;
use crate::model::ServingModel;
use crate::text::tokenizer::{self, EOS};
use crate::util::rng::SplitMix64;

struct InFlight {
    request: Request,
    reply: Sender<Response>,
    tokens: Vec<i32>,
    /// Prompt length in tokens, recorded once at admit time (re-encoding
    /// the prompt at completion just to count it was a hot-path bug).
    prompt_tokens: usize,
    ttft_ms: f64,
    sampler: Sampler,
    rng: SplitMix64,
}

pub struct Scheduler {
    model: ServingModel,
    slots: SlotManager,
    inflight: HashMap<usize, InFlight>, // slot -> request state
    metrics: Arc<ServerMetrics>,
}

impl Scheduler {
    pub fn new(model: ServingModel, metrics: Arc<ServerMetrics>) -> Scheduler {
        let cfg = &model.entry.config;
        let slots = SlotManager::new(cfg.slots, cfg.ctx);
        Scheduler { model, slots, inflight: HashMap::new(), metrics }
    }

    pub fn model(&self) -> &ServingModel {
        &self.model
    }

    /// Run until the batcher closes and all in-flight work drains.
    pub fn run(&mut self, batcher: &Batcher, batch_wait: Duration) {
        loop {
            let free = self.slots.free_count();
            // Block on the queue only when idle; when decoding, poll.
            let wait = if self.inflight.is_empty() {
                Duration::from_millis(50)
            } else {
                batch_wait.min(Duration::from_millis(1))
            };
            let admitted = if free > 0 { batcher.drain(free, wait) } else { vec![] };
            for job in admitted {
                self.admit(job);
            }
            if self.inflight.is_empty() {
                if batcher.is_closed() && batcher.is_empty() {
                    return;
                }
                continue;
            }
            self.decode_round();
        }
    }

    fn admit(&mut self, job: Job) {
        let Job { request, reply } = job;
        let ids = tokenizer::encode(&request.prompt, true, false);
        let max_new = request.opts.max_new_tokens;
        let sampler = request.opts.sampler.clone();
        let (slot, logits) = match self.model.prefill_slot_checked(
            &mut self.slots,
            request.id,
            &ids,
            max_new,
        ) {
            Ok(x) => x,
            Err(e) => {
                let _ = reply.send(Response::failed(request.id, e.to_string()));
                return;
            }
        };
        self.metrics
            .prefill_tokens
            .fetch_add(ids.len() as u64, std::sync::atomic::Ordering::Relaxed);
        let mut rng = SplitMix64::new(request.id ^ 0x5eed);
        let first = sampler.sample(&logits, &mut rng);
        let ttft_ms = request.submitted_at.elapsed().as_secs_f64() * 1e3;
        self.slots.get_mut(slot).unwrap().next_token = first;
        self.inflight.insert(
            slot,
            InFlight {
                request,
                reply,
                tokens: vec![],
                prompt_tokens: ids.len(),
                ttft_ms,
                sampler,
                rng,
            },
        );
    }

    fn decode_round(&mut self) {
        // Compacted batch: only active slots cross the executor boundary;
        // decode_active dispatches them at bucket granularity (the device
        // computes — and downloads — the covering bucket, not all [S]
        // lanes; see runtime::buckets).
        let active = self.slots.active_inputs();
        let rows = match self.model.decode_active(&active) {
            Ok(r) => r,
            // Failure isolation: a batch error must not fail every
            // in-flight request. Retry each live slot alone; only the
            // slots that still fail are drained, the rest keep decoding.
            Err(e) => self.decode_round_isolated(&active, &e),
        };
        // Rounds that produced nothing (every slot failed) don't count as
        // decode steps, matching the pre-isolation accounting; after a
        // partial failure only the lanes that actually produced a row
        // count toward the occupancy histogram.
        if !rows.is_empty() {
            self.metrics.record_decode_round(rows.len());
        }
        for (slot, row) in rows {
            self.apply_sampled_row(slot, &row);
        }
    }

    /// Per-slot fallback after a batched decode error: decode each live
    /// slot in its own round (the B=1 bucket), failing only the slots
    /// whose single-lane step also errors. Returns the successfully
    /// decoded rows.
    fn decode_round_isolated(
        &mut self,
        active: &[(usize, i32, i32)],
        batch_err: &crate::Error,
    ) -> Vec<(usize, Vec<f32>)> {
        let mut rows = Vec::new();
        for &lane in active {
            match self.model.decode_active(&[lane]) {
                Ok(mut r) => rows.append(&mut r),
                Err(e) => {
                    let slot = lane.0;
                    self.slots.free(slot);
                    if let Some(inf) = self.inflight.remove(&slot) {
                        let _ = inf.reply.send(Response::failed(
                            inf.request.id,
                            format!("decode failed: {e} (batch round failed: {batch_err})"),
                        ));
                    }
                }
            }
        }
        rows
    }

    /// Fold one sampled logits row back into its slot: extend the output,
    /// sample the next token, retire the sequence if finished.
    fn apply_sampled_row(&mut self, slot: usize, row: &[f32]) {
        let Some(inf) = self.inflight.get_mut(&slot) else { return };
        // The token just processed at `pos` becomes output history.
        let current = self.slots.get(slot).unwrap().next_token;
        inf.tokens.push(current);
        let next = inf.sampler.sample(row, &mut inf.rng);
        let done = self.slots.advance(slot, next, EOS);
        if done {
            let inf = self.inflight.remove(&slot).unwrap();
            self.slots.free(slot);
            let latency = inf.request.submitted_at.elapsed().as_secs_f64() * 1e3;
            self.metrics.record_completion(inf.ttft_ms, latency, inf.tokens.len());
            let _ = inf.reply.send(Response {
                id: inf.request.id,
                text: tokenizer::decode(&inf.tokens),
                prompt_tokens: inf.prompt_tokens,
                tokens: inf.tokens,
                ttft_ms: inf.ttft_ms,
                latency_ms: latency,
                error: None,
            });
        }
    }
}

impl ServingModel {
    /// Allocate a slot + prefill as one transaction (slot freed on error).
    pub fn prefill_slot_checked(
        &self,
        slots: &mut SlotManager,
        request_id: u64,
        ids: &[i32],
        max_new: usize,
    ) -> crate::Result<(usize, Vec<f32>)> {
        let slot = slots.alloc(request_id, ids.len(), max_new, 0)?;
        match self.prefill(slot, ids) {
            Ok(logits) => Ok((slot, logits)),
            Err(e) => {
                slots.free(slot);
                Err(e)
            }
        }
    }
}
