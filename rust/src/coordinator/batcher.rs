//! Bounded admission queue with batch draining (the dynamic-batching half
//! of continuous batching: the scheduler drains as many waiting requests as
//! it has free slots, waiting up to `batch_wait` to accumulate work).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::request::Job;

pub struct Batcher {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
}

struct Inner {
    queue: VecDeque<Job>,
    closed: bool,
}

pub enum SubmitError {
    Full(Job),
    Closed(Job),
}

impl Batcher {
    pub fn new(capacity: usize) -> Batcher {
        Batcher {
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking submit; back-pressure via `SubmitError::Full`.
    pub fn submit(&self, req: Job) -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed(req));
        }
        if g.queue.len() >= self.capacity {
            return Err(SubmitError::Full(req));
        }
        g.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Drain up to `max_n` requests, waiting at most `wait` for the first
    /// one (returns fewer — possibly zero — on timeout or close).
    pub fn drain(&self, max_n: usize, wait: Duration) -> Vec<Job> {
        let deadline = Instant::now() + wait;
        let mut g = self.inner.lock().unwrap();
        while g.queue.is_empty() && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                return vec![];
            }
            let (guard, _timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
        let n = max_n.min(g.queue.len());
        g.queue.drain(..n).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, RequestOptions};

    fn req(id: u64) -> Job {
        let (tx, _rx) = std::sync::mpsc::channel();
        Job {
            request: Request {
                id,
                prompt: "x".into(),
                opts: RequestOptions::default(),
                submitted_at: Instant::now(),
            },
            reply: tx,
        }
    }

    #[test]
    fn fifo_order_and_capacity() {
        let b = Batcher::new(2);
        b.submit(req(1)).ok().unwrap();
        b.submit(req(2)).ok().unwrap();
        assert!(matches!(b.submit(req(3)), Err(SubmitError::Full(_))));
        let drained = b.drain(10, Duration::from_millis(1));
        assert_eq!(drained.iter().map(|r| r.request.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn drain_respects_max_n() {
        let b = Batcher::new(10);
        for i in 0..5 {
            b.submit(req(i)).ok().unwrap();
        }
        assert_eq!(b.drain(2, Duration::from_millis(1)).len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn drain_times_out_empty() {
        let b = Batcher::new(1);
        let t = Instant::now();
        assert!(b.drain(1, Duration::from_millis(20)).is_empty());
        assert!(t.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn drain_wakes_on_submit_from_other_thread() {
        let b = std::sync::Arc::new(Batcher::new(4));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.drain(1, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        b.submit(req(42)).ok().unwrap();
        let got = h.join().unwrap();
        assert_eq!(got[0].request.id, 42);
    }

    #[test]
    fn close_rejects_and_wakes() {
        let b = Batcher::new(4);
        b.close();
        assert!(matches!(b.submit(req(1)), Err(SubmitError::Closed(_))));
        assert!(b.drain(1, Duration::from_secs(1)).is_empty());
    }
}
