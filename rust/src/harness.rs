//! Shared plumbing for the experiment regenerators (`rust/src/bin/*`):
//! context loading, result-file output, common sweep parameters.

use std::path::PathBuf;

use crate::config::InterconnectConfig;
use crate::error::Result;
use crate::model::Weights;
use crate::runtime::{Engine, Manifest, ModelEntry};

/// Everything a scoring experiment needs for one model.
pub struct ScoringCtx {
    pub manifest: Manifest,
    pub engine: Engine,
    pub model: String,
}

impl ScoringCtx {
    pub fn load(model: &str) -> Result<ScoringCtx> {
        Ok(ScoringCtx {
            manifest: Manifest::load_default()?,
            engine: Engine::cpu()?,
            model: model.to_string(),
        })
    }

    pub fn entry(&self) -> &ModelEntry {
        self.manifest.model(&self.model).expect("model in manifest")
    }

    /// Trained weights from `checkpoints/<model>` (or a named variant dir).
    pub fn weights(&self) -> Result<Weights> {
        self.weights_from(&self.model)
    }

    pub fn weights_from(&self, ckpt_name: &str) -> Result<Weights> {
        let dir = crate::repo_root().join("checkpoints").join(ckpt_name);
        Weights::load(&dir, &self.entry().config)
    }
}

/// Results directory (`results/`), created on demand.
pub fn results_dir() -> PathBuf {
    let d = crate::repo_root().join("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Write a CSV result file and echo its path.
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> PathBuf {
    let path = results_dir().join(name);
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text).expect("write results csv");
    println!("→ wrote {}", path.display());
    path
}

/// Default interconnect for speed experiments — the calibrated α–β model
/// (see EXPERIMENTS.md §Calibration).
pub fn default_net() -> InterconnectConfig {
    InterconnectConfig::default()
}

/// Interconnect disabled (pure host-compute timing).
pub fn no_net() -> InterconnectConfig {
    InterconnectConfig { enabled: false, ..Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_dir_exists_after_call() {
        assert!(results_dir().is_dir());
    }

    #[test]
    fn write_csv_roundtrip() {
        let p = write_csv("selftest.csv", "a,b", &["1,2".into(), "3,4".into()]);
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
    }
}
