//! Static verification of artifacts and plans: the load-time checker
//! behind `truedepth verify`, `bin/verify_artifacts` and the CI verify job.
//!
//! Since the plan-variant registry, the computational graph is *data* — a
//! manifest `variants` section of stage lists, picked per request. This
//! module proves a manifest's plans are well-formed **before** they reach
//! the hot path, the way sharded training stacks verify SPMD programs
//! before launch. Three analyses:
//!
//! * [`plan_check`] — every [`crate::runtime::VariantSpec`] covers each
//!   transformer layer exactly once, LP pairs are adjacent (bands
//!   contiguous, as a warning), every stage resolves to executables that
//!   exist in the manifest, and bucket sets / `prefill_chunk` / the
//!   KV-cache schema are mutually consistent.
//! * [`binding_check`] — abstract interpretation of the dispatch sequence
//!   each plan induces (a [`trace::DispatchTrace`] emitted by the serving
//!   executor's own dispatch code): every `ArgRef::Resident` is written
//!   before it is read, no exec key is used after `ExecCache` release, and
//!   the weight (`l{i}.tp.*` / `l{i}.full.*`) and KV (`kv.{tier}.*`) keys a
//!   stage binds all exist in the initial resident set.
//! * [`collective_check`] — MPI-style matching of the per-rank collective
//!   streams, proving all ranks issue the same collective sequence with
//!   identical payload shapes, so a rank-divergent plan is a load-time
//!   error instead of a mesh deadlock.
//!
//! Every diagnostic is `VariantId`-qualified ([`Diagnostic`]). Entry
//! points: [`verify_manifest`] (pure), [`verify_manifest_files`] (adds
//! artifact-file existence), [`check_load`] (error-severity gate run by
//! `Manifest::load`), [`check_strict`] (warnings fail too — the CI mode),
//! and [`run_cli`] (the printer both CLIs share). [`crosscheck_trace`]
//! pins the static traces to the mesh's recorded dispatch events
//! ([`crate::parallel::Mesh::begin_trace`]).

pub mod binding_check;
pub mod collective_check;
pub mod plan_check;
pub mod trace;

pub use binding_check::binding_check;
pub use collective_check::collective_check;
pub use plan_check::check_model;
pub use trace::{CollectiveEvent, CollectiveKind, DispatchTrace, RankIo, TraceOp};

use std::fmt;
use std::path::Path;

use crate::error::{Error, Result};
use crate::model::plan::GraphPlan;
use crate::model::prefill::chunk_step_trace;
use crate::model::serving::{
    decode_trace, initial_resident_names, prefill_trace, serve_stages, ServeStage, SERVE_RANKS,
};
use crate::parallel::MeshEvent;
use crate::runtime::{Manifest, VariantId};

/// Which analysis produced a diagnostic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Check {
    Plan,
    Binding,
    Collective,
    /// The static-trace/recorded-dispatch cross-check ([`crosscheck_trace`]).
    Trace,
}

impl fmt::Display for Check {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Check::Plan => write!(f, "plan"),
            Check::Binding => write!(f, "binding"),
            Check::Collective => write!(f, "collective"),
            Check::Trace => write!(f, "trace"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but servable — fails only `--strict` / [`check_strict`].
    Warn,
    /// Malformed — [`check_load`] rejects the manifest.
    Error,
}

/// One finding, qualified by model and (where applicable) plan variant, so
/// a broken tier names itself: `td-small / variant `lp`: [plan.pair-not-adjacent] …`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub model: String,
    pub variant: Option<VariantId>,
    pub check: Check,
    pub severity: Severity,
    /// Stable machine-readable code, e.g. `plan.layer-covered-twice` — the
    /// corpus tests key on these.
    pub code: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn error(
        check: Check,
        model: &str,
        variant: Option<&VariantId>,
        code: &'static str,
        message: String,
    ) -> Diagnostic {
        Diagnostic {
            model: model.to_string(),
            variant: variant.cloned(),
            check,
            severity: Severity::Error,
            code,
            message,
        }
    }

    pub fn warn(
        check: Check,
        model: &str,
        variant: Option<&VariantId>,
        code: &'static str,
        message: String,
    ) -> Diagnostic {
        Diagnostic { severity: Severity::Warn, ..Diagnostic::error(check, model, variant, code, message) }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warn => "warn",
        };
        match &self.variant {
            Some(vid) => write!(
                f,
                "{sev}: {} / variant `{vid}`: [{}] {}",
                self.model, self.code, self.message
            ),
            None => write!(f, "{sev}: {}: [{}] {}", self.model, self.code, self.message),
        }
    }
}

/// The outcome of a verification pass.
#[derive(Debug, Default)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn has_errors(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    /// All diagnostics, one per line, errors first.
    pub fn render(&self) -> String {
        let mut lines: Vec<String> = self.errors().map(|d| d.to_string()).collect();
        lines.extend(
            self.diagnostics
                .iter()
                .filter(|d| d.severity == Severity::Warn)
                .map(|d| d.to_string()),
        );
        lines.join("\n")
    }

    /// Error-severity diagnostics only, one per line.
    pub fn render_errors(&self) -> String {
        self.errors().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    }
}

/// The serve-time stage walks of a model's parseable variants — the inputs
/// of the dispatch-level (binding/collective) analyses. Variants whose
/// plans do not parse are reported by [`plan_check`] and skipped here.
fn servable_variants(
    entry: &crate::runtime::ModelEntry,
) -> Vec<(VariantId, Vec<ServeStage>)> {
    entry
        .variants
        .values()
        .filter_map(|spec| {
            let plan = GraphPlan::from_stage_lists(entry.config.n_layers, &spec.stages).ok()?;
            let stages = serve_stages(&plan).ok()?;
            Some((spec.id.clone(), stages))
        })
        .collect()
}

/// The abstract dispatch traces one variant induces: the fixed-`[S]`
/// decode round, one bucketed decode round per registered batch bucket,
/// one monolithic prefill pass per seq bucket, and (when the manifest
/// carries the chunk family) a mid-stream and a final chunk step.
fn variant_traces(
    vid: &VariantId,
    stages: &[ServeStage],
    entry: &crate::runtime::ModelEntry,
    seq_buckets: &[usize],
    prefill_chunk: Option<usize>,
) -> Vec<DispatchTrace> {
    let cfg = &entry.config;
    let mut traces =
        vec![decode_trace(vid, stages, SERVE_RANKS, cfg.d_model, cfg.slots, "", false)];
    for &b in &entry.batch_buckets {
        traces.push(decode_trace(
            vid,
            stages,
            SERVE_RANKS,
            cfg.d_model,
            b,
            &format!("_b{b}"),
            true,
        ));
    }
    for &t in seq_buckets {
        traces.push(prefill_trace(vid, stages, SERVE_RANKS, cfg.d_model, t));
    }
    if let Some(k) = prefill_chunk {
        traces.push(chunk_step_trace(vid, stages, SERVE_RANKS, cfg.d_model, k, false));
        traces.push(chunk_step_trace(vid, stages, SERVE_RANKS, cfg.d_model, k, true));
    }
    traces
}

/// Run all three analyses over every model of a parsed manifest (pure —
/// no filesystem access; see [`verify_manifest_files`] for the CI pass).
pub fn verify_manifest(m: &Manifest) -> VerifyReport {
    let mut diagnostics = Vec::new();
    for (mname, entry) in &m.models {
        diagnostics.extend(plan_check::check_model(
            mname,
            entry,
            &m.seq_buckets,
            m.prefill_chunk,
        ));
        let variants = servable_variants(entry);
        let residents = initial_resident_names(&variants, SERVE_RANKS);
        for (vid, stages) in &variants {
            for tr in variant_traces(vid, stages, entry, &m.seq_buckets, m.prefill_chunk) {
                diagnostics.extend(binding_check(mname, vid, &tr, &residents));
                diagnostics.extend(collective_check(
                    mname,
                    vid,
                    &tr.label,
                    &tr.rank_collective_streams(),
                ));
            }
        }
    }
    VerifyReport { diagnostics }
}

/// [`verify_manifest`] plus artifact-file existence — the standalone /
/// CI-mode pass (load-time verification stays pure so a manifest can be
/// checked without its `.hlo` payloads present).
pub fn verify_manifest_files(m: &Manifest) -> VerifyReport {
    let mut report = verify_manifest(m);
    for (mname, entry) in &m.models {
        for a in entry.artifacts.values() {
            if !a.file.exists() {
                report.diagnostics.push(Diagnostic::error(
                    Check::Plan,
                    mname,
                    None,
                    "plan.artifact-file-missing",
                    format!("artifact `{}` file {} does not exist", a.name, a.file.display()),
                ));
            }
        }
    }
    report
}

/// The load-time gate `Manifest::load` runs: error-severity findings
/// reject the manifest; warnings pass (use [`check_strict`] to fail them).
pub fn check_load(m: &Manifest) -> Result<()> {
    let report = verify_manifest(m);
    if report.has_errors() {
        return Err(Error::Verify(report.render_errors()));
    }
    Ok(())
}

/// The strict gate (`Manifest::load_strict`, `truedepth verify --strict`,
/// CI): any finding — including warnings and missing artifact files —
/// fails.
pub fn check_strict(m: &Manifest) -> Result<()> {
    let report = verify_manifest_files(m);
    if !report.is_clean() {
        return Err(Error::Verify(report.render()));
    }
    Ok(())
}

/// Shared CLI driver of `truedepth verify` and `bin/verify_artifacts`:
/// load the manifest unverified, run the full pass, print every finding,
/// and fail on errors (or, under `strict`, on any finding).
pub fn run_cli(dir: &Path, strict: bool) -> Result<()> {
    let m = Manifest::load_unverified(dir)?;
    let n_variants: usize = m.models.values().map(|e| e.variants.len()).sum();
    println!(
        "verify: {} — {} model(s), {} plan variant(s), strict={}",
        dir.join("manifest.json").display(),
        m.models.len(),
        n_variants,
        strict
    );
    let report = verify_manifest_files(&m);
    for d in &report.diagnostics {
        println!("{d}");
    }
    let n_err = report.errors().count();
    let n_warn = report.diagnostics.len() - n_err;
    if n_err > 0 || (strict && n_warn > 0) {
        return Err(Error::Verify(format!(
            "{n_err} error(s), {n_warn} warning(s) — manifest rejected"
        )));
    }
    println!("verify: OK ({n_warn} warning(s))");
    Ok(())
}

/// Cross-check a static [`DispatchTrace`] against the dispatch events the
/// mesh actually recorded ([`crate::parallel::Mesh::begin_trace`] /
/// `take_trace`) — the debug-mode assertion that the emitters mirror the
/// real hot path op for op. `EnsureExecs` / `ReleaseExec` have no mesh
/// event (compilation is lazy and unrecorded); every other op maps 1:1.
pub fn crosscheck_trace(
    model: &str,
    vid: &VariantId,
    tr: &DispatchTrace,
    events: &[MeshEvent],
) -> Vec<Diagnostic> {
    let mut expected = Vec::new();
    for op in &tr.ops {
        match op {
            TraceOp::EnsureExecs { .. } | TraceOp::ReleaseExec { .. } => {}
            TraceOp::UploadAll { name } => {
                expected.push(MeshEvent::Upload { name: name.clone(), ranks: tr.ranks })
            }
            TraceOp::ExecRank { rank, key, .. } => {
                expected.push(MeshEvent::ExecRank { key: key.clone(), rank: *rank })
            }
            TraceOp::ExecAll { key, .. } => {
                expected.push(MeshEvent::Exec { key: key.clone(), ranks: tr.ranks })
            }
            TraceOp::BroadcastResident { name, .. } => {
                expected.push(MeshEvent::Broadcast { name: name.clone() })
            }
            TraceOp::ReduceInto { elems, .. } => expected.push(MeshEvent::Collective {
                kind: "reduce_into",
                bytes: *elems as u64 * 4,
                ranks: tr.ranks,
            }),
        }
    }
    let mut diags = Vec::new();
    if expected.len() != events.len() {
        diags.push(Diagnostic::error(
            Check::Trace,
            model,
            Some(vid),
            "trace.dispatch-count",
            format!(
                "`{}`: static trace has {} dispatch ops, the mesh recorded {}",
                tr.label,
                expected.len(),
                events.len()
            ),
        ));
    }
    for (i, (e, g)) in expected.iter().zip(events.iter()).enumerate() {
        if e != g {
            diags.push(Diagnostic::error(
                Check::Trace,
                model,
                Some(vid),
                "trace.dispatch-mismatch",
                format!("`{}`: op #{i}: static trace says {e:?}, mesh recorded {g:?}", tr.label),
            ));
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::InterconnectConfig;
    use crate::model::serving::ServingModel;
    use crate::model::weights::Weights;

    fn quiet() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    /// The shipped AOT artifacts must verify clean — the tentpole
    /// acceptance criterion, library half.
    #[test]
    fn shipped_manifest_verifies_clean() {
        let Ok(m) = Manifest::load_default() else { return };
        let report = verify_manifest_files(&m);
        assert!(report.is_clean(), "shipped artifacts must verify clean:\n{}", report.render());
        assert!(check_strict(&m).is_ok());
    }

    /// The static decode trace must match the mesh's recorded dispatch
    /// events op for op — the emitters cannot drift from the hot path.
    #[test]
    fn static_decode_trace_matches_recorded_dispatch() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 7);
        let Ok(m) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        let prompt: Vec<i32> = "the red fox".bytes().map(|b| b as i32).collect();
        for vid in m.variant_ids() {
            m.prefill_v(&vid, 0, &prompt).unwrap();
            let tokens = vec![0i32; cfg.slots];
            let pos = vec![0i32; cfg.slots];
            m.decode_step_v(&vid, &tokens, &pos).unwrap(); // warm (lazy compiles)
            m.mesh.begin_trace();
            m.decode_step_v(&vid, &tokens, &pos).unwrap();
            let events = m.mesh.take_trace();
            let tr = m.static_decode_trace(&vid, None).unwrap();
            let diags = crosscheck_trace("td-small", &vid, &tr, &events);
            assert!(
                diags.is_empty(),
                "tier {vid}: static decode trace diverged from dispatch:\n{}",
                diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
            );
        }
    }

    /// Same cross-check for the chunk-prefill step — mid-stream and final.
    #[test]
    fn static_chunk_trace_matches_recorded_dispatch() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 9);
        let Ok(m) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        let Some(k) = m.prefill_chunk() else { return };
        let vid = m.default_tier().clone();
        let prompt: Vec<i32> = (0..(k + 3) as i32).map(|i| 40 + (i % 50)).collect();
        let mut st = m.begin_prefill_v(&vid, 0, &prompt).unwrap();
        m.prefill_chunked_v(&vid, 1, &prompt).unwrap(); // warm (lazy compiles)
        for last in [false, true] {
            m.mesh.begin_trace();
            let out = m.prefill_step(&mut st).unwrap();
            assert_eq!(out.is_some(), last);
            let events = m.mesh.take_trace();
            let tr = m.static_chunk_trace(&vid, last).unwrap().unwrap();
            let diags = crosscheck_trace("td-small", &vid, &tr, &events);
            assert!(
                diags.is_empty(),
                "chunk step (last={last}) diverged from dispatch:\n{}",
                diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
            );
        }
    }

    /// Every buffer the static resident model claims exists must actually
    /// be fetchable on the mesh after construction.
    #[test]
    fn static_residents_all_fetchable_on_the_mesh() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 5);
        let Ok(m) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        for (rank, names) in m.static_residents().iter().enumerate() {
            for name in names {
                assert!(
                    m.mesh.workers[rank].fetch(name).is_ok(),
                    "rank {rank}: static model claims `{name}` resident but the mesh has no such buffer"
                );
            }
        }
    }

    #[test]
    fn diagnostic_display_is_variant_qualified() {
        let d = Diagnostic::error(
            Check::Plan,
            "td-x",
            Some(&VariantId::new("lp")),
            "plan.layer-missing",
            "layer 3 not covered by any stage".into(),
        );
        assert_eq!(
            d.to_string(),
            "error: td-x / variant `lp`: [plan.layer-missing] layer 3 not covered by any stage"
        );
        let w = Diagnostic::warn(Check::Plan, "td-x", None, "plan.band-not-contiguous", "x".into());
        assert!(w.to_string().starts_with("warn: td-x: [plan.band-not-contiguous]"));
    }
}
