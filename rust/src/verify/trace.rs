//! Abstract dispatch traces: the IR the static checkers interpret.
//!
//! A [`DispatchTrace`] is the sequence of mesh operations one protocol
//! step of the serving executor issues — one decode round, one monolithic
//! prefill pass, one chunk step — with every `ArgRef::Resident` binding
//! named explicitly per rank. The emitters live next to the dispatch code
//! they mirror ([`crate::model::serving::decode_trace`],
//! [`crate::model::serving::prefill_trace`],
//! [`crate::model::prefill::chunk_step_trace`]) so the trace doubles as
//! documentation of the hot path, and the mesh's debug trace recorder
//! ([`crate::parallel::Mesh::begin_trace`]) pins each emitter to the real
//! dispatch sequence bit for bit (see [`super::crosscheck_trace`]).
//!
//! Two analyses interpret the IR:
//!
//! * [`super::binding_check`] walks the ops in order against the initial
//!   resident set, proving every resident read was written first;
//! * [`super::collective_check`] projects the ops onto per-rank collective
//!   streams ([`DispatchTrace::rank_collective_streams`]) and proves the
//!   ranks agree on the collective sequence and payload shapes.

use std::fmt;

/// Per-rank resident-buffer IO of one [`TraceOp::ExecAll`] call.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RankIo {
    /// Resident buffer names the call reads (`ArgRef::Resident` args).
    pub reads: Vec<String>,
    /// Resident buffer names the call persists outputs into.
    pub writes: Vec<String>,
}

/// One abstract mesh operation of a dispatch sequence. Host-value args
/// (`ArgRef::Host`) are not bindings and do not appear; `elems` fields
/// carry the f32 element count of the payload so collective shapes can be
/// matched across ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceOp {
    /// `ExecCache::ensure` over the keys the step is about to bind.
    EnsureExecs { keys: Vec<String> },
    /// Exec-cache eviction of one key (`Mesh::release_all`).
    ReleaseExec { key: String },
    /// `Mesh::upload_all`: fresh host data becomes a resident buffer on
    /// every rank.
    UploadAll { name: String },
    /// `Mesh::exec_rank`: one call on one rank.
    ExecRank { rank: usize, key: String, reads: Vec<String>, writes: Vec<String> },
    /// `Mesh::exec_all`: one call per rank, joined.
    ExecAll { key: String, per_rank: Vec<RankIo> },
    /// `Mesh::broadcast_resident`: device-to-device fan-out of `name`.
    BroadcastResident { name: String, elems: usize },
    /// `Mesh::reduce_into`: gather `partial` from every rank, sum, scatter
    /// into `dest` on every rank — the resident-buffer all-reduce.
    ReduceInto { partial: String, dest: String, elems: usize },
}

/// The abstract dispatch sequence of one protocol step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DispatchTrace {
    /// Human-readable step label, e.g. `decode[lp]@4` — used verbatim in
    /// diagnostics.
    pub label: String,
    pub ranks: usize,
    pub ops: Vec<TraceOp>,
}

/// Kind of a collective event as seen by one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Fan-out of a resident buffer to every rank (`broadcast_resident`).
    Broadcast,
    /// All-reduce of per-rank partials (`reduce_into` / `all_reduce`).
    Reduce,
}

impl fmt::Display for CollectiveKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CollectiveKind::Broadcast => write!(f, "broadcast"),
            CollectiveKind::Reduce => write!(f, "reduce"),
        }
    }
}

/// One collective a rank participates in: kind, buffer name, payload
/// element count. Every rank of the mesh must issue the same sequence of
/// these or the joint dispatch deadlocks — the property
/// [`super::collective_check`] proves.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CollectiveEvent {
    pub kind: CollectiveKind,
    pub name: String,
    pub elems: usize,
}

impl fmt::Display for CollectiveEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} `{}` [{} elems]", self.kind, self.name, self.elems)
    }
}

impl DispatchTrace {
    /// Project the trace onto per-rank collective streams. The emitted
    /// traces are uniform by construction (every collective op names all
    /// ranks), so the interesting inputs to [`super::collective_check`]
    /// are hand-built divergent streams — the SPMD bug class where one
    /// rank skips a collective the peers are blocked in.
    pub fn rank_collective_streams(&self) -> Vec<Vec<CollectiveEvent>> {
        let mut streams: Vec<Vec<CollectiveEvent>> = vec![Vec::new(); self.ranks];
        for op in &self.ops {
            let ev = match op {
                TraceOp::BroadcastResident { name, elems } => CollectiveEvent {
                    kind: CollectiveKind::Broadcast,
                    name: name.clone(),
                    elems: *elems,
                },
                TraceOp::ReduceInto { partial, elems, .. } => CollectiveEvent {
                    kind: CollectiveKind::Reduce,
                    name: partial.clone(),
                    elems: *elems,
                },
                _ => continue,
            };
            for s in &mut streams {
                s.push(ev.clone());
            }
        }
        streams
    }

    /// Every executable key the trace binds (exec ops only).
    pub fn exec_keys(&self) -> Vec<&str> {
        let mut keys = Vec::new();
        for op in &self.ops {
            match op {
                TraceOp::ExecRank { key, .. } | TraceOp::ExecAll { key, .. } => {
                    keys.push(key.as_str())
                }
                _ => {}
            }
        }
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_trace_projects_identical_streams() {
        let t = DispatchTrace {
            label: "t".into(),
            ranks: 2,
            ops: vec![
                TraceOp::UploadAll { name: "pos".into() },
                TraceOp::BroadcastResident { name: "act".into(), elems: 8 },
                TraceOp::ReduceInto {
                    partial: "act.partial".into(),
                    dest: "act".into(),
                    elems: 8,
                },
            ],
        };
        let streams = t.rank_collective_streams();
        assert_eq!(streams.len(), 2);
        assert_eq!(streams[0], streams[1]);
        assert_eq!(streams[0].len(), 2, "upload is not a collective");
        assert_eq!(streams[0][0].kind, CollectiveKind::Broadcast);
        assert_eq!(streams[0][1].kind, CollectiveKind::Reduce);
        assert_eq!(streams[0][1].to_string(), "reduce `act.partial` [8 elems]");
    }

    #[test]
    fn exec_keys_lists_both_exec_forms() {
        let t = DispatchTrace {
            label: "t".into(),
            ranks: 1,
            ops: vec![
                TraceOp::EnsureExecs { keys: vec!["a".into()] },
                TraceOp::ExecRank {
                    rank: 0,
                    key: "a".into(),
                    reads: vec![],
                    writes: vec![],
                },
                TraceOp::ExecAll { key: "b".into(), per_rank: vec![] },
            ],
        };
        assert_eq!(t.exec_keys(), vec!["a", "b"]);
    }
}
