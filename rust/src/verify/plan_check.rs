//! plan_check: structural verification of the manifest's plan-variant
//! registry and its executable/bucket/chunk consistency.
//!
//! Per variant: each transformer layer covered **exactly once**, stage
//! arity 1 (TP) or 2 (LP pair), LP pairs adjacent (`[i, i+1]`), LP pairs
//! forming a contiguous band (warning — a gapped band is servable but
//! almost certainly a manifest typo), and every executable the stage walk
//! binds present in the `artifacts` section. Per model: batch buckets
//! within the slot count and unique, `prefill_chunk` dividing `ctx`, and —
//! when the manifest carries a `kv_pages` section — the paged-KV geometry
//! consistent (`page_tokens` dividing `prefill_chunk` so a chunk step
//! never straddles a partial page, and each pool at least
//! `slots × blocks_per_slot + 1` pages so a fully dense occupancy plus the
//! scratch page fits without eviction).

use crate::model::plan::GraphPlan;
use crate::model::serving::{chunk_exec_keys, decode_exec_keys, prefill_exec_keys, serve_stages};
use crate::runtime::ModelEntry;

use super::{Check, Diagnostic, Severity};

/// Run the plan analysis over one model entry. `seq_buckets` and
/// `prefill_chunk` come from the manifest top level.
pub fn check_model(
    model: &str,
    entry: &ModelEntry,
    seq_buckets: &[usize],
    prefill_chunk: Option<usize>,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let cfg = &entry.config;

    // ---- model-level: bucket registry + chunk consistency ------------------
    let mut seen_buckets = std::collections::BTreeSet::new();
    for &b in &entry.batch_buckets {
        if b > cfg.slots {
            diags.push(Diagnostic::error(
                Check::Plan,
                model,
                None,
                "plan.bucket-exceeds-slots",
                format!("batch bucket {b} exceeds the slot count {}", cfg.slots),
            ));
        }
        if !seen_buckets.insert(b) {
            diags.push(Diagnostic::error(
                Check::Plan,
                model,
                None,
                "plan.bucket-duplicate",
                format!("batch bucket {b} listed more than once"),
            ));
        }
    }
    if let Some(k) = prefill_chunk {
        if cfg.ctx % k != 0 {
            diags.push(Diagnostic::error(
                Check::Plan,
                model,
                None,
                "plan.chunk-not-dividing-ctx",
                format!(
                    "prefill_chunk {k} does not divide ctx {} — the final chunk's \
                     cache window would run out of bounds",
                    cfg.ctx
                ),
            ));
        }
    }
    if let Some(kvp) = &entry.kv_pages {
        if let Some(k) = prefill_chunk {
            if kvp.page_tokens == 0 || k % kvp.page_tokens != 0 {
                diags.push(Diagnostic::error(
                    Check::Plan,
                    model,
                    None,
                    "plan.page-not-dividing-chunk",
                    format!(
                        "kv_pages page_tokens {} does not divide prefill_chunk {k} — a \
                         chunk step would straddle a partial page",
                        kvp.page_tokens
                    ),
                ));
            }
        }
        let min = kvp.min_pool_pages(cfg.slots);
        for (pool, pages) in
            [("half", kvp.pool_pages_half), ("full", kvp.pool_pages_full)]
        {
            if pages < min {
                diags.push(Diagnostic::error(
                    Check::Plan,
                    model,
                    None,
                    "plan.page-pool-too-small",
                    format!(
                        "kv_pages pool_pages_{pool} = {pages} is below the minimum {min} \
                         ({} slots × {} blocks + the scratch page)",
                        cfg.slots, kvp.blocks_per_slot
                    ),
                ));
            }
        }
    }

    // ---- per-variant: coverage, adjacency, executables ---------------------
    for spec in entry.variants.values() {
        let vid = &spec.id;
        let n = cfg.n_layers;
        let err = |code, message| Diagnostic::error(Check::Plan, model, Some(vid), code, message);
        let mut counts = vec![0usize; n];
        // arity/range problems make "layer missing" cascade noise — track
        // them and report coverage only for structurally sound walks
        let mut structural = true;
        if spec.stages.is_empty() {
            diags.push(err(
                "plan.no-stages",
                "variant has no stages (embed→logits with every layer skipped)".into(),
            ));
            continue;
        }
        for st in &spec.stages {
            if st.is_empty() || st.len() > 2 {
                diags.push(err(
                    "plan.stage-arity",
                    format!("stage {st:?} has arity {}, want 1 (TP) or 2 (LP pair)", st.len()),
                ));
                structural = false;
                continue;
            }
            for &l in st {
                if l >= n {
                    diags.push(err(
                        "plan.layer-out-of-range",
                        format!("layer {l} out of range (model has {n} layers)"),
                    ));
                    structural = false;
                } else {
                    counts[l] += 1;
                }
            }
            if let &[a, b] = st.as_slice() {
                if b != a + 1 {
                    diags.push(err(
                        "plan.pair-not-adjacent",
                        format!("LP pair [{a}, {b}] is not adjacent (want [i, i+1])"),
                    ));
                }
            }
        }
        for (l, &c) in counts.iter().enumerate() {
            if c > 1 {
                diags.push(err(
                    "plan.layer-covered-twice",
                    format!("layer {l} covered by {c} stages (want exactly once)"),
                ));
            } else if c == 0 && structural {
                diags.push(err(
                    "plan.layer-missing",
                    format!("layer {l} not covered by any stage (want exactly once)"),
                ));
            }
        }

        // Dispatch-level structure needs a parseable plan; GraphPlan
        // re-validates reuse/range, so a failure here was reported above.
        let Ok(plan) = GraphPlan::from_stage_lists(n, &spec.stages) else { continue };
        if !plan.lp_band_contiguous() {
            diags.push(Diagnostic::warn(
                Check::Plan,
                model,
                Some(vid),
                "plan.band-not-contiguous",
                format!(
                    "LP pairs cover layers {:?} — not one contiguous band; servable, \
                     but the paper's transform always parallelizes a contiguous window",
                    plan.lp_layers()
                ),
            ));
        }
        let Ok(stages) = serve_stages(&plan) else { continue };

        for key in decode_exec_keys(&stages, "") {
            if !entry.artifacts.contains_key(&key) {
                diags.push(err(
                    "plan.missing-executable",
                    format!("decode executable `{key}` not in the manifest artifacts"),
                ));
            }
        }
        for &t in seq_buckets {
            for key in prefill_exec_keys(&stages, t) {
                if !entry.artifacts.contains_key(&key) {
                    diags.push(err(
                        "plan.missing-executable",
                        format!(
                            "prefill executable `{key}` (seq bucket {t}) not in the \
                             manifest artifacts"
                        ),
                    ));
                }
            }
        }
        if prefill_chunk.is_some() {
            for key in chunk_exec_keys(&stages) {
                if !entry.artifacts.contains_key(&key) {
                    diags.push(err(
                        "plan.chunk-missing-executable",
                        format!(
                            "chunk executable `{key}` not in the manifest artifacts \
                             (prefill_chunk is set)"
                        ),
                    ));
                }
            }
        }
        // Missing bucket executables are a warning: the runtime registers
        // only complete buckets and falls back to the fixed-[S] path.
        for &b in &entry.batch_buckets {
            for key in decode_exec_keys(&stages, &format!("_b{b}")) {
                if !entry.artifacts.contains_key(&key) {
                    diags.push(Diagnostic {
                        severity: Severity::Warn,
                        ..err(
                            "plan.bucket-missing-executable",
                            format!(
                                "bucket executable `{key}` (batch bucket {b}) not in the \
                                 manifest artifacts — the bucket will silently fall back \
                                 to the fixed-[S] path"
                            ),
                        )
                    });
                }
            }
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelConfig, VariantId, VariantSpec};
    use std::collections::BTreeMap;

    fn mini_cfg() -> ModelConfig {
        ModelConfig {
            name: "td-mini".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 4,
            n_heads: 2,
            head_dim: 8,
            d_ff: 32,
            ctx: 64,
            slots: 2,
        }
    }

    fn entry_with(stages: Vec<Vec<usize>>) -> ModelEntry {
        let mut variants = BTreeMap::new();
        let id = VariantId::new("t");
        variants.insert(id.clone(), VariantSpec { id, stages });
        ModelEntry {
            config: mini_cfg(),
            batch_buckets: vec![],
            kv_pages: None,
            variants,
            artifacts: BTreeMap::new(),
        }
    }

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn exactly_once_violations_are_flagged() {
        let d = check_model("m", &entry_with(vec![vec![0], vec![0], vec![1], vec![2], vec![3]]), &[], None);
        assert!(codes(&d).contains(&"plan.layer-covered-twice"), "{d:?}");
        let d = check_model("m", &entry_with(vec![vec![0], vec![1], vec![2]]), &[], None);
        assert!(codes(&d).contains(&"plan.layer-missing"), "{d:?}");
        let d = check_model("m", &entry_with(vec![vec![0], vec![1], vec![2], vec![9]]), &[], None);
        assert!(codes(&d).contains(&"plan.layer-out-of-range"), "{d:?}");
        assert!(
            !codes(&d).contains(&"plan.layer-missing"),
            "range errors must not cascade into missing-layer noise: {d:?}"
        );
    }

    #[test]
    fn pair_adjacency_and_band_contiguity() {
        let d = check_model("m", &entry_with(vec![vec![0, 2], vec![1], vec![3]]), &[], None);
        assert!(codes(&d).contains(&"plan.pair-not-adjacent"), "{d:?}");
        // a single (trivially contiguous) pair: no band warning
        let d = check_model("m", &entry_with(vec![vec![0, 1], vec![2], vec![3]]), &[], None);
        assert!(!codes(&d).contains(&"plan.band-not-contiguous"), "single pair: {d:?}");
        // two adjacent pairs with a TP layer between them: servable, warned
        let mut cfg = mini_cfg();
        cfg.n_layers = 6;
        let mut variants = BTreeMap::new();
        let id = VariantId::new("t");
        variants.insert(
            id.clone(),
            VariantSpec { id, stages: vec![vec![0, 1], vec![2], vec![4, 5], vec![3]] },
        );
        let gapped = ModelEntry {
            config: cfg,
            batch_buckets: vec![],
            kv_pages: None,
            variants,
            artifacts: BTreeMap::new(),
        };
        let d = check_model("m", &gapped, &[], None);
        let band: Vec<_> =
            d.iter().filter(|x| x.code == "plan.band-not-contiguous").collect();
        assert_eq!(band.len(), 1, "{d:?}");
        assert_eq!(band[0].severity, Severity::Warn);
        assert!(band[0].to_string().contains("variant `t`"));
    }

    #[test]
    fn missing_executables_are_variant_qualified() {
        // empty artifacts section: every decode key the walk binds is missing
        let d = check_model("m", &entry_with(vec![vec![0], vec![1], vec![2, 3]]), &[32], None);
        let missing: Vec<_> =
            d.iter().filter(|x| x.code == "plan.missing-executable").collect();
        assert!(!missing.is_empty());
        assert!(missing.iter().all(|x| x.variant == Some(VariantId::new("t"))));
        // both families bound: tp (stages [0],[1]) and lp (pair [2,3])
        let msgs: Vec<String> = missing.iter().map(|x| x.message.clone()).collect();
        assert!(msgs.iter().any(|m| m.contains("tpattn_decode")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("lpattn_decode")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("lpattn_prefill_t32")), "{msgs:?}");
    }

    #[test]
    fn bucket_and_chunk_consistency() {
        let mut e = entry_with(vec![vec![0], vec![1], vec![2], vec![3]]);
        e.batch_buckets = vec![1, 2, 2, 64];
        let d = check_model("m", &e, &[], Some(24));
        let c = codes(&d);
        assert!(c.contains(&"plan.bucket-exceeds-slots"), "{d:?}");
        assert!(c.contains(&"plan.bucket-duplicate"), "{d:?}");
        assert!(c.contains(&"plan.chunk-not-dividing-ctx"), "{d:?}");
        assert!(c.contains(&"plan.chunk-missing-executable"), "{d:?}");
        assert!(c.contains(&"plan.bucket-missing-executable"), "{d:?}");
    }

    #[test]
    fn kv_pages_geometry_violations() {
        use crate::runtime::KvPages;
        // mini_cfg: ctx 64, slots 2. page 24 does not divide chunk 32;
        // pools of 9 cover 2 slots × 4 blocks + scratch exactly
        let mut e = entry_with(vec![vec![0], vec![1], vec![2], vec![3]]);
        e.kv_pages = Some(KvPages {
            page_tokens: 24,
            blocks_per_slot: 4,
            pool_pages_half: 9,
            pool_pages_full: 9,
        });
        let d = check_model("m", &e, &[], Some(32));
        let c = codes(&d);
        assert!(c.contains(&"plan.page-not-dividing-chunk"), "{d:?}");
        assert!(!c.contains(&"plan.page-pool-too-small"), "{d:?}");

        // page geometry fine, but the half pool is one page short of the
        // minimum 2 slots × 2 blocks + scratch = 5
        e.kv_pages = Some(KvPages {
            page_tokens: 32,
            blocks_per_slot: 2,
            pool_pages_half: 4,
            pool_pages_full: 5,
        });
        let d = check_model("m", &e, &[], Some(32));
        let small: Vec<_> =
            d.iter().filter(|x| x.code == "plan.page-pool-too-small").collect();
        assert_eq!(small.len(), 1, "{d:?}");
        assert!(small[0].message.contains("pool_pages_half"), "{}", small[0]);
        assert!(!codes(&d).contains(&"plan.page-not-dividing-chunk"), "{d:?}");

        // a well-formed section raises neither code; without prefill_chunk
        // the divisibility check is vacuous
        e.kv_pages = Some(KvPages {
            page_tokens: 32,
            blocks_per_slot: 2,
            pool_pages_half: 5,
            pool_pages_full: 5,
        });
        let d = check_model("m", &e, &[], Some(32));
        assert!(d.iter().all(|x| !x.code.starts_with("plan.page-")), "{d:?}");
        e.kv_pages = Some(KvPages {
            page_tokens: 24,
            blocks_per_slot: 2,
            pool_pages_half: 5,
            pool_pages_full: 5,
        });
        let d = check_model("m", &e, &[], None);
        assert!(!codes(&d).contains(&"plan.page-not-dividing-chunk"), "{d:?}");
    }

    #[test]
    fn stage_arity_and_empty_walks() {
        let d = check_model("m", &entry_with(vec![vec![0, 1, 2], vec![3]]), &[], None);
        assert!(codes(&d).contains(&"plan.stage-arity"), "{d:?}");
        let d = check_model("m", &entry_with(vec![]), &[], None);
        assert_eq!(codes(&d), vec!["plan.no-stages"]);
    }
}
