//! binding_check: abstract interpretation of a dispatch trace against the
//! resident-buffer state of each rank.
//!
//! The interpreter walks one [`DispatchTrace`] op by op, tracking per rank
//! the set of resident buffer names written so far (seeded with the
//! post-`upload_weights`/`init_caches` resident set) and the set of
//! ensured executables. Every `ArgRef::Resident` read must be preceded by
//! a write on that rank; every exec key must be ensured and not released;
//! weight-key and KV-key misses get their own diagnostic codes so a
//! manifest/schema mismatch reads differently from a protocol ordering
//! bug.

use std::collections::BTreeSet;

use crate::runtime::VariantId;

use super::trace::{DispatchTrace, TraceOp};
use super::{Check, Diagnostic};

/// Classify a missing-read diagnostic by the name's key schema — the
/// recognizers live in [`crate::runtime::keys`], the same module the
/// loader and the dispatch paths build the names from, so the checker
/// cannot drift from the schema it checks. Covers both the dense
/// per-variant caches (`kv.*`) and the shared paged pools (`kvpool.*`).
fn missing_read_code(name: &str) -> &'static str {
    if crate::runtime::keys::is_kv_key(name) {
        "binding.missing-kv-key"
    } else if crate::runtime::keys::is_weight_key(name) {
        "binding.missing-weight-key"
    } else {
        "binding.read-before-write"
    }
}

/// Interpret `trace` against the per-rank initial resident sets (index =
/// rank). Returns one diagnostic per violation, `VariantId`-qualified and
/// carrying the trace label so a finding points at one protocol step of
/// one variant.
pub fn binding_check(
    model: &str,
    vid: &VariantId,
    trace: &DispatchTrace,
    initial: &[BTreeSet<String>],
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let label = &trace.label;
    let mut err = |code: &'static str, message: String| {
        diags.push(Diagnostic::error(Check::Binding, model, Some(vid), code, message));
    };

    if initial.len() != trace.ranks {
        err(
            "binding.rank-out-of-range",
            format!(
                "{label}: trace spans {} ranks but the resident model covers {}",
                trace.ranks,
                initial.len()
            ),
        );
        return diags;
    }

    let mut residents: Vec<BTreeSet<String>> = initial.to_vec();
    let mut ensured: BTreeSet<String> = BTreeSet::new();
    let mut released: BTreeSet<String> = BTreeSet::new();

    // shared read/write walk for both exec forms
    let mut step =
        |residents: &mut Vec<BTreeSet<String>>,
         diags: &mut Vec<Diagnostic>,
         rank: usize,
         key: &str,
         reads: &[String],
         writes: &[String]| {
            for r in reads {
                if !residents[rank].contains(r) {
                    diags.push(Diagnostic::error(
                        Check::Binding,
                        model,
                        Some(vid),
                        missing_read_code(r),
                        format!(
                            "{label}: `{key}` on rank {rank} reads resident `{r}` \
                             which was never written on that rank"
                        ),
                    ));
                }
            }
            for w in writes {
                residents[rank].insert(w.clone());
            }
        };

    for op in &trace.ops {
        match op {
            TraceOp::EnsureExecs { keys } => {
                for k in keys {
                    released.remove(k);
                    ensured.insert(k.clone());
                }
            }
            TraceOp::ReleaseExec { key } => {
                ensured.remove(key);
                released.insert(key.clone());
            }
            TraceOp::UploadAll { name } => {
                for r in &mut residents {
                    r.insert(name.clone());
                }
            }
            TraceOp::BroadcastResident { name, .. } => {
                // store_all under the hood: the buffer lands on every rank
                for r in &mut residents {
                    r.insert(name.clone());
                }
            }
            TraceOp::ExecRank { rank, key, reads, writes } => {
                if released.contains(key) {
                    err(
                        "binding.exec-released",
                        format!(
                            "{label}: executable `{key}` used after release \
                             (dangling across ExecCache eviction)"
                        ),
                    );
                } else if !ensured.contains(key) {
                    err(
                        "binding.exec-not-ensured",
                        format!("{label}: executable `{key}` dispatched without EnsureExecs"),
                    );
                }
                if *rank >= trace.ranks {
                    err(
                        "binding.rank-out-of-range",
                        format!(
                            "{label}: `{key}` targets rank {rank} of a {}-rank mesh",
                            trace.ranks
                        ),
                    );
                    continue;
                }
                step(&mut residents, &mut diags, *rank, key, reads, writes);
            }
            TraceOp::ExecAll { key, per_rank } => {
                if released.contains(key) {
                    err(
                        "binding.exec-released",
                        format!(
                            "{label}: executable `{key}` used after release \
                             (dangling across ExecCache eviction)"
                        ),
                    );
                } else if !ensured.contains(key) {
                    err(
                        "binding.exec-not-ensured",
                        format!("{label}: executable `{key}` dispatched without EnsureExecs"),
                    );
                }
                if per_rank.len() != trace.ranks {
                    err(
                        "binding.arity",
                        format!(
                            "{label}: exec_all `{key}` carries {} per-rank calls on a \
                             {}-rank mesh",
                            per_rank.len(),
                            trace.ranks
                        ),
                    );
                    continue;
                }
                for (rank, io) in per_rank.iter().enumerate() {
                    step(&mut residents, &mut diags, rank, key, &io.reads, &io.writes);
                }
            }
            TraceOp::ReduceInto { partial, dest, .. } => {
                // fetches `partial` from every rank, then store_all(dest)
                for (rank, r) in residents.iter_mut().enumerate() {
                    if !r.contains(partial) {
                        diags.push(Diagnostic::error(
                            Check::Binding,
                            model,
                            Some(vid),
                            missing_read_code(partial),
                            format!(
                                "{label}: reduce_into reads partial `{partial}` on rank \
                                 {rank} which was never written on that rank"
                            ),
                        ));
                    }
                    r.insert(dest.clone());
                }
            }
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::super::trace::RankIo;
    use super::*;

    fn vid() -> VariantId {
        VariantId::new("lp")
    }

    fn residents_with(names: &[&str]) -> Vec<BTreeSet<String>> {
        let set: BTreeSet<String> = names.iter().map(|s| (*s).to_string()).collect();
        vec![set.clone(), set]
    }

    fn trace(ops: Vec<TraceOp>) -> DispatchTrace {
        DispatchTrace { label: "decode[lp]@2".into(), ranks: 2, ops }
    }

    fn codes(d: &[Diagnostic]) -> Vec<&'static str> {
        d.iter().map(|x| x.code).collect()
    }

    #[test]
    fn read_before_write_on_plain_buffer() {
        let t = trace(vec![
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::ExecAll {
                key: "k".into(),
                per_rank: vec![
                    RankIo { reads: vec!["act".into()], writes: vec![] },
                    RankIo { reads: vec!["act".into()], writes: vec![] },
                ],
            },
        ]);
        let d = binding_check("m", &vid(), &t, &residents_with(&[]));
        assert_eq!(codes(&d), vec!["binding.read-before-write", "binding.read-before-write"]);
        assert!(d[0].to_string().contains("variant `lp`"), "{}", d[0]);
        assert!(d[0].message.contains("decode[lp]@2"), "{}", d[0]);
    }

    #[test]
    fn write_then_read_is_clean_and_per_rank() {
        let t = trace(vec![
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::BroadcastResident { name: "act".into(), elems: 4 },
            TraceOp::ExecAll {
                key: "k".into(),
                per_rank: vec![
                    RankIo { reads: vec!["act".into()], writes: vec!["act.partial".into()] },
                    RankIo { reads: vec!["act".into()], writes: vec![] },
                ],
            },
            // rank 1 never wrote act.partial → exactly one finding
            TraceOp::ReduceInto { partial: "act.partial".into(), dest: "act".into(), elems: 4 },
        ]);
        let d = binding_check("m", &vid(), &t, &residents_with(&[]));
        assert_eq!(codes(&d), vec!["binding.read-before-write"]);
        assert!(d[0].message.contains("rank 1"), "{}", d[0]);
    }

    #[test]
    fn missing_weight_and_kv_keys_get_schema_codes() {
        let t = trace(vec![
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::ExecAll {
                key: "k".into(),
                per_rank: vec![
                    RankIo {
                        reads: vec![
                            "l0.tp.wq".into(),
                            "kv.lp.k.0".into(),
                            "kvpool.half.k".into(),
                            "lnf".into(),
                        ],
                        writes: vec![],
                    },
                    RankIo { reads: vec![], writes: vec![] },
                ],
            },
        ]);
        let d = binding_check("m", &vid(), &t, &residents_with(&["lnf"]));
        assert_eq!(
            codes(&d),
            vec![
                "binding.missing-weight-key",
                "binding.missing-kv-key",
                "binding.missing-kv-key"
            ]
        );
    }

    #[test]
    fn exec_lifecycle_violations() {
        let t = trace(vec![
            TraceOp::ExecRank { rank: 0, key: "cold".into(), reads: vec![], writes: vec![] },
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::ReleaseExec { key: "k".into() },
            TraceOp::ExecRank { rank: 0, key: "k".into(), reads: vec![], writes: vec![] },
        ]);
        let d = binding_check("m", &vid(), &t, &residents_with(&[]));
        assert_eq!(codes(&d), vec!["binding.exec-not-ensured", "binding.exec-released"]);
        // re-ensure after release clears the dangle
        let t = trace(vec![
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::ReleaseExec { key: "k".into() },
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::ExecRank { rank: 0, key: "k".into(), reads: vec![], writes: vec![] },
        ]);
        assert!(binding_check("m", &vid(), &t, &residents_with(&[])).is_empty());
    }

    #[test]
    fn structural_violations() {
        let t = trace(vec![
            TraceOp::EnsureExecs { keys: vec!["k".into()] },
            TraceOp::ExecRank { rank: 5, key: "k".into(), reads: vec![], writes: vec![] },
            TraceOp::ExecAll {
                key: "k".into(),
                per_rank: vec![RankIo { reads: vec![], writes: vec![] }],
            },
        ]);
        let d = binding_check("m", &vid(), &t, &residents_with(&[]));
        assert_eq!(codes(&d), vec!["binding.rank-out-of-range", "binding.arity"]);
    }
}
