//! collective_check: MPI-style matching of per-rank collective streams.
//!
//! The mesh's collectives (`broadcast_resident`, `reduce_into`) are
//! rendezvous points: every rank must issue the same collective, in the
//! same order, with the same payload shape, or some rank blocks forever
//! waiting for a peer that went elsewhere. This analysis compares each
//! rank's projected collective stream ([`DispatchTrace::
//! rank_collective_streams`](super::trace::DispatchTrace::rank_collective_streams))
//! against rank 0's and turns the three SPMD divergence classes into
//! load-time diagnostics:
//!
//! * `collective.sequence-diverged` — rank r's i-th collective is a
//!   different op or buffer than rank 0's i-th;
//! * `collective.payload-diverged` — same op, different element count
//!   (shape mismatch corrupts the reduction);
//! * `collective.count-diverged` — one rank issues fewer collectives, so
//!   its peers block in a rendezvous it never enters: the deadlock.

use crate::runtime::VariantId;

use super::trace::CollectiveEvent;
use super::{Check, Diagnostic};

/// Match every rank's collective stream against rank 0's. `label` names
/// the protocol step (the trace label) in diagnostics.
pub fn collective_check(
    model: &str,
    vid: &VariantId,
    label: &str,
    streams: &[Vec<CollectiveEvent>],
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let Some(base) = streams.first() else { return diags };
    let mut err = |code: &'static str, message: String| {
        diags.push(Diagnostic::error(Check::Collective, model, Some(vid), code, message));
    };

    for (rank, stream) in streams.iter().enumerate().skip(1) {
        let mut diverged = false;
        for (i, (a, b)) in base.iter().zip(stream.iter()).enumerate() {
            if a.kind != b.kind || a.name != b.name {
                err(
                    "collective.sequence-diverged",
                    format!(
                        "{label}: collective #{i} diverges — rank 0 issues {a}, \
                         rank {rank} issues {b}; the ranks rendezvous in different \
                         collectives and the mesh deadlocks"
                    ),
                );
                diverged = true;
                break; // everything after the first divergence is noise
            }
            if a.elems != b.elems {
                err(
                    "collective.payload-diverged",
                    format!(
                        "{label}: collective #{i} ({a}) carries {} elems on rank \
                         {rank} — shape-mismatched reduction",
                        b.elems
                    ),
                );
            }
        }
        if !diverged && base.len() != stream.len() {
            let (short, long) = if stream.len() < base.len() { (rank, 0) } else { (0, rank) };
            err(
                "collective.count-diverged",
                format!(
                    "{label}: rank 0 issues {} collectives, rank {rank} issues {} — \
                     rank {short} exits the step while rank {long} blocks in its next \
                     collective forever (deadlock)",
                    base.len(),
                    stream.len()
                ),
            );
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::super::trace::CollectiveKind;
    use super::*;

    fn vid() -> VariantId {
        VariantId::new("lp")
    }

    fn ev(kind: CollectiveKind, name: &str, elems: usize) -> CollectiveEvent {
        CollectiveEvent { kind, name: name.into(), elems }
    }

    fn codes(d: &[Diagnostic]) -> Vec<&'static str> {
        d.iter().map(|x| x.code).collect()
    }

    #[test]
    fn uniform_streams_are_clean() {
        let s = vec![
            ev(CollectiveKind::Broadcast, "act", 8),
            ev(CollectiveKind::Reduce, "act.partial", 8),
        ];
        let d = collective_check("m", &vid(), "decode[lp]@2", &[s.clone(), s]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn single_rank_or_empty_is_vacuously_clean() {
        assert!(collective_check("m", &vid(), "t", &[]).is_empty());
        let s = vec![ev(CollectiveKind::Reduce, "x", 1)];
        assert!(collective_check("m", &vid(), "t", &[s]).is_empty());
    }

    #[test]
    fn sequence_divergence_reports_once_per_rank() {
        let a = vec![
            ev(CollectiveKind::Broadcast, "act", 8),
            ev(CollectiveKind::Reduce, "act.partial", 8),
        ];
        let b = vec![
            ev(CollectiveKind::Reduce, "act.partial", 8),
            ev(CollectiveKind::Broadcast, "act", 8),
        ];
        let d = collective_check("m", &vid(), "decode[lp]@2", &[a, b]);
        assert_eq!(codes(&d), vec!["collective.sequence-diverged"]);
        assert!(d[0].to_string().contains("variant `lp`"), "{}", d[0]);
        assert!(d[0].message.contains("rank 1"), "{}", d[0]);
    }

    #[test]
    fn payload_divergence_flags_shape_mismatch() {
        let a = vec![ev(CollectiveKind::Reduce, "act.partial", 8)];
        let b = vec![ev(CollectiveKind::Reduce, "act.partial", 16)];
        let d = collective_check("m", &vid(), "t", &[a, b]);
        assert_eq!(codes(&d), vec!["collective.payload-diverged"]);
    }

    #[test]
    fn count_divergence_names_the_blocked_rank() {
        let a = vec![
            ev(CollectiveKind::Reduce, "act.partial", 8),
            ev(CollectiveKind::Reduce, "act.partial", 8),
        ];
        let b = vec![ev(CollectiveKind::Reduce, "act.partial", 8)];
        let d = collective_check("m", &vid(), "t", &[a, b]);
        assert_eq!(codes(&d), vec!["collective.count-diverged"]);
        assert!(d[0].message.contains("deadlock"), "{}", d[0]);
    }
}
