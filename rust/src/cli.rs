//! Tiny CLI argument parser (substrate: no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of arguments. `flag_names` lists options that take
    /// no value (everything else with `--` expects one, unless `=` is used).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(
            sv(&["run", "--model", "td-small", "--fast", "--n=5", "extra"]),
            &["fast"],
        );
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("model"), Some("td-small"));
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("n", 0), 5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(sv(&["--verbose"]), &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(sv(&[]), &[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert!((a.get_f64("f", 1.5) - 1.5).abs() < 1e-12);
    }
}
