//! Observability: deterministic tracing + metrics export.
//!
//! Everything in this module runs on the **simulated clock** — the
//! mesh's modelled time (`MeshMetrics::modelled_total_ns`, fed by the
//! `parallel/simnet.rs` cost model: roofline compute, α–β collectives,
//! host-link transfers) — never wall clock. That makes every artifact
//! deterministic: two identical runs export byte-identical traces and
//! snapshots, so they can be diffed, archived, and gated in CI exactly
//! like the modelled throughput figures already are.
//!
//! Three layers:
//!
//! * [`Tracer`] ([`tracer`]) — records request-lifecycle spans from the
//!   scheduler (admit → queued → prefill chunks → per-tier decode
//!   rounds → first token → complete, with request/tier attributes) and
//!   absorbs mesh-level events (dispatches, collectives, host
//!   transfers) from the `Mesh::begin_trace` recorder's timed form.
//! * [`chrome`] — exports those events as Chrome trace-event JSON that
//!   loads in Perfetto / `chrome://tracing`: one track per serving slot
//!   and per tier, plus a mesh track.
//! * [`MetricsSnapshot`] ([`snapshot`]) — a machine-readable snapshot
//!   of the counters, histograms and summaries that
//!   `coordinator/metrics.rs` (`ServerMetrics::report`) and
//!   `MeshMetrics` otherwise render only as text; serialized via
//!   `util/json.rs` and flattenable to dotted-key metrics for
//!   `bin/perf_gate.rs`.
//!
//! Wiring: `truedepth serve --trace-out t.json --metrics-out m.json`,
//! the same flags on `examples/serve_batch.rs` and the benches, and
//! `table3_profile --trace-out` for the paper's sync-vs-compute
//! timeline. See the README "Observability" section for the Perfetto
//! workflow.

pub mod chrome;
pub mod snapshot;
pub mod tracer;

pub use snapshot::MetricsSnapshot;
pub use tracer::{TraceEvent, Tracer, Track};
