//! Chrome trace-event JSON exporter.
//!
//! Emits the [trace-event format] understood by Perfetto and
//! `chrome://tracing`: one process, one "thread" (track) per
//! [`Track`], `"X"` complete events for spans and `"i"` instants for
//! point events, timestamps in microseconds of the *simulated* clock.
//! Output is deterministic: tracks get ids in [`Track`]'s `Ord` order,
//! events are stably sorted by start time, and object keys serialize
//! sorted (`util/json.rs` uses `BTreeMap`) — so byte-identical runs
//! yield byte-identical trace files.
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::collections::BTreeMap;

use crate::util::json::{self, Value};

use super::tracer::{TraceEvent, Track};

/// Build the trace-event JSON document for a set of recorded events.
pub fn chrome_trace(events: &[TraceEvent]) -> Value {
    // Deterministic track → tid assignment: collect the distinct tracks
    // and number them in Track's Ord order (scheduler, mesh, slots, tiers).
    let mut tids: BTreeMap<Track, u64> = BTreeMap::new();
    for ev in events {
        tids.entry(ev.track.clone()).or_insert(0);
    }
    for (i, tid) in tids.values_mut().enumerate() {
        *tid = i as u64;
    }

    let mut out: Vec<Value> = Vec::new();
    out.push(json::obj(vec![
        ("ph", json::s("M")),
        ("name", json::s("process_name")),
        ("pid", json::num(0.0)),
        ("args", json::obj(vec![("name", json::s("truedepth (simulated clock)"))])),
    ]));
    for (track, &tid) in &tids {
        out.push(json::obj(vec![
            ("ph", json::s("M")),
            ("name", json::s("thread_name")),
            ("pid", json::num(0.0)),
            ("tid", json::num(tid as f64)),
            ("args", json::obj(vec![("name", json::s(track.label()))])),
        ]));
        out.push(json::obj(vec![
            ("ph", json::s("M")),
            ("name", json::s("thread_sort_index")),
            ("pid", json::num(0.0)),
            ("tid", json::num(tid as f64)),
            ("args", json::obj(vec![("sort_index", json::num(tid as f64))])),
        ]));
    }

    // Stable sort by start time: events at the same simulated instant
    // keep their recording order, so the output is reproducible even
    // when many events share a timestamp.
    let mut evs: Vec<&TraceEvent> = events.iter().collect();
    evs.sort_by_key(|e| e.at_ns);
    for ev in evs {
        let mut pairs = vec![
            ("name", json::s(ev.name.clone())),
            ("cat", json::s(ev.track.category())),
            ("pid", json::num(0.0)),
            ("tid", json::num(tids[&ev.track] as f64)),
            // trace-event timestamps are microseconds
            ("ts", json::num(ev.at_ns as f64 / 1e3)),
        ];
        match ev.dur_ns {
            Some(d) => {
                pairs.push(("ph", json::s("X")));
                pairs.push(("dur", json::num(d as f64 / 1e3)));
            }
            None => {
                pairs.push(("ph", json::s("i")));
                pairs.push(("s", json::s("t"))); // instant scoped to its thread/track
            }
        }
        if !ev.args.is_empty() {
            let m: BTreeMap<String, Value> =
                ev.args.iter().map(|(k, v)| (k.clone(), Value::Str(v.clone()))).collect();
            pairs.push(("args", Value::Obj(m)));
        }
        out.push(json::obj(pairs));
    }

    json::obj(vec![("displayTimeUnit", json::s("ms")), ("traceEvents", json::arr(out))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                name: "req 1".to_string(),
                track: Track::Slot(0),
                at_ns: 2_000,
                dur_ns: Some(5_000),
                args: vec![("tier".to_string(), "lp".to_string())],
            },
            TraceEvent {
                name: "all_reduce".to_string(),
                track: Track::Mesh,
                at_ns: 1_000,
                dur_ns: Some(500),
                args: vec![("bytes".to_string(), "4096".to_string())],
            },
            TraceEvent {
                name: "first_token".to_string(),
                track: Track::Slot(0),
                at_ns: 4_000,
                dur_ns: None,
                args: Vec::new(),
            },
        ]
    }

    #[test]
    fn exports_valid_trace_event_json() {
        let doc = chrome_trace(&sample_events());
        // round-trips through the repo's own parser
        let re = Value::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(re.get("displayTimeUnit").and_then(Value::as_str), Some("ms"));
        let evs = re.get("traceEvents").and_then(Value::as_arr).unwrap();
        // 1 process + 2 tracks × 2 metadata + 3 events
        assert_eq!(evs.len(), 8);
        // tids follow Track order: Mesh (0) before Slot(0) (1)
        let thread_names: Vec<(&str, f64)> = evs
            .iter()
            .filter(|e| e.get("name").and_then(Value::as_str) == Some("thread_name"))
            .map(|e| {
                (
                    e.get("args").unwrap().get("name").and_then(Value::as_str).unwrap(),
                    e.get("tid").and_then(Value::as_f64).unwrap(),
                )
            })
            .collect();
        assert_eq!(thread_names, vec![("mesh", 0.0), ("slot 0", 1.0)]);
        // events are time-sorted: all_reduce (1µs) precedes req 1 (2µs)
        let bodies: Vec<&Value> =
            evs.iter().filter(|e| e.get("ph").and_then(Value::as_str) != Some("M")).collect();
        assert_eq!(bodies[0].get("name").and_then(Value::as_str), Some("all_reduce"));
        assert_eq!(bodies[0].get("ts").and_then(Value::as_f64), Some(1.0));
        assert_eq!(bodies[0].get("dur").and_then(Value::as_f64), Some(0.5));
        assert_eq!(bodies[0].get("ph").and_then(Value::as_str), Some("X"));
        // span args survive; instant carries scope but no duration
        assert_eq!(
            bodies[1].get("args").unwrap().get("tier").and_then(Value::as_str),
            Some("lp")
        );
        assert_eq!(bodies[2].get("ph").and_then(Value::as_str), Some("i"));
        assert_eq!(bodies[2].get("s").and_then(Value::as_str), Some("t"));
        assert!(bodies[2].get("dur").is_none());
    }

    #[test]
    fn export_is_deterministic() {
        let a = chrome_trace(&sample_events()).to_string_pretty();
        let b = chrome_trace(&sample_events()).to_string_pretty();
        assert_eq!(a, b);
    }

    #[test]
    fn empty_trace_is_still_valid() {
        let doc = chrome_trace(&[]);
        let re = Value::parse(&doc.to_string_compact()).unwrap();
        // just the process-name metadata record
        assert_eq!(re.get("traceEvents").and_then(Value::as_arr).unwrap().len(), 1);
    }
}
