//! Span/event recorder on the simulated clock.
//!
//! The scheduler emits request-lifecycle spans directly; mesh-level
//! events arrive pre-timestamped as [`TimedMeshEvent`]s drained from
//! `Mesh::take_timed_trace` — one recorder in the mesh serves both the
//! static verifier and this exporter. All timestamps are modelled-clock
//! nanoseconds, so recorded traces are deterministic.

use std::path::Path;
use std::sync::Mutex;

use crate::error::Result;
use crate::parallel::mesh::{MeshEvent, TimedMeshEvent};
use crate::util::json::Value;

/// Which timeline an event renders on in the exported trace. The derive
/// order is the track order in the viewer: scheduler control events,
/// the mesh, then one track per serving slot and per tier.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Track {
    /// Scheduler control events (admission rejections, shutdown).
    Scheduler,
    /// Mesh dispatches, collectives and host transfers.
    Mesh,
    /// Request-lifecycle spans of the request occupying this slot.
    Slot(usize),
    /// Bucketed decode rounds of one plan-variant tier.
    Tier(String),
}

impl Track {
    /// Category label rendered in the trace (`cat` field).
    pub fn category(&self) -> &'static str {
        match self {
            Track::Scheduler => "scheduler",
            Track::Mesh => "mesh",
            Track::Slot(_) => "slot",
            Track::Tier(_) => "tier",
        }
    }

    /// Human-readable track name (Chrome thread name).
    pub fn label(&self) -> String {
        match self {
            Track::Scheduler => "scheduler".to_string(),
            Track::Mesh => "mesh".to_string(),
            Track::Slot(i) => format!("slot {i}"),
            Track::Tier(t) => format!("tier {t}"),
        }
    }
}

/// One recorded span or instant, in simulated-clock nanoseconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    pub track: Track,
    /// Simulated-clock start of the event, ns.
    pub at_ns: u64,
    /// Span duration, ns; `None` marks an instant event.
    pub dur_ns: Option<u64>,
    /// Attribute key/value pairs (rendered under Chrome `args`).
    pub args: Vec<(String, String)>,
}

/// Thread-safe event sink shared (via `Arc`) between the scheduler and
/// whoever exports the trace at the end of a run.
#[derive(Debug, Default)]
pub struct Tracer {
    events: Mutex<Vec<TraceEvent>>,
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::default()
    }

    /// Record a span covering `[start_ns, end_ns]` on the simulated clock.
    pub fn span(
        &self,
        track: Track,
        name: impl Into<String>,
        start_ns: u64,
        end_ns: u64,
        args: &[(&str, String)],
    ) {
        self.push(TraceEvent {
            name: name.into(),
            track,
            at_ns: start_ns,
            dur_ns: Some(end_ns.saturating_sub(start_ns)),
            args: own(args),
        });
    }

    /// Record a point-in-time event.
    pub fn instant(
        &self,
        track: Track,
        name: impl Into<String>,
        at_ns: u64,
        args: &[(&str, String)],
    ) {
        self.push(TraceEvent { name: name.into(), track, at_ns, dur_ns: None, args: own(args) });
    }

    /// Absorb a batch of timed mesh events (from `Mesh::take_timed_trace`)
    /// onto the mesh track. Zero-duration events render as instants.
    pub fn record_mesh_events(&self, events: Vec<TimedMeshEvent>) {
        let mut log = self.events.lock().unwrap();
        for t in events {
            let (name, args) = describe_mesh_event(&t.event);
            log.push(TraceEvent {
                name,
                track: Track::Mesh,
                at_ns: t.at_ns,
                dur_ns: (t.dur_ns > 0).then_some(t.dur_ns),
                args,
            });
        }
    }

    fn push(&self, ev: TraceEvent) {
        self.events.lock().unwrap().push(ev);
    }

    /// Snapshot of everything recorded so far.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.lock().unwrap().is_empty()
    }

    /// Export as Chrome trace-event JSON (see [`crate::obs::chrome`]).
    pub fn to_chrome_json(&self) -> Value {
        super::chrome::chrome_trace(&self.events())
    }

    /// Write the Chrome trace to `path` (pretty-printed, trailing newline).
    pub fn write_chrome(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_chrome_json().to_string_pretty() + "\n")?;
        Ok(())
    }
}

fn own(args: &[(&str, String)]) -> Vec<(String, String)> {
    args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

/// Render a mesh event as (span name, attributes).
fn describe_mesh_event(ev: &MeshEvent) -> (String, Vec<(String, String)>) {
    match ev {
        MeshEvent::Exec { key, ranks } => {
            (format!("exec {key}"), vec![("ranks".to_string(), ranks.to_string())])
        }
        MeshEvent::ExecRank { key, rank } => {
            (format!("exec[r{rank}] {key}"), vec![("rank".to_string(), rank.to_string())])
        }
        MeshEvent::Upload { name, ranks } => {
            (format!("upload {name}"), vec![("ranks".to_string(), ranks.to_string())])
        }
        MeshEvent::Broadcast { name } => (format!("broadcast {name}"), Vec::new()),
        MeshEvent::Collective { kind, bytes, ranks } => (
            kind.to_string(),
            vec![
                ("bytes".to_string(), bytes.to_string()),
                ("ranks".to_string(), ranks.to_string()),
            ],
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_spans_instants_and_mesh_events() {
        let tr = Tracer::new();
        assert!(tr.is_empty());
        tr.span(Track::Slot(0), "req 1", 100, 350, &[("tier", "lp".to_string())]);
        tr.instant(Track::Scheduler, "reject", 400, &[]);
        tr.record_mesh_events(vec![
            TimedMeshEvent {
                at_ns: 120,
                dur_ns: 80,
                event: MeshEvent::Collective { kind: "all_reduce", bytes: 4096, ranks: 2 },
            },
            TimedMeshEvent {
                at_ns: 200,
                dur_ns: 0,
                event: MeshEvent::Broadcast { name: "h".to_string() },
            },
        ]);
        let evs = tr.events();
        assert_eq!(tr.len(), 4);
        assert_eq!(evs[0].dur_ns, Some(250));
        assert_eq!(evs[0].args, vec![("tier".to_string(), "lp".to_string())]);
        assert_eq!(evs[1].dur_ns, None, "instants carry no duration");
        assert_eq!((evs[2].name.as_str(), evs[2].dur_ns), ("all_reduce", Some(80)));
        assert_eq!(evs[2].track, Track::Mesh);
        assert_eq!(evs[3].dur_ns, None, "zero-cost mesh events render as instants");
    }

    #[test]
    fn span_clamps_inverted_intervals() {
        let tr = Tracer::new();
        tr.span(Track::Mesh, "x", 500, 400, &[]);
        assert_eq!(tr.events()[0].dur_ns, Some(0));
    }

    #[test]
    fn track_order_is_scheduler_mesh_slots_tiers() {
        let mut tracks = vec![
            Track::Tier("dense".to_string()),
            Track::Slot(1),
            Track::Mesh,
            Track::Scheduler,
            Track::Slot(0),
        ];
        tracks.sort();
        assert_eq!(
            tracks,
            vec![
                Track::Scheduler,
                Track::Mesh,
                Track::Slot(0),
                Track::Slot(1),
                Track::Tier("dense".to_string()),
            ]
        );
    }
}
