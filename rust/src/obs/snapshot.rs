//! Machine-readable metrics snapshots.
//!
//! [`MetricsSnapshot`] captures the counters, histograms and latency
//! summaries that `ServerMetrics::report` (`coordinator/metrics.rs`)
//! and `MeshMetrics` (`parallel/mesh.rs`) otherwise render only as
//! text, as one JSON document with a schema marker. Only
//! **deterministic** figures are included — modelled (simulated-clock,
//! `parallel/simnet.rs`) times and pure counters, never wall clock —
//! so two identical runs serialize byte-identically and the file can
//! be diffed or CI-gated like any other modelled metric.
//!
//! `bin/perf_gate.rs` consumes these files via [`MetricsSnapshot::
//! is_snapshot_json`] + [`MetricsSnapshot::flatten`], which turns the
//! nested document into the flat `source.path.to.metric → f64` map the
//! baseline comparison already speaks.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::Ordering;

use crate::coordinator::ServerMetrics;
use crate::error::Result;
use crate::parallel::mesh::MeshMetrics;
use crate::util::json::{self, Value};
use crate::util::stats::Summary;

/// Snapshot of serving + mesh metrics, built section by section.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    source: String,
    sections: BTreeMap<String, Value>,
}

impl MetricsSnapshot {
    /// Schema marker carried by every snapshot document.
    pub const SCHEMA: &'static str = "truedepth.metrics/v1";

    /// `source` names the producing run (e.g. `serve`, `bench_decode`);
    /// it prefixes every flattened metric key.
    pub fn new(source: impl Into<String>) -> MetricsSnapshot {
        MetricsSnapshot { source: source.into(), sections: BTreeMap::new() }
    }

    /// Add the serving-layer section: request/token counters, occupancy
    /// histogram, per-tier decode attribution and the *modelled* latency
    /// summaries. Wall-clock TTFT/latency are deliberately excluded —
    /// they would break run-to-run byte identity.
    pub fn with_server(self, m: &ServerMetrics) -> MetricsSnapshot {
        self.with_server_named("server", m)
    }

    /// Like [`MetricsSnapshot::with_server`] but under a caller-chosen
    /// section name — the cluster exports one section per replica
    /// (`replica0`, `replica1`, …) next to its own `cluster` section.
    pub fn with_server_named(mut self, section: &str, m: &ServerMetrics) -> MetricsSnapshot {
        let load = |a: &std::sync::atomic::AtomicU64| json::num(a.load(Ordering::Relaxed) as f64);
        let mut sec: Vec<(&str, Value)> = vec![
            ("requests_submitted", load(&m.requests_submitted)),
            ("requests_completed", load(&m.requests_completed)),
            ("requests_rejected", load(&m.requests_rejected)),
            ("requests_cancelled", load(&m.requests_cancelled)),
            ("slot_allocs", load(&m.slot_allocs)),
            ("admission_waits", load(&m.admission_waits)),
            ("tokens_generated", load(&m.tokens_generated)),
            ("prefill_tokens", load(&m.prefill_tokens)),
            ("decode_steps", load(&m.decode_steps)),
            ("exec_cache_evictions", load(&m.exec_cache_evictions)),
            ("modelled_decode_ns", load(&m.modelled_decode_ns)),
            ("modelled_decode_tokens", load(&m.modelled_decode_tokens)),
            ("modelled_prefill_ns", load(&m.modelled_prefill_ns)),
            (
                "occupancy_hist",
                json::arr(m.occupancy_histogram().iter().map(|&n| json::num(n as f64)).collect()),
            ),
        ];
        if let Some(tps) = m.modelled_decode_tok_per_s() {
            sec.push(("modelled_decode_tok_per_s", json::num(tps)));
        }
        if let Some(s) = m.modelled_ttft_summary() {
            sec.push(("modelled_ttft_ms", summary_json(&s)));
        }
        if let Some(s) = m.modelled_latency_summary() {
            sec.push(("modelled_latency_ms", summary_json(&s)));
        }
        // paged-KV counters, present only when paging actually ran (gauge
        // or any probe non-zero) so dense snapshots stay byte-stable
        let kv_pages = m.kv_pages_in_use.load(Ordering::Relaxed);
        let kv_lookups = m.kv_prefix_lookups.load(Ordering::Relaxed);
        if kv_pages > 0 || kv_lookups > 0 {
            sec.push((
                "kv",
                json::obj(vec![
                    ("pages_in_use", load(&m.kv_pages_in_use)),
                    ("prefix_lookups", load(&m.kv_prefix_lookups)),
                    ("prefix_hits", load(&m.kv_prefix_hits)),
                    ("prefix_shared_tokens", load(&m.kv_prefix_shared_tokens)),
                    ("evictions", load(&m.kv_evictions)),
                ]),
            ));
        }
        let tiers: BTreeMap<String, Value> = m
            .tier_stats()
            .into_iter()
            .map(|(name, st)| {
                let mut t = vec![
                    ("rounds", json::num(st.rounds as f64)),
                    ("tokens", json::num(st.tokens as f64)),
                    ("modelled_ns", json::num(st.modelled_ns as f64)),
                ];
                if let Some(tps) = st.modelled_tok_per_s() {
                    t.push(("modelled_tok_per_s", json::num(tps)));
                }
                (name, json::obj(t))
            })
            .collect();
        if !tiers.is_empty() {
            sec.push(("tiers", Value::Obj(tiers)));
        }
        self.sections.insert(section.to_string(), json::obj(sec));
        self
    }

    /// Add an arbitrary pre-built section (the cluster layer composes its
    /// own `cluster` section this way). Numeric leaves flatten into the
    /// perf-gate key space like any built-in section.
    pub fn with_section(mut self, name: &str, section: Value) -> MetricsSnapshot {
        self.sections.insert(name.to_string(), section);
        self
    }

    /// Add the mesh section: collective/dispatch/host-transfer counters
    /// plus the modelled clock split (sync / compute / host). The wall
    /// `sync_ns`/`compute_ns` are excluded for the same determinism
    /// reason as above.
    pub fn with_mesh(mut self, m: &MeshMetrics) -> MetricsSnapshot {
        let h = m.host_transfers();
        let sec = json::obj(vec![
            ("sync_ops", json::num(m.sync_ops.load(Ordering::Relaxed) as f64)),
            ("sync_bytes", json::num(m.sync_bytes() as f64)),
            ("exec_ops", json::num(m.exec_ops.load(Ordering::Relaxed) as f64)),
            ("modelled_sync_ns", json::num(m.modelled_sync_ns.load(Ordering::Relaxed) as f64)),
            (
                "modelled_compute_ns",
                json::num(m.modelled_compute_ns.load(Ordering::Relaxed) as f64),
            ),
            ("modelled_host_ns", json::num(m.modelled_host_ns.load(Ordering::Relaxed) as f64)),
            ("modelled_total_ns", json::num(m.modelled_total_ns() as f64)),
            ("modelled_flops", json::num(m.modelled_flops.load(Ordering::Relaxed) as f64)),
            ("host_in_ops", json::num(h.in_ops as f64)),
            ("host_in_bytes", json::num(h.in_bytes as f64)),
            ("host_out_ops", json::num(h.out_ops as f64)),
            ("host_out_bytes", json::num(h.out_bytes as f64)),
        ]);
        self.sections.insert("mesh".to_string(), sec);
        self
    }

    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), json::s(Self::SCHEMA));
        m.insert("source".to_string(), json::s(self.source.clone()));
        for (k, v) in &self.sections {
            m.insert(k.clone(), v.clone());
        }
        Value::Obj(m)
    }

    pub fn to_string_pretty(&self) -> String {
        self.to_json().to_string_pretty() + "\n"
    }

    /// Write the snapshot to `path` (creating parent directories).
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string_pretty())?;
        Ok(())
    }

    /// Does a parsed JSON document carry this snapshot schema?
    pub fn is_snapshot_json(doc: &Value) -> bool {
        doc.get("schema").and_then(Value::as_str) == Some(Self::SCHEMA)
    }

    /// Flatten a snapshot document into `source.section.path → value`
    /// for the perf gate: numeric leaves get dotted keys, nested objects
    /// recurse, arrays and strings are skipped.
    pub fn flatten(doc: &Value) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        let src = doc.get("source").and_then(Value::as_str).unwrap_or("snapshot").to_string();
        if let Some(m) = doc.as_obj() {
            for (k, v) in m {
                if k == "schema" || k == "source" {
                    continue;
                }
                walk(&format!("{src}.{k}"), v, &mut out);
            }
        }
        out
    }
}

fn walk(prefix: &str, v: &Value, out: &mut BTreeMap<String, f64>) {
    match v {
        Value::Num(n) => {
            out.insert(prefix.to_string(), *n);
        }
        Value::Obj(m) => {
            for (k, v) in m {
                walk(&format!("{prefix}.{k}"), v, out);
            }
        }
        _ => {}
    }
}

fn summary_json(s: &Summary) -> Value {
    json::obj(vec![
        ("n", json::num(s.n as f64)),
        ("mean", json::num(s.mean)),
        ("std", json::num(s.std)),
        ("min", json::num(s.min)),
        ("p50", json::num(s.p50)),
        ("p90", json::num(s.p90)),
        ("p99", json::num(s.p99)),
        ("max", json::num(s.max)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loaded_metrics() -> ServerMetrics {
        let m = ServerMetrics::default();
        m.requests_submitted.store(2, Ordering::Relaxed);
        m.record_completion(10.0, 50.0, 8, 9.0, 45.0);
        m.record_completion(20.0, 70.0, 8, 19.0, 65.0);
        m.record_decode_round(2, 1_000_000);
        m.record_tier_round("lp", 2, 1_000_000);
        m
    }

    #[test]
    fn snapshot_roundtrips_and_flattens() {
        let snap = MetricsSnapshot::new("serve").with_server(&loaded_metrics());
        let doc = Value::parse(&snap.to_string_pretty()).unwrap();
        assert!(MetricsSnapshot::is_snapshot_json(&doc));
        assert_eq!(doc.get("source").and_then(Value::as_str), Some("serve"));
        let flat = MetricsSnapshot::flatten(&doc);
        assert_eq!(flat.get("serve.server.requests_completed"), Some(&2.0));
        assert_eq!(flat.get("serve.server.modelled_ttft_ms.p50"), Some(&14.0));
        assert_eq!(flat.get("serve.server.tiers.lp.modelled_tok_per_s"), Some(&2000.0));
        // strings/arrays don't leak into the metric map
        assert!(flat.keys().all(|k| k.starts_with("serve.server.")));
        assert!(!flat.contains_key("serve.server.occupancy_hist"));
    }

    /// The `kv` subsection appears only once paging has done something, so
    /// dense-run snapshots are unchanged byte for byte.
    #[test]
    fn kv_section_is_gated_on_paging_activity() {
        let m = loaded_metrics();
        let dense = MetricsSnapshot::new("serve").with_server(&m).to_string_pretty();
        assert!(!dense.contains("\"kv\""), "{dense}");
        m.record_kv_stats(&crate::model::kvcache::KvStats {
            pages_in_use: 24,
            prefix_lookups: 2,
            prefix_hits: 1,
            prefix_shared_tokens: 64,
            evictions: 0,
        });
        let snap = MetricsSnapshot::new("serve").with_server(&m);
        let doc = Value::parse(&snap.to_string_pretty()).unwrap();
        let flat = MetricsSnapshot::flatten(&doc);
        assert_eq!(flat.get("serve.server.kv.pages_in_use"), Some(&24.0));
        assert_eq!(flat.get("serve.server.kv.prefix_hits"), Some(&1.0));
        assert_eq!(flat.get("serve.server.kv.prefix_shared_tokens"), Some(&64.0));
        assert_eq!(flat.get("serve.server.kv.evictions"), Some(&0.0));
    }

    #[test]
    fn snapshot_excludes_wall_clock_figures() {
        let text = MetricsSnapshot::new("serve").with_server(&loaded_metrics()).to_string_pretty();
        // wall TTFT/latency were recorded (10/20, 50/70 ms) but must not
        // appear: only modelled figures keep the file run-stable
        assert!(!text.contains("\"ttft_ms\""), "{text}");
        assert!(!text.contains("\"latency_ms\""), "{text}");
        assert!(text.contains("\"modelled_ttft_ms\""), "{text}");
    }

    #[test]
    fn identical_metric_states_serialize_identically() {
        let a = MetricsSnapshot::new("x").with_server(&loaded_metrics()).to_string_pretty();
        let b = MetricsSnapshot::new("x").with_server(&loaded_metrics()).to_string_pretty();
        assert_eq!(a, b);
    }

    #[test]
    fn bench_reports_are_not_snapshots() {
        let report = Value::parse(r#"{"group": "g", "metrics": {"m": 1}}"#).unwrap();
        assert!(!MetricsSnapshot::is_snapshot_json(&report));
    }
}
