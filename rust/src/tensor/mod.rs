//! Host tensor: the small dense f32 tensor type used on the coordinator's
//! hot path (residual adds, all-reduce sums, logits post-processing).
//!
//! This is intentionally minimal — heavy math lives in the AOT'd XLA
//! executables; the coordinator only ever touches activation-sized tensors
//! ([T, D], [S, V]), so simple contiguous loops are at memory-bandwidth
//! roofline already (verified in `benches/bench_hostops.rs`).

use crate::error::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::msg(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// In-place element-wise add (residual / reduce combinator).
    pub fn add_assign(&mut self, other: &HostTensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::msg(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        add_slices(&mut self.data, &other.data);
        Ok(())
    }

    /// Row view for a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let cols = *self.shape.last().unwrap();
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }
}

/// `dst += src`, the innermost loop of both the residual add and the
/// all-reduce; written as an exact-size iterator pair so LLVM vectorizes.
#[inline]
pub fn add_slices(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d += *s;
    }
}

/// Sum of n slices into a fresh buffer (used by the collective).
pub fn sum_slices(parts: &[&[f32]]) -> Vec<f32> {
    let mut out = parts[0].to_vec();
    for p in &parts[1..] {
        add_slices(&mut out, p);
    }
    out
}

/// Index of the maximum element (greedy sampling).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// log-softmax value of `logits[target]` (perplexity scoring).
pub fn log_softmax_at(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (logits[target] as f64) - m - sum.ln()
}

/// Top-k indices by value, descending (sampling, debug introspection).
///
/// Total order via `f32::total_cmp` — `partial_cmp(..).unwrap()` panicked
/// the serving thread on NaN logits. NaNs are keyed as −∞ so they sink to
/// the back and are never selected ahead of any finite logit.
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let key = |x: f32| if x.is_nan() { f32::NEG_INFINITY } else { x };
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| key(xs[b]).total_cmp(&key(xs[a])));
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn add_assign_works() {
        let mut a = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = HostTensor::new(vec![2, 2], vec![10.0, 20.0, 30.0, 40.0]).unwrap();
        a.add_assign(&b).unwrap();
        assert_eq!(a.data, vec![11.0, 22.0, 33.0, 44.0]);
        let c = HostTensor::zeros(vec![3]);
        assert!(a.add_assign(&c).is_err());
    }

    #[test]
    fn sum_and_argmax() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [0.5f32, 0.5, 0.5];
        assert_eq!(sum_slices(&[&a, &b]), vec![1.5, 2.5, 3.5]);
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn log_softmax_normalizes() {
        let l = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&l, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(log_softmax_at(&l, 2) > log_softmax_at(&l, 0));
    }

    #[test]
    fn top_k_sorted() {
        assert_eq!(top_k(&[0.1, 0.9, 0.5, 0.7], 2), vec![1, 3]);
    }

    #[test]
    fn top_k_never_panics_or_prefers_nan() {
        // regression: partial_cmp(..).unwrap() panicked here
        let xs = [f32::NAN, 1.0, f32::NAN, 2.0, 0.5];
        assert_eq!(top_k(&xs, 3), vec![3, 1, 4]);
        // NaNs only appear after every finite logit is exhausted
        let all = top_k(&xs, 5);
        assert_eq!(&all[..3], &[3, 1, 4]);
        // degenerate all-NaN input: still total-ordered, no panic
        assert_eq!(top_k(&[f32::NAN, f32::NAN], 1).len(), 1);
    }

    #[test]
    fn rows_view() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.rows(), 2);
    }
}
