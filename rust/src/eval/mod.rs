//! Evaluation: perplexity on the held-out synthetic corpus and the 5-shot
//! ICL task suite (the lm-eval stand-in — see DESIGN.md §Substitutions).

pub mod icl;
pub mod ppl;

pub use icl::{IclReport, IclTask};
pub use ppl::{eval_windows, perplexity};
