//! Synthetic 5-shot in-context-learning suite — the lm-eval stand-in.
//!
//! Five tasks over the training distribution's formats (so a well-trained
//! model starts near ceiling, like the paper's pre-trained Llamas on PiQA):
//!
//! | task      | lm-eval analogue      | why |
//! |-----------|-----------------------|-----|
//! | Copy      | easy span tasks       | pure induction head behaviour |
//! | Reverse   | character manipulation| positional circuits |
//! | Pattern   | sequence completion   | relational generalization |
//! | Relation  | factual recall (MMLU-ish) | memorized associations |
//! | Arith     | GSM-8K                | sparse arithmetic circuitry — the paper's most LP-fragile benchmark |
//!
//! Scoring is teacher-forced exact match: every answer token must be the
//! argmax given the gold prefix (equivalent to greedy decoding when the
//! model is on-path, and far cheaper to evaluate across many depths).
//! `table1_icl --serving` cross-checks a subset through the true decode
//! path.

use crate::error::Result;
use crate::model::plan::GraphPlan;
use crate::model::Scorer;
use crate::text::corpus;
use crate::text::tokenizer;
use crate::util::rng::SplitMix64;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IclTask {
    Copy,
    Reverse,
    Pattern,
    Relation,
    Arith,
}

pub const ALL_TASKS: [IclTask; 5] =
    [IclTask::Copy, IclTask::Reverse, IclTask::Pattern, IclTask::Relation, IclTask::Arith];

impl IclTask {
    pub fn name(&self) -> &'static str {
        match self {
            IclTask::Copy => "copy",
            IclTask::Reverse => "reverse",
            IclTask::Pattern => "pattern",
            IclTask::Relation => "relation",
            IclTask::Arith => "arith",
        }
    }
}

/// One evaluation sample: a k-shot prompt and the exact expected answer.
#[derive(Clone, Debug)]
pub struct Sample {
    pub prompt: String,
    pub answer: String,
}

/// Split a complete corpus item into (query-prefix, answer).
fn split_item(task: IclTask, item: &str) -> (String, String) {
    match task {
        IclTask::Copy | IclTask::Reverse | IclTask::Pattern => {
            let (q, a) = item.split_once("-> ").expect("item format");
            (format!("{q}-> "), a.trim_end_matches(" .").to_string())
        }
        IclTask::Relation => {
            let (q, a) = item.split_once(" is ").expect("item format");
            (format!("{q} is "), a.trim_end_matches(" .").to_string())
        }
        IclTask::Arith => {
            let (q, a) = item.split_once("= ").expect("item format");
            (format!("{q}= "), a.trim_end_matches(" .").to_string())
        }
    }
}

fn gen_item(task: IclTask, rng: &mut SplitMix64) -> String {
    match task {
        IclTask::Copy => corpus::gen_copy(rng),
        IclTask::Reverse => corpus::gen_reverse(rng),
        IclTask::Pattern => corpus::gen_pattern(rng),
        IclTask::Relation => corpus::gen_relation(rng),
        IclTask::Arith => corpus::gen_arith(rng),
    }
}

/// Build a k-shot sample. Shots and query come from independent draws; the
/// query's full item never appears among the shots.
pub fn gen_sample(task: IclTask, k: usize, rng: &mut SplitMix64) -> Sample {
    let query = gen_item(task, rng);
    let mut shots = Vec::with_capacity(k);
    while shots.len() < k {
        let item = gen_item(task, rng);
        if item != query {
            shots.push(item);
        }
    }
    let (qprefix, answer) = split_item(task, &query);
    let prompt = format!("{} {}", shots.join(" "), qprefix);
    Sample { prompt, answer }
}

/// Teacher-forced exact-match correctness of one sample under `plan`.
/// `scorers` are bucket-sorted alternatives; the smallest bucket that fits
/// the sample is used (5-shot relation prompts exceed 128 tokens).
pub fn sample_correct(scorers: &[&Scorer], plan: &GraphPlan, sample: &Sample) -> Result<bool> {
    let mut ids = tokenizer::encode(&sample.prompt, true, false);
    let prompt_len = ids.len();
    ids.extend(tokenizer::encode(&sample.answer, false, false));
    let Some(scorer) = scorers.iter().find(|s| ids.len() < s.bucket) else {
        return Ok(false); // does not fit any compiled bucket
    };
    let bucket = scorer.bucket;
    let v = scorer.entry.config.vocab;
    let answer_len = ids.len() - prompt_len;
    let padded = tokenizer::pad_to(&ids, bucket)?;
    let logits = scorer.logits(&padded, plan)?;
    for i in 0..answer_len {
        let pos = prompt_len + i; // token at `pos` predicted from `pos - 1`
        let row = &logits[(pos - 1) * v..pos * v];
        if crate::tensor::argmax(row) as i32 != ids[pos] {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Accuracy of `plan` on `n` samples of `task` (k-shot).
pub fn task_accuracy(
    scorers: &[&Scorer],
    plan: &GraphPlan,
    task: IclTask,
    k: usize,
    n: usize,
    seed: u64,
) -> Result<f64> {
    let mut rng = SplitMix64::new(seed ^ task.name().len() as u64 ^ 0xabcdef);
    let mut correct = 0usize;
    for _ in 0..n {
        let s = gen_sample(task, k, &mut rng);
        if sample_correct(scorers, plan, &s)? {
            correct += 1;
        }
    }
    Ok(correct as f64 / n as f64)
}

/// Full-suite report for one plan.
#[derive(Clone, Debug)]
pub struct IclReport {
    pub effective_depth: usize,
    pub per_task: Vec<(IclTask, f64)>,
}

impl IclReport {
    pub fn average(&self) -> f64 {
        self.per_task.iter().map(|(_, a)| a).sum::<f64>() / self.per_task.len() as f64
    }
}

pub fn evaluate_suite(
    scorers: &[&Scorer],
    plan: &GraphPlan,
    k: usize,
    n_per_task: usize,
    seed: u64,
) -> Result<IclReport> {
    let mut per_task = Vec::new();
    for task in ALL_TASKS {
        per_task.push((task, task_accuracy(scorers, plan, task, k, n_per_task, seed)?));
    }
    Ok(IclReport { effective_depth: plan.effective_depth(), per_task })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_well_formed() {
        let mut rng = SplitMix64::new(5);
        for task in ALL_TASKS {
            for _ in 0..20 {
                let s = gen_sample(task, 5, &mut rng);
                assert!(!s.answer.is_empty(), "{task:?}");
                assert!(s.prompt.len() < 220, "{task:?} prompt too long: {}", s.prompt.len());
                assert!(s.prompt.ends_with(' '), "{task:?}");
                // answer must be verifiable from the query in the prompt
                match task {
                    IclTask::Copy => {
                        let q = s.prompt.rsplit("copy : ").next().unwrap();
                        let w = q.split(" ->").next().unwrap();
                        assert_eq!(s.answer, w);
                    }
                    IclTask::Arith => {
                        let tail = s.prompt.rsplit(". ").next().unwrap();
                        let body = tail.trim_end_matches("= ").trim();
                        let parts: Vec<&str> = body.split_whitespace().collect();
                        let (a, op, b): (i64, &str, i64) =
                            (parts[0].parse().unwrap(), parts[1], parts[2].parse().unwrap());
                        let expect = if op == "+" { a + b } else { a - b };
                        assert_eq!(s.answer.parse::<i64>().unwrap(), expect);
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn query_not_leaked_into_shots() {
        let mut rng = SplitMix64::new(17);
        for _ in 0..50 {
            let s = gen_sample(IclTask::Relation, 5, &mut rng);
            let full = format!("{}{} .", s.prompt, s.answer);
            let query_part = full.rsplit(". ").next().unwrap().trim();
            let shots_part = &s.prompt[..s.prompt.len() - query_part.len().min(s.prompt.len())];
            // the exact query item must not appear verbatim among the shots
            assert!(!shots_part.contains(query_part));
        }
    }

    #[test]
    fn trained_model_beats_chance_and_arith_is_fragile() {
        // Integration: requires artifacts + trained checkpoint.
        let Ok(manifest) = crate::runtime::Manifest::load_default() else { return };
        let dir = crate::repo_root().join("checkpoints/td-small");
        if !dir.join("weights.tdw").exists() {
            return;
        }
        let entry = manifest.model("td-small").unwrap();
        let weights = crate::model::Weights::load(&dir, &entry.config).unwrap();
        let engine = crate::runtime::Engine::cpu().unwrap();
        let s128 = Scorer::new(&engine, entry, &weights, 128).unwrap();
        let s256 = Scorer::new(&engine, entry, &weights, 256).unwrap();
        let scorers = [&s128, &s256];
        let n = entry.config.n_layers;
        let plan = crate::model::transform::sequential(n);
        // pattern is the most reliably-acquired skill at small training
        // budgets (copy/reverse need induction heads a 500-step run may
        // not buy); table1_icl reports the full per-task picture.
        let acc = task_accuracy(&scorers, &plan, IclTask::Pattern, 5, 10, 3).unwrap();
        assert!(acc > 0.5, "pattern accuracy {acc}");
    }
}
