//! Perplexity over the held-out corpus split (the RedPajama stand-in).

use crate::error::Result;
use crate::model::plan::GraphPlan;
use crate::model::Scorer;
use crate::text::corpus;
use crate::text::tokenizer;

/// Pack eval documents into `n_windows` windows of `bucket + 1` tokens,
/// deterministic given `seed` (documents are drawn from the eval split,
/// disjoint from training by construction).
pub fn eval_windows(bucket: usize, n_windows: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut windows = Vec::with_capacity(n_windows);
    let mut buf: Vec<i32> = Vec::new();
    let mut doc_idx = 0u64;
    while windows.len() < n_windows {
        while buf.len() < bucket + 1 {
            let doc = corpus::eval_doc(seed, doc_idx);
            doc_idx += 1;
            buf.extend(tokenizer::encode(&doc, true, false));
        }
        windows.push(buf[..bucket + 1].to_vec());
        buf.drain(..bucket + 1);
    }
    windows
}

/// Corpus perplexity of `plan` over pre-built windows: exp(mean NLL).
pub fn perplexity(scorer: &Scorer, plan: &GraphPlan, windows: &[Vec<i32>]) -> Result<f64> {
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in windows {
        let (n, c) = scorer.window_nll(w, plan)?;
        nll += n;
        count += c;
    }
    Ok((nll / count.max(1) as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_are_full_and_deterministic() {
        let a = eval_windows(32, 3, corpus::DATA_SEED);
        let b = eval_windows(32, 3, corpus::DATA_SEED);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|w| w.len() == 33));
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn eval_windows_disjoint_from_training_stream() {
        // Training uses doc indices starting at 0; eval uses EVAL_BASE.
        let train0 = corpus::gen_corpus_doc(corpus::DATA_SEED, 0);
        let eval0 = corpus::eval_doc(corpus::DATA_SEED, 0);
        assert_ne!(train0, eval0);
    }

    #[test]
    fn perplexity_of_trained_model_is_low_and_damage_raises_it() {
        // Integration: requires artifacts + trained checkpoint.
        let Ok(manifest) = crate::runtime::Manifest::load_default() else { return };
        let root = crate::repo_root();
        let dir = root.join("checkpoints/td-small");
        if !dir.join("weights.tdw").exists() {
            return;
        }
        let entry = manifest.model("td-small").unwrap();
        let weights = crate::model::Weights::load(&dir, &entry.config).unwrap();
        let engine = crate::runtime::Engine::cpu().unwrap();
        let scorer = Scorer::new(&engine, entry, &weights, 128).unwrap();
        let windows = eval_windows(128, 2, corpus::DATA_SEED);
        let n = entry.config.n_layers;
        let base = perplexity(&scorer, &crate::model::transform::sequential(n), &windows).unwrap();
        assert!(base < 4.0, "trained model ppl {base}");
        let pruned =
            perplexity(&scorer, &crate::model::transform::prune(n, 2, 8), &windows).unwrap();
        assert!(pruned > base, "pruning must hurt: {pruned} vs {base}");
    }
}
