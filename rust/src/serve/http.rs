//! Minimal HTTP/1.1 primitives for the network edge — std-only, no
//! external dependencies (cargo-deny stays green).
//!
//! Server-side only, and only what the edge needs: a bounded request-head
//! reader, a `Content-Length` body reader, and response writers for plain
//! bodies and chunked SSE streams. Protocol violations surface as
//! [`ApiError`]s so they go out through the same error envelope as every
//! other rejection.

use std::io::{self, BufRead, Read, Write};

use crate::api::{ApiError, ErrorCode};

/// Upper bound on the request head (request line + headers). A client
/// that cannot fit in this never reaches the JSON parser.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Upper bound on a request body. Oversized uploads are rejected from the
/// `Content-Length` header alone, before any body byte is read.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Why a request could not be read off the socket.
#[derive(Debug)]
pub enum ReadError {
    /// The peer closed or the socket failed — nothing to respond to.
    Disconnected,
    /// A protocol violation; answer with this error envelope.
    Bad(ApiError),
}

fn bad(msg: impl Into<String>) -> ApiError {
    ApiError::new(ErrorCode::InvalidRequest, msg)
}

/// Parsed request line + headers.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestHead {
    pub method: String,
    pub path: String,
    headers: Vec<(String, String)>,
}

impl RequestHead {
    /// Header lookup, case-insensitive per RFC 9110.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// The declared body length (0 when the header is absent).
    pub fn content_length(&self) -> Result<usize, ApiError> {
        match self.header("content-length") {
            None => Ok(0),
            Some(v) => v.parse().map_err(|_| bad("invalid Content-Length header")),
        }
    }

    /// `Expect: 100-continue` — the client wants a go-ahead before
    /// sending the body (curl does this for larger uploads).
    pub fn expects_continue(&self) -> bool {
        self.header("expect").is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
    }
}

/// Read the request head off the stream: bytes up to the blank line,
/// bounded by [`MAX_HEAD_BYTES`]. EOF before any byte arrived is a normal
/// connection close ([`ReadError::Disconnected`]), not a protocol error.
pub fn read_head<R: BufRead>(r: &mut R) -> Result<RequestHead, ReadError> {
    let mut buf: Vec<u8> = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                return Err(if buf.is_empty() {
                    ReadError::Disconnected
                } else {
                    ReadError::Bad(bad("truncated request head"))
                });
            }
            Ok(_) => buf.push(byte[0]),
            Err(_) => return Err(ReadError::Disconnected),
        }
        if buf.ends_with(b"\r\n\r\n") {
            break;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(ReadError::Bad(bad("request head too large")));
        }
    }
    let text = std::str::from_utf8(&buf)
        .map_err(|_| ReadError::Bad(bad("request head is not valid UTF-8")))?;
    parse_head(text).map_err(ReadError::Bad)
}

/// Parse a complete head (request line + header lines). Split out of
/// [`read_head`] so the grammar is testable without a stream.
pub fn parse_head(text: &str) -> Result<RequestHead, ApiError> {
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if !m.is_empty() && parts.next().is_none() => (m, p, v),
        _ => return Err(bad(format!("malformed request line `{request_line}`"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad(format!("unsupported protocol `{version}`")));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            break; // the blank line before the body
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad(format!("malformed header line `{line}`")));
        };
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    Ok(RequestHead { method: method.to_string(), path: path.to_string(), headers })
}

/// Read exactly `len` body bytes (the caller has already validated `len`
/// against [`MAX_BODY_BYTES`]) and require UTF-8 — every accepted body is
/// JSON.
pub fn read_body<R: Read>(r: &mut R, len: usize) -> Result<String, ReadError> {
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|_| ReadError::Disconnected)?;
    String::from_utf8(buf).map_err(|_| ReadError::Bad(bad("request body is not valid UTF-8")))
}

/// Reason phrase for the statuses this edge emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "",
    }
}

/// Write a complete non-streaming response. Every response closes the
/// connection — one request per connection keeps the edge stateless.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &str,
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        reason(status),
        body.len(),
    )?;
    w.flush()
}

/// Write an error envelope with its taxonomy-assigned status.
pub fn write_error(w: &mut impl Write, err: &ApiError) -> io::Result<()> {
    write_response(w, err.code.http_status(), "application/json", &err.to_json())
}

/// The interim go-ahead for `Expect: 100-continue`.
pub fn write_continue(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    w.flush()
}

/// Start a streamed (chunked-transfer) SSE response.
pub fn write_sse_header(w: &mut impl Write) -> io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

/// One SSE event (`data: {payload}\n\n`) framed as one HTTP chunk, flushed
/// immediately — the per-token latency IS the product here.
pub fn write_sse_event(w: &mut impl Write, payload: &str) -> io::Result<()> {
    let data = format!("data: {payload}\n\n");
    write!(w, "{:x}\r\n{data}\r\n", data.len())?;
    w.flush()
}

/// The chunked-transfer terminator.
pub fn write_sse_end(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn reads_and_parses_a_request_head() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\nExpect: 100-CONTINUE\r\n\r\n{\"prompt\":1}";
        let mut r = Cursor::new(&raw[..]);
        let head = read_head(&mut r).expect("valid head");
        assert_eq!(head.method, "POST");
        assert_eq!(head.path, "/v1/completions");
        assert_eq!(head.header("HOST"), Some("x"), "header lookup is case-insensitive");
        assert_eq!(head.content_length().unwrap(), 12);
        assert!(head.expects_continue());
        // the body is still unread on the stream
        assert_eq!(read_body(&mut r, 12).unwrap(), "{\"prompt\":1}");
    }

    #[test]
    fn head_errors_are_classified() {
        // clean close before any byte: not a protocol error
        assert!(matches!(read_head(&mut Cursor::new(b"")), Err(ReadError::Disconnected)));
        // bytes then EOF without the blank line: truncated
        let e = read_head(&mut Cursor::new(&b"GET / HTTP/1.1\r\n"[..])).unwrap_err();
        match e {
            ReadError::Bad(e) => assert!(e.message.contains("truncated"), "{e}"),
            other => panic!("{other:?}"),
        }
        // unbounded head: rejected at the cap
        let big = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES));
        let e = read_head(&mut Cursor::new(big.as_bytes())).unwrap_err();
        match e {
            ReadError::Bad(e) => assert!(e.message.contains("too large"), "{e}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_head_rejects_malformed_grammar() {
        for (raw, needle) in [
            ("GET /\r\n\r\n", "malformed request line"),
            ("GET / HTTP/1.1 extra\r\n\r\n", "malformed request line"),
            ("GET / SPDY/3\r\n\r\n", "unsupported protocol"),
            ("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", "malformed header line"),
        ] {
            let e = parse_head(raw).unwrap_err();
            assert_eq!(e.code, ErrorCode::InvalidRequest, "{raw}");
            assert!(e.message.contains(needle), "{raw}: {e}");
        }
        // a bogus Content-Length parses as a head but fails on use
        let head = parse_head("GET / HTTP/1.1\r\nContent-Length: lots\r\n\r\n").unwrap();
        assert!(head.content_length().unwrap_err().message.contains("Content-Length"));
        // absent Content-Length means no body
        assert_eq!(parse_head("GET / HTTP/1.1\r\n\r\n").unwrap().content_length().unwrap(), 0);
    }

    #[test]
    fn response_and_error_bytes_are_exact() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "text/plain", "ok").unwrap();
        assert_eq!(
            String::from_utf8(out).unwrap(),
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain\r\nContent-Length: 2\r\nConnection: close\r\n\r\nok"
        );
        let mut out = Vec::new();
        write_error(&mut out, &ApiError::new(ErrorCode::Overloaded, "busy")).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.ends_with(r#"{"error":{"code":"overloaded","message":"busy"}}"#), "{text}");
    }

    #[test]
    fn sse_stream_uses_chunked_framing() {
        let mut out = Vec::new();
        write_sse_header(&mut out).unwrap();
        write_sse_event(&mut out, r#"{"id":1}"#).unwrap();
        write_sse_event(&mut out, "[DONE]").unwrap();
        write_sse_end(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Content-Type: text/event-stream"), "{text}");
        assert!(text.contains("Transfer-Encoding: chunked"), "{text}");
        // `data: {"id":1}\n\n` is 16 bytes -> chunk size "10" in hex
        assert!(text.contains("10\r\ndata: {\"id\":1}\n\n\r\n"), "{text}");
        assert!(text.ends_with("data: [DONE]\n\n\r\n0\r\n\r\n"), "{text}");
    }
}
