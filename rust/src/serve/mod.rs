//! Network serving edge: a std-only HTTP/1.1 front-end over a serving
//! [`Backend`] — a single [`coordinator::Server`](crate::coordinator::Server)
//! or a multi-replica [`cluster::Cluster`](crate::cluster::Cluster).
//!
//! `truedepth serve --listen <addr> [--replicas R]` lands here. The shape
//! is a classic threadpool accept loop: one acceptor pushes connections
//! into a bounded queue, a fixed set of workers drains it. Both overload
//! paths shed load *before* any KV slot is claimed — a full connection
//! queue answers a canned 429 straight from the acceptor, and the
//! scheduler's admission checks reject over-budget requests with zero
//! slot churn (the loopback test pins `slot_allocs` to the completion
//! count).
//!
//! Routes (see `docs/api.md`, generated from [`crate::api`]):
//!
//! * `POST /v1/completions` — typed completions; `"stream": true` sends
//!   per-token SSE chunks fed straight from the request's
//!   [`TokenEvent`] receiver. Between tokens the worker probes the
//!   client socket, so a disconnected consumer cancels the request at
//!   the next token boundary instead of generating into the void.
//! * `GET /v1/models` — the served model, its tiers, the replica count.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — the live [`obs::MetricsSnapshot`](crate::obs::MetricsSnapshot).
//! * `POST /admin/shutdown` — stop accepting and drain (used by the CI
//!   smoke job; bind to loopback in anything resembling production).

pub mod http;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{
    ApiError, CompletionChunk, CompletionRequest, CompletionResponse, ErrorCode, ModelsResponse,
};
use crate::cluster::Cluster;
use crate::coordinator::{ResponseHandle, Server, TokenEvent};
use crate::error::Result;
use crate::obs::MetricsSnapshot;

/// What the HTTP edge needs from whatever serves the requests. Both the
/// single-server deployment and the lockstep cluster implement it, so
/// `serve --listen` and `serve --listen --replicas R` share the whole
/// edge (parsing, SSE relay, shedding, routes).
pub trait Backend: Send + Sync + 'static {
    /// Submit a typed request; back-pressure must surface as
    /// [`crate::error::Error::Overloaded`] (429) without claiming a slot.
    fn request(&self, req: CompletionRequest) -> Result<ResponseHandle>;
    /// The live `GET /metrics` document.
    fn metrics_snapshot(&self) -> MetricsSnapshot;
    /// The `GET /v1/models` payload.
    fn models(&self) -> ModelsResponse;
}

/// [`Backend`] over one threaded [`Server`] (the classic deployment).
pub struct SingleBackend {
    server: Arc<Server>,
    models: ModelsResponse,
}

impl SingleBackend {
    /// `models` describes the one model the server fronts (the caller
    /// knows the model name + registered tiers; `replicas` should be 1).
    pub fn new(server: Arc<Server>, models: ModelsResponse) -> SingleBackend {
        SingleBackend { server, models }
    }
}

impl Backend for SingleBackend {
    fn request(&self, req: CompletionRequest) -> Result<ResponseHandle> {
        self.server.request(req)
    }

    fn metrics_snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot::new("serve").with_server(&self.server.metrics)
    }

    fn models(&self) -> ModelsResponse {
        self.models.clone()
    }
}

/// [`Backend`] over a lockstep [`Cluster`]: one driver thread steps the
/// cluster whenever work exists, HTTP workers submit through the mutex.
/// (The lockstep core stays single-threaded and deterministic; only the
/// arrival order is wall-clock here, exactly like a real front door.)
pub struct ClusterBackend {
    cluster: Arc<Mutex<Cluster>>,
    stop: Arc<AtomicBool>,
    driver: Mutex<Option<JoinHandle<()>>>,
}

impl ClusterBackend {
    pub fn start(cluster: Cluster) -> ClusterBackend {
        let cluster = Arc::new(Mutex::new(cluster));
        let stop = Arc::new(AtomicBool::new(false));
        let (c2, s2) = (cluster.clone(), stop.clone());
        let driver = std::thread::Builder::new()
            .name("cluster-driver".into())
            .spawn(move || {
                while !s2.load(Ordering::SeqCst) {
                    let busy = c2.lock().unwrap().step();
                    if !busy {
                        // idle: don't spin the mutex against submitters
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            })
            .expect("spawn cluster driver");
        ClusterBackend { cluster, stop, driver: Mutex::new(Some(driver)) }
    }

    /// Direct access for export paths (snapshot/trace after shutdown).
    pub fn cluster(&self) -> Arc<Mutex<Cluster>> {
        self.cluster.clone()
    }

    /// Let in-flight work drain, then stop and join the driver thread.
    /// Safe behind an `Arc` (also runs on drop).
    pub fn shutdown(&self) {
        let handle = self.driver.lock().unwrap().take();
        if let Some(j) = handle {
            loop {
                if self.cluster.lock().unwrap().is_idle() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            self.stop.store(true, Ordering::SeqCst);
            let _ = j.join();
        }
    }
}

impl Drop for ClusterBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Backend for ClusterBackend {
    fn request(&self, req: CompletionRequest) -> Result<ResponseHandle> {
        self.cluster.lock().unwrap().submit(req)
    }

    fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.cluster.lock().unwrap().snapshot("serve")
    }

    fn models(&self) -> ModelsResponse {
        self.cluster.lock().unwrap().models_response()
    }
}

/// Edge sizing knobs.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Worker threads draining the connection queue (= max concurrent
    /// connections being served).
    pub workers: usize,
    /// Bounded connection queue between acceptor and workers; a full
    /// queue sheds the connection with a canned 429 before any parsing.
    pub backlog: usize,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig { workers: 4, backlog: 16 }
    }
}

/// Everything a worker needs besides the connection itself.
struct EdgeState {
    backend: Arc<dyn Backend>,
    shutdown: AtomicBool,
    addr: SocketAddr,
}

/// A running edge. Dropping the handle does NOT stop the listener — call
/// [`HttpHandle::shutdown`] (or POST `/admin/shutdown` and
/// [`HttpHandle::wait`]).
pub struct HttpHandle {
    state: Arc<EdgeState>,
    threads: Vec<JoinHandle<()>>,
}

impl HttpHandle {
    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Block until the edge stops (via [`HttpHandle::shutdown`] from
    /// another thread, or a `POST /admin/shutdown` from the network).
    pub fn wait(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Stop accepting, drain in-flight connections, join the threads.
    pub fn shutdown(self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        // wake the acceptor out of its blocking accept
        let _ = TcpStream::connect(self.state.addr);
        self.wait();
    }
}

/// Bind `addr` and serve `backend` over HTTP until shut down.
pub fn serve(backend: Arc<dyn Backend>, addr: &str, cfg: &HttpConfig) -> Result<HttpHandle> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let state = Arc::new(EdgeState { backend, shutdown: AtomicBool::new(false), addr });
    let (tx, rx) = sync_channel::<TcpStream>(cfg.backlog.max(1));
    let rx = Arc::new(Mutex::new(rx));
    let mut threads = Vec::new();

    let accept_state = state.clone();
    threads.push(
        std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_state.shutdown.load(Ordering::SeqCst) {
                        break; // dropping `tx` drains the workers out
                    }
                    let Ok(stream) = stream else { continue };
                    if let Err(TrySendError::Full(mut stream)) = tx.try_send(stream) {
                        // connection-level load shedding: the queue is the
                        // admission edge, so overload never reaches the
                        // parser (let alone a slot)
                        let err = ApiError::new(
                            ErrorCode::Overloaded,
                            "connection backlog full; retry later",
                        );
                        let _ = http::write_error(&mut stream, &err);
                    }
                }
            })
            .expect("spawn http acceptor"),
    );

    for i in 0..cfg.workers.max(1) {
        let rx: Arc<Mutex<Receiver<TcpStream>>> = rx.clone();
        let state = state.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("http-worker-{i}"))
                .spawn(move || loop {
                    // hold the lock only to dequeue, never while serving
                    let next = rx.lock().unwrap().recv();
                    match next {
                        Ok(stream) => handle_conn(&state, stream),
                        Err(_) => return, // acceptor gone: shutdown
                    }
                })
                .expect("spawn http worker"),
        );
    }

    Ok(HttpHandle { state, threads })
}

/// Serve one connection: one request, one response, close.
fn handle_conn(state: &EdgeState, mut stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let head = match http::read_head(&mut reader) {
        Ok(h) => h,
        Err(http::ReadError::Disconnected) => return,
        Err(http::ReadError::Bad(e)) => {
            let _ = http::write_error(&mut stream, &e);
            return;
        }
    };
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = http::write_response(&mut stream, 200, "text/plain", "ok");
        }
        ("GET", "/metrics") => {
            let snap = state.backend.metrics_snapshot();
            let _ = http::write_response(
                &mut stream,
                200,
                "application/json",
                &snap.to_string_pretty(),
            );
        }
        ("GET", "/v1/models") => {
            let _ = http::write_response(
                &mut stream,
                200,
                "application/json",
                &state.backend.models().to_json(),
            );
        }
        ("POST", "/v1/completions") => {
            handle_completion(state.backend.as_ref(), &head, &mut reader, &mut stream);
        }
        ("POST", "/admin/shutdown") => {
            state.shutdown.store(true, Ordering::SeqCst);
            let _ = http::write_response(&mut stream, 200, "text/plain", "ok");
            // wake the acceptor so the flag is observed
            let _ = TcpStream::connect(state.addr);
        }
        (method, path) => {
            let err = ApiError::new(ErrorCode::NotFound, format!("no route {method} {path}"));
            let _ = http::write_error(&mut stream, &err);
        }
    }
}

/// `POST /v1/completions`: decode the body straight into a typed
/// [`CompletionRequest`] (one event pass, no DOM), hand it to the
/// in-process path, and relay the reply stream.
fn handle_completion(
    backend: &dyn Backend,
    head: &http::RequestHead,
    reader: &mut impl std::io::BufRead,
    stream: &mut TcpStream,
) {
    let len = match head.content_length() {
        Ok(l) => l,
        Err(e) => {
            let _ = http::write_error(stream, &e);
            return;
        }
    };
    if len == 0 {
        let e = ApiError::new(ErrorCode::InvalidRequest, "missing request body");
        let _ = http::write_error(stream, &e);
        return;
    }
    if len > http::MAX_BODY_BYTES {
        // rejected from the header alone — the body is never read
        let e = ApiError::new(
            ErrorCode::InvalidRequest,
            format!("request body of {len} bytes exceeds the {} byte limit", http::MAX_BODY_BYTES),
        );
        let _ = http::write_error(stream, &e);
        return;
    }
    if head.expects_continue() && http::write_continue(stream).is_err() {
        return;
    }
    let body = match http::read_body(reader, len) {
        Ok(b) => b,
        Err(http::ReadError::Disconnected) => return,
        Err(http::ReadError::Bad(e)) => {
            let _ = http::write_error(stream, &e);
            return;
        }
    };
    let req = match CompletionRequest::from_json(&body) {
        Ok(r) => r,
        Err(e) => {
            let _ = http::write_error(stream, &ApiError::from(&e));
            return;
        }
    };
    let streaming = req.stream;
    // back-pressure surfaces here as Error::Overloaded -> 429, before any
    // slot work; admission rejections arrive as the first TokenEvent
    let handle = match backend.request(req) {
        Ok(h) => h,
        Err(e) => {
            let _ = http::write_error(stream, &ApiError::from(&e));
            return;
        }
    };
    if streaming {
        stream_completion(handle, stream);
    } else {
        match handle.wait() {
            Ok(resp) => match &resp.error {
                Some(e) => {
                    let _ = http::write_error(stream, e);
                }
                None => {
                    let _ = http::write_response(
                        stream,
                        200,
                        "application/json",
                        &CompletionResponse::from_response(&resp).to_json(),
                    );
                }
            },
            Err(e) => {
                let _ = http::write_error(stream, &ApiError::from(&e));
            }
        }
    }
}

/// How often a streaming worker probes the client connection while the
/// scheduler has not produced the next token yet.
const STREAM_PROBE_INTERVAL: Duration = Duration::from_millis(50);

/// Relay a reply stream as SSE. The FIRST scheduler event decides the
/// HTTP status line: an admission rejection arrives as an immediate
/// `Done` and goes out as a plain error envelope (429/404/400), never as
/// a 200 stream.
fn stream_completion(handle: ResponseHandle, stream: &mut TcpStream) {
    let Some(first) = handle.next_event() else {
        let e = ApiError::new(ErrorCode::Internal, "scheduler dropped the request");
        let _ = http::write_error(stream, &e);
        return;
    };
    if let TokenEvent::Done(r) = &first {
        if let Some(e) = &r.error {
            let _ = http::write_error(stream, e);
            return;
        }
    }
    if http::write_sse_header(stream).is_err() {
        return;
    }
    let id = handle.id();
    let mut next = Some(first);
    loop {
        match next.take() {
            Some(TokenEvent::Token { index, token, text }) => {
                let chunk = CompletionChunk { id, index, token, text };
                if http::write_sse_event(stream, &chunk.to_json()).is_err() {
                    // client gone mid-stream: dropping `handle` closes the
                    // reply channel, which the scheduler notices at the
                    // next token boundary — slot reclaimed, run continues
                    return;
                }
            }
            Some(TokenEvent::Done(r)) => {
                let payload = match &r.error {
                    Some(e) => e.to_json(),
                    None => CompletionResponse::from_response(&r).to_json(),
                };
                let _ = http::write_sse_event(stream, &payload);
                let _ = http::write_sse_event(stream, "[DONE]");
                let _ = http::write_sse_end(stream);
                return;
            }
            None => {}
        }
        // wait for the next event, probing the socket so a disconnected
        // consumer cancels instead of being generated for invisibly
        loop {
            use std::sync::mpsc::RecvTimeoutError;
            match handle.events().recv_timeout(STREAM_PROBE_INTERVAL) {
                Ok(ev) => {
                    next = Some(ev);
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    if client_gone(stream) {
                        return; // drops `handle` -> cancellation
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    let _ = http::write_sse_end(stream);
                    return;
                }
            }
        }
    }
}

/// Probe whether the peer hung up: a non-blocking read returning 0 bytes
/// means orderly close. (`WouldBlock` — the common case — means alive.)
fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 8];
    // `Read` is implemented for `&TcpStream`, so the probe needs no clone
    let mut half: &TcpStream = stream;
    let gone = match std::io::Read::read(&mut half, &mut probe) {
        Ok(0) => true,
        Ok(_) => false, // stray bytes; a one-request connection ignores them
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}
