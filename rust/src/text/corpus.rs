//! Deterministic synthetic corpus — bit-exact mirror of
//! `python/compile/data.py` (same word tables, same SplitMix64 draws, same
//! templates). Python generates the training stream; rust generates eval
//! and workload streams; golden tests on both sides pin the output.

use crate::util::rng::SplitMix64;

pub const ADJECTIVES: [&str; 16] = [
    "red", "small", "quiet", "bright", "old", "swift", "calm", "brave", "green", "tall", "soft",
    "sharp", "young", "cold", "warm", "plain",
];
pub const NOUNS: [&str; 16] = [
    "fox", "river", "stone", "bird", "tree", "cloud", "wolf", "lamp", "ship", "tower", "field",
    "storm", "book", "road", "horse", "flame",
];
pub const VERBS: [&str; 16] = [
    "watches", "follows", "finds", "passes", "guards", "carries", "meets", "crosses", "holds",
    "leaves", "seeks", "joins", "greets", "trails", "lifts", "turns",
];
pub const COUNTRIES: [&str; 32] = [
    "avaria", "belmora", "cassia", "dorvan", "elyna", "fermont", "galdia", "harwick", "isolde",
    "jorvik", "kelmar", "lorvina", "mendia", "norwell", "ostrava", "pellia", "quorath", "rivona",
    "selwick", "tormund", "ulvania", "verdane", "wystan", "xanthe", "yorvale", "zembla",
    "ardenne", "brovia", "cathmor", "drellin", "eswick", "farlone",
];
pub const CAPITALS: [&str; 32] = [
    "avaport", "belcity", "casburg", "dorhaven", "elyton", "fermouth", "galford", "harmont",
    "isoton", "jorholm", "kelport", "lorgrad", "menfort", "norbury", "ostwick", "pelgrove",
    "quorton", "rivgate", "selmora", "torvale", "ulham", "verdun", "wysport", "xanburg",
    "yorford", "zemholm", "ardfell", "broville", "cathwick", "drelport", "esgard", "farmont",
];
pub const LETTERS: &[u8; 26] = b"abcdefghijklmnopqrstuvwxyz";

/// Positional relation used by the ICL relation-recall task.
pub fn capital_of(country_idx: usize) -> &'static str {
    CAPITALS[country_idx]
}

// --- atomic item generators (draw order must match data.py exactly) -------

pub fn gen_sentence(rng: &mut SplitMix64) -> String {
    let a = ADJECTIVES[rng.below(ADJECTIVES.len() as u64) as usize];
    let n1 = NOUNS[rng.below(NOUNS.len() as u64) as usize];
    let v = VERBS[rng.below(VERBS.len() as u64) as usize];
    let n2 = NOUNS[rng.below(NOUNS.len() as u64) as usize];
    format!("the {a} {n1} {v} the {n2} .")
}

pub fn gen_arith(rng: &mut SplitMix64) -> String {
    // single-digit operands — see python/compile/data.py::gen_arith
    let a = rng.below(10);
    let b = rng.below(10);
    if rng.below(2) == 0 {
        format!("{a} + {b} = {} .", a + b)
    } else {
        let (hi, lo) = (a.max(b), a.min(b));
        format!("{hi} - {lo} = {} .", hi - lo)
    }
}

pub fn gen_relation(rng: &mut SplitMix64) -> String {
    let i = rng.below(COUNTRIES.len() as u64) as usize;
    format!("the capital of {} is {} .", COUNTRIES[i], capital_of(i))
}

fn rand_letters(rng: &mut SplitMix64, lo: u64, hi: u64) -> String {
    let k = lo + rng.below(hi - lo + 1);
    (0..k).map(|_| LETTERS[rng.below(26) as usize] as char).collect()
}

pub fn gen_copy(rng: &mut SplitMix64) -> String {
    let w = rand_letters(rng, 3, 6);
    format!("copy : {w} -> {w} .")
}

pub fn gen_reverse(rng: &mut SplitMix64) -> String {
    let w = rand_letters(rng, 3, 6);
    let r: String = w.chars().rev().collect();
    format!("rev : {w} -> {r} .")
}

pub fn gen_pattern(rng: &mut SplitMix64) -> String {
    let start = rng.below(22) as usize;
    let seq: Vec<char> = (0..4).map(|j| LETTERS[start + j] as char).collect();
    format!("next : {} {} {} -> {} .", seq[0], seq[1], seq[2], seq[3])
}

/// Sampling weights out of 16, matching `data.py::ITEM_WEIGHTS`.
const ITEM_WEIGHTS: [u64; 6] = [6, 3, 3, 1, 1, 2];

pub fn gen_item(rng: &mut SplitMix64) -> String {
    let total: u64 = ITEM_WEIGHTS.iter().sum();
    let r = rng.below(total);
    let mut cum = 0;
    for (k, w) in ITEM_WEIGHTS.iter().enumerate() {
        cum += w;
        if r < cum {
            return match k {
                0 => gen_sentence(rng),
                1 => gen_arith(rng),
                2 => gen_relation(rng),
                3 => gen_copy(rng),
                4 => gen_reverse(rng),
                _ => gen_pattern(rng),
            };
        }
    }
    unreachable!()
}

pub fn gen_document_with(rng: &mut SplitMix64, n_items: usize) -> String {
    (0..n_items).map(|_| gen_item(rng)).collect::<Vec<_>>().join(" ")
}

/// Document `i` of the stream for `seed` — mirror of
/// `data.py::gen_corpus_doc` (per-doc stream, 8 items).
pub fn gen_corpus_doc(seed: u64, i: u64) -> String {
    let mut rng = SplitMix64::new(seed ^ i.wrapping_mul(0x9E3779B97F4A7C15));
    gen_document_with(&mut rng, 8)
}

/// Train/eval split convention shared with python: eval docs start at this
/// index offset.
pub const EVAL_BASE: u64 = 0x4000_0000;

pub fn eval_doc(seed: u64, i: u64) -> String {
    gen_corpus_doc(seed, EVAL_BASE + i)
}

/// The data seed used by `python/train.py` (training distribution); eval
/// must draw from the same distribution.
pub const DATA_SEED: u64 = 20260711;

#[cfg(test)]
mod tests {
    use super::*;

    /// sha256 is not in the vendor set; use the exact golden *prefix* plus
    /// length instead (the full doc hash is pinned on the python side).
    #[test]
    fn golden_doc_matches_python() {
        let doc = gen_corpus_doc(20260711, 0);
        assert!(
            doc.starts_with(
                "the capital of ostrava is ostwick . the old field guards the tree . \
                 the tall wolf seeks the bird . next : l m "
            ),
            "corpus drifted: {}",
            &doc[..doc.len().min(120)]
        );
        assert_eq!(doc.len(), 174);
    }

    #[test]
    fn determinism_and_distinctness() {
        assert_eq!(gen_corpus_doc(1, 5), gen_corpus_doc(1, 5));
        assert_ne!(gen_corpus_doc(1, 5), gen_corpus_doc(1, 6));
        assert_eq!(eval_doc(1, 0), gen_corpus_doc(1, EVAL_BASE));
    }

    #[test]
    fn arithmetic_items_are_correct() {
        let mut rng = SplitMix64::new(123);
        for _ in 0..200 {
            let s = gen_arith(&mut rng);
            let body = s.trim_end_matches(" .");
            let (lhs, rhs) = body.split_once('=').unwrap();
            let parts: Vec<&str> = lhs.split_whitespace().collect();
            let (a, op, b): (i64, &str, i64) =
                (parts[0].parse().unwrap(), parts[1], parts[2].parse().unwrap());
            let expect = if op == "+" { a + b } else { a - b };
            assert_eq!(rhs.trim().parse::<i64>().unwrap(), expect, "{s}");
            assert!(expect >= 0);
        }
    }

    #[test]
    fn reverse_items_are_correct() {
        let mut rng = SplitMix64::new(5);
        for _ in 0..100 {
            let s = gen_reverse(&mut rng);
            let body = s.strip_prefix("rev : ").unwrap().trim_end_matches(" .");
            let (w, r) = body.split_once(" -> ").unwrap();
            assert_eq!(r, w.chars().rev().collect::<String>());
        }
    }

    #[test]
    fn pattern_items_are_consecutive() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..100 {
            let s = gen_pattern(&mut rng);
            let body = s.strip_prefix("next : ").unwrap().trim_end_matches(" .");
            let (seq, nxt) = body.split_once(" -> ").unwrap();
            let idx: Vec<usize> = seq
                .split_whitespace()
                .map(|c| LETTERS.iter().position(|&l| l as char == c.chars().next().unwrap()).unwrap())
                .collect();
            assert_eq!(idx[1], idx[0] + 1);
            assert_eq!(idx[2], idx[1] + 1);
            let n = LETTERS
                .iter()
                .position(|&l| l as char == nxt.chars().next().unwrap())
                .unwrap();
            assert_eq!(n, idx[2] + 1);
        }
    }

    #[test]
    fn relation_tables_aligned() {
        assert_eq!(COUNTRIES.len(), CAPITALS.len());
        let mut rng = SplitMix64::new(1);
        for _ in 0..50 {
            let s = gen_relation(&mut rng);
            let body = s.strip_prefix("the capital of ").unwrap().trim_end_matches(" .");
            let (country, capital) = body.split_once(" is ").unwrap();
            let i = COUNTRIES.iter().position(|&c| c == country).unwrap();
            assert_eq!(capital, CAPITALS[i]);
        }
    }
}
