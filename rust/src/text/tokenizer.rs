//! Byte-level tokenizer — mirror of `python/compile/tok.py`.
//!
//! Vocabulary (V = 260): 0..255 raw bytes, 256 BOS, 257 EOS, 258 PAD,
//! 259 reserved.

pub const VOCAB_SIZE: usize = 260;
pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;

/// UTF-8 bytes to token ids, optionally wrapped in BOS/EOS.
pub fn encode(text: &str, bos: bool, eos: bool) -> Vec<i32> {
    let mut ids = Vec::with_capacity(text.len() + 2);
    if bos {
        ids.push(BOS);
    }
    ids.extend(text.bytes().map(|b| b as i32));
    if eos {
        ids.push(EOS);
    }
    ids
}

/// Token ids back to text; specials dropped, invalid utf-8 replaced.
pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| (0..256).contains(&i))
        .map(|&i| i as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Right-pad to exactly `length` tokens.
///
/// `length < ids.len()` used to silently truncate — dropping the prompt
/// tail and serving a logits row for the wrong token; it is a caller bug
/// (a mis-sized bucket) and is now an error.
pub fn pad_to(ids: &[i32], length: usize) -> crate::error::Result<Vec<i32>> {
    if length < ids.len() {
        return Err(crate::error::Error::msg(format!(
            "pad_to: {} tokens do not fit length {length} (would silently drop the tail)",
            ids.len()
        )));
    }
    let mut out = ids.to_vec();
    out.resize(length, PAD);
    Ok(out)
}

/// The smallest AOT sequence bucket that fits `len` tokens, if any.
pub fn bucket_for(len: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_specials() {
        let s = "the capital of avaria is avaport . 3 + 5 = 8 .";
        let ids = encode(s, true, true);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(decode(&ids), s);
        assert!(ids.iter().all(|&i| (i as usize) < VOCAB_SIZE));
    }

    #[test]
    fn pad_fills_and_rejects_truncation() {
        assert_eq!(pad_to(&[1, 2, 3], 5).unwrap(), vec![1, 2, 3, PAD, PAD]);
        assert_eq!(pad_to(&[1, 2, 3], 3).unwrap(), vec![1, 2, 3]);
        // regression: undersized lengths used to silently drop the tail
        let err = pad_to(&[1, 2, 3, 4, 5], 3).unwrap_err();
        assert!(err.to_string().contains("drop the tail"), "{err}");
    }

    #[test]
    fn bucket_selection() {
        let b = [32, 128, 256];
        assert_eq!(bucket_for(1, &b), Some(32));
        assert_eq!(bucket_for(32, &b), Some(32));
        assert_eq!(bucket_for(33, &b), Some(128));
        assert_eq!(bucket_for(257, &b), None);
    }

    #[test]
    fn unicode_text_roundtrips() {
        let s = "héllo 中文";
        assert_eq!(decode(&encode(s, false, false)), s);
    }
}
