//! Byte-level tokenizer — mirror of `python/compile/tok.py`.
//!
//! Vocabulary (V = 260): 0..255 raw bytes, 256 BOS, 257 EOS, 258 PAD,
//! 259 reserved.

pub const VOCAB_SIZE: usize = 260;
pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;

/// UTF-8 bytes to token ids, optionally wrapped in BOS/EOS.
pub fn encode(text: &str, bos: bool, eos: bool) -> Vec<i32> {
    let mut ids = Vec::with_capacity(text.len() + 2);
    if bos {
        ids.push(BOS);
    }
    ids.extend(text.bytes().map(|b| b as i32));
    if eos {
        ids.push(EOS);
    }
    ids
}

/// Token ids back to text; specials dropped, invalid utf-8 replaced.
pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| (0..256).contains(&i))
        .map(|&i| i as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Right-pad (or truncate) to exactly `length` tokens.
pub fn pad_to(ids: &[i32], length: usize) -> Vec<i32> {
    let mut out: Vec<i32> = ids.iter().copied().take(length).collect();
    out.resize(length, PAD);
    out
}

/// The smallest AOT sequence bucket that fits `len` tokens, if any.
pub fn bucket_for(len: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_specials() {
        let s = "the capital of avaria is avaport . 3 + 5 = 8 .";
        let ids = encode(s, true, true);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(decode(&ids), s);
        assert!(ids.iter().all(|&i| (i as usize) < VOCAB_SIZE));
    }

    #[test]
    fn pad_and_truncate() {
        assert_eq!(pad_to(&[1, 2, 3], 5), vec![1, 2, 3, PAD, PAD]);
        assert_eq!(pad_to(&[1, 2, 3, 4, 5], 3), vec![1, 2, 3]);
    }

    #[test]
    fn bucket_selection() {
        let b = [32, 128, 256];
        assert_eq!(bucket_for(1, &b), Some(32));
        assert_eq!(bucket_for(32, &b), Some(32));
        assert_eq!(bucket_for(33, &b), Some(128));
        assert_eq!(bucket_for(257, &b), None);
    }

    #[test]
    fn unicode_text_roundtrips() {
        let s = "héllo 中文";
        assert_eq!(decode(&encode(s, false, false)), s);
    }
}
