//! Text substrate: byte tokenizer and the deterministic corpus generator
//! (bit-exact mirrors of `python/compile/tok.py` / `data.py`).

pub mod corpus;
pub mod tokenizer;
