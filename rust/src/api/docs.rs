//! `docs/api.md` generator: the committed file is rendered FROM the
//! schema types in this module's parent, so the docs cannot drift from
//! the wire format. Regenerate with `truedepth apidoc > docs/api.md`;
//! the drift test below pins the committed file to the rendered text.

use std::fmt::Write as _;

use super::{
    ApiError, CompletionChunk, CompletionRequest, CompletionResponse, ErrorCode, ModelInfo,
    ModelsResponse,
};

/// One-line "when you get this" note per error code, for the docs table.
fn describe(code: ErrorCode) -> &'static str {
    match code {
        ErrorCode::InvalidRequest => {
            "Malformed JSON, unknown/duplicate field, wrong type, empty prompt, \
             or admission bounds (prompt length, `max_tokens`)"
        }
        ErrorCode::NotFound => "Unknown route",
        ErrorCode::UnknownTier => {
            "`tier` names no manifest plan variant (the message lists the available tiers)"
        }
        ErrorCode::Overloaded => {
            "Queue back-pressure or KV page pools exhausted; retry later, unchanged"
        }
        ErrorCode::Internal => "Model or runtime fault",
    }
}

/// The example payloads the docs embed — also exercised by the wire-shape
/// tests in the parent module, so the documented bytes are tested bytes.
fn fixtures() -> (CompletionRequest, CompletionChunk, CompletionResponse, ApiError, ModelsResponse)
{
    let request = CompletionRequest::new("the red fox").max_tokens(8).tier("lp").stream(true);
    let chunk = CompletionChunk { id: 42, index: 0, token: 104, text: "h".into() };
    let response = CompletionResponse {
        id: 42,
        tier: Some("lp".into()),
        text: "hi".into(),
        tokens: vec![104, 105],
        prompt_tokens: 5,
        ttft_ms: 12.0,
        latency_ms: 96.0,
    };
    let error = ApiError::new(ErrorCode::Overloaded, "queue full (back-pressure)");
    let models = ModelsResponse {
        models: vec![ModelInfo {
            model: "td-small".into(),
            tiers: vec!["dense".into(), "lp".into(), "lp_aggr".into()],
            default_tier: "lp".into(),
        }],
        replicas: 2,
    };
    (request, chunk, response, error, models)
}

/// Render the full `docs/api.md` text.
pub fn render_api_md() -> String {
    let (request, chunk, response, error, models) = fixtures();
    let mut md = String::new();
    md.push_str(
        "# truedepth serving API (v1)\n\
         \n\
         > GENERATED from `rust/src/api/` — edit that module, then regenerate\n\
         > with `truedepth apidoc > docs/api.md`. A drift test pins this file\n\
         > to the schema (`api::docs`).\n\
         \n\
         ## Endpoints\n\
         \n\
         | Method | Path | Description |\n\
         |---|---|---|\n\
         | POST | `/v1/completions` | Run a completion; set `\"stream\": true` for per-token SSE |\n\
         | GET | `/v1/models` | List served models, their tiers, and the replica count |\n\
         | GET | `/healthz` | Liveness probe: `200 ok` while the scheduler runs |\n\
         | GET | `/metrics` | JSON metrics snapshot (schema `truedepth.metrics/v1`) |\n\
         \n\
         ## POST /v1/completions\n\
         \n\
         Request body (`Content-Type: application/json`):\n\
         \n\
         ```json\n",
    );
    let _ = writeln!(md, "{}", request.to_json());
    md.push_str(
        "```\n\
         \n\
         | Field | Type | Default | Meaning |\n\
         |---|---|---|---|\n\
         | `prompt` | string | required | Text to complete |\n\
         | `max_tokens` | int >= 1 | 32 | Generation budget (validated at admission) |\n\
         | `tier` | string | model default | Serving tier: a manifest plan variant (e.g. `dense`, `lp`, `lp_aggr`) |\n\
         | `stream` | bool | false | Stream tokens as SSE instead of one JSON body |\n\
         | `top_k` | int >= 1 | greedy | Switch to top-k sampling with this k |\n\
         | `temperature` | number > 0 | 1 | Softmax temperature (top-k only) |\n\
         | `seed` | int >= 0 | 0 | Sampling seed (top-k only) |\n\
         | `session` | string | none | Multi-turn affinity key: a cluster pins all requests of one session to the same replica so shared-prefix KV reuse stays local (single server: ignored) |\n\
         \n\
         Unknown fields, duplicate fields and wrong types are rejected with\n\
         `400 invalid_request`.\n\
         \n\
         ### Non-streaming response\n\
         \n\
         `200 OK`, `Content-Type: application/json`:\n\
         \n\
         ```json\n",
    );
    let _ = writeln!(md, "{}", response.to_json());
    md.push_str(
        "```\n\
         \n\
         `completion_tokens` always equals the length of `tokens`; `tier` names\n\
         the plan variant that decoded the request.\n\
         \n\
         ### Streaming response (`\"stream\": true`)\n\
         \n\
         `200 OK`, `Content-Type: text/event-stream`, chunked transfer. One SSE\n\
         event per generated token:\n\
         \n\
         ```\n",
    );
    let _ = writeln!(md, "data: {}", chunk.to_json());
    md.push_str(
        "```\n\
         \n\
         After the last token the final response object (the non-streaming\n\
         shape above) arrives as one more `data:` event, then the terminator:\n\
         \n\
         ```\n",
    );
    let _ = writeln!(md, "data: {}\n", response.to_json());
    md.push_str(
        "data: [DONE]\n\
         ```\n\
         \n\
         If the request is rejected at admission, the error status and envelope\n\
         are sent instead of a stream (the first scheduler event decides the\n\
         HTTP status line).\n\
         \n\
         ## Errors\n\
         \n\
         Failures use one envelope shape:\n\
         \n\
         ```json\n",
    );
    let _ = writeln!(md, "{}", error.to_json());
    md.push_str(
        "```\n\
         \n\
         | Code | HTTP | When |\n\
         |---|---|---|\n",
    );
    for code in ErrorCode::ALL {
        let _ =
            writeln!(md, "| `{}` | {} | {} |", code.as_str(), code.http_status(), describe(code));
    }
    md.push_str(
        "\n\
         Rejections (`invalid_request`, `unknown_tier`, `overloaded`) happen\n\
         before any KV slot is claimed: overload sheds load with zero slot\n\
         churn.\n\
         \n\
         ## GET /v1/models\n\
         \n\
         `200 OK`, `Content-Type: application/json`: every model this\n\
         deployment serves, the serving tiers its manifest registers, the\n\
         default tier, and the number of replicas behind the edge (1 for a\n\
         plain `serve --listen`, R for `serve --listen --replicas R`):\n\
         \n\
         ```json\n",
    );
    let _ = writeln!(md, "{}", models.to_json());
    md.push_str(
        "```\n\
         \n\
         ## GET /healthz\n\
         \n\
         `200 OK`, body `ok`.\n\
         \n\
         ## GET /metrics\n\
         \n\
         `200 OK`, `Content-Type: application/json`: the live server's\n\
         `obs::MetricsSnapshot` document (schema `truedepth.metrics/v1`).\n",
    );
    md
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite: the committed `docs/api.md` IS the rendered schema —
    /// any edit to the wire format that forgets to regenerate the docs
    /// (or any hand edit to the docs) fails here.
    #[test]
    fn committed_api_md_matches_rendered_schema() {
        // anchored to the crate manifest, not repo_root(): this test must
        // run even where artifacts/TRUEDEPTH_ROOT are absent (tier-1 CI)
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/api.md");
        let committed = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("docs/api.md must exist at {}: {e}", path.display()));
        let rendered = render_api_md();
        assert!(
            committed == rendered,
            "docs/api.md has drifted from the schema — regenerate with \
             `truedepth apidoc > docs/api.md`"
        );
    }

    #[test]
    fn rendered_docs_embed_the_tested_fixtures() {
        let md = render_api_md();
        let (request, chunk, response, error, models) = super::fixtures();
        for payload in [
            request.to_json(),
            chunk.to_json(),
            response.to_json(),
            error.to_json(),
            models.to_json(),
        ] {
            assert!(md.contains(&payload), "fixture missing from docs: {payload}");
        }
        for code in ErrorCode::ALL {
            assert!(md.contains(code.as_str()), "code missing from docs: {}", code.as_str());
        }
    }
}
