//! Typed completions API schema — the ONE definition of the wire format.
//!
//! Both entry points speak these types: the in-process path
//! (`coordinator::Server::request` takes a [`CompletionRequest`] and
//! replies with [`CompletionResponse`] / streamed [`CompletionChunk`]s)
//! and the HTTP edge (`serve::` decodes request bodies into the same
//! struct and encodes the same structs back out). Field names are
//! versioned constants ([`fields`]); JSON encode/decode goes through
//! `util::json`'s event reader + [`JsonWriter`](crate::util::json::JsonWriter)
//! so a request body never round-trips through a DOM.
//!
//! The error taxonomy lives here too: every [`Error`] variant maps to a
//! stable machine-readable [`ErrorCode`] + HTTP status (asserted in a
//! table-driven test), and failures cross the wire as [`ApiError`].
//!
//! `docs/api.md` is GENERATED from this module (`truedepth apidoc`,
//! [`docs::render_api_md`]) and a drift test pins the committed file to
//! the rendered text — the docs cannot disagree with the code.

pub mod docs;

use crate::coordinator::request::{RequestOptions, Response};
use crate::error::{Error, Result};
use crate::gen::Sampler;
use crate::util::json::{self, Event, JsonWriter};

/// Versioned wire field names (v1). Every JSON key either side emits or
/// accepts is one of these constants — renaming a field is an API break
/// and must bump the version notes in `docs/api.md`.
pub mod fields {
    // request
    pub const PROMPT: &str = "prompt";
    pub const MAX_TOKENS: &str = "max_tokens";
    pub const TIER: &str = "tier";
    pub const STREAM: &str = "stream";
    pub const TOP_K: &str = "top_k";
    pub const TEMPERATURE: &str = "temperature";
    pub const SEED: &str = "seed";
    pub const SESSION: &str = "session";
    // models listing (GET /v1/models)
    pub const MODELS: &str = "models";
    pub const MODEL: &str = "model";
    pub const TIERS: &str = "tiers";
    pub const DEFAULT_TIER: &str = "default_tier";
    pub const REPLICAS: &str = "replicas";
    // response / chunk
    pub const ID: &str = "id";
    pub const INDEX: &str = "index";
    pub const TOKEN: &str = "token";
    pub const TEXT: &str = "text";
    pub const TOKENS: &str = "tokens";
    pub const PROMPT_TOKENS: &str = "prompt_tokens";
    pub const COMPLETION_TOKENS: &str = "completion_tokens";
    pub const TTFT_MS: &str = "ttft_ms";
    pub const LATENCY_MS: &str = "latency_ms";
    // error envelope
    pub const ERROR: &str = "error";
    pub const CODE: &str = "code";
    pub const MESSAGE: &str = "message";
}

/// The request fields [`CompletionRequest::from_json`] accepts; anything
/// else is rejected (fail-fast beats silently ignoring a typo'd knob).
const KNOWN_FIELDS: [&str; 8] = [
    fields::PROMPT,
    fields::MAX_TOKENS,
    fields::TIER,
    fields::STREAM,
    fields::TOP_K,
    fields::TEMPERATURE,
    fields::SEED,
    fields::SESSION,
];

// ---- error taxonomy --------------------------------------------------------

/// Stable machine-readable error codes. The wire string and HTTP status
/// are part of the API contract (table-driven test below); clients switch
/// on `code`, never on message text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed or out-of-bounds request (bad JSON, unknown field,
    /// empty prompt, admission limits) — HTTP 400.
    InvalidRequest,
    /// Unknown route or resource — HTTP 404.
    NotFound,
    /// A serving tier the model's manifest does not carry (the message
    /// names the available tiers) — HTTP 404.
    UnknownTier,
    /// Transient capacity exhaustion: queue back-pressure or page pools.
    /// Retry later, unchanged — HTTP 429.
    Overloaded,
    /// Everything else (model/runtime faults) — HTTP 500.
    Internal,
}

impl ErrorCode {
    /// Every code, in docs order.
    pub const ALL: [ErrorCode; 5] = [
        ErrorCode::InvalidRequest,
        ErrorCode::NotFound,
        ErrorCode::UnknownTier,
        ErrorCode::Overloaded,
        ErrorCode::Internal,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::InvalidRequest => "invalid_request",
            ErrorCode::NotFound => "not_found",
            ErrorCode::UnknownTier => "unknown_tier",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn http_status(self) -> u16 {
        match self {
            ErrorCode::InvalidRequest => 400,
            ErrorCode::NotFound => 404,
            ErrorCode::UnknownTier => 404,
            ErrorCode::Overloaded => 429,
            ErrorCode::Internal => 500,
        }
    }
}

/// A failed request as it crosses the API boundary: stable code + human
/// message. This is what `Response::error` carries and what the HTTP
/// edge serializes as the error envelope.
#[derive(Clone, Debug, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into() }
    }

    /// Prefix the message with a stage label (e.g. `prefill failed`),
    /// keeping the code — classification survives context wrapping.
    pub fn context(mut self, prefix: &str) -> ApiError {
        self.message = format!("{prefix}: {}", self.message);
        self
    }

    /// The error envelope: `{"error":{"code":…,"message":…}}`.
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key(fields::ERROR).begin_obj();
        w.key(fields::CODE).str(self.code.as_str());
        w.key(fields::MESSAGE).str(&self.message);
        w.end_obj();
        w.end_obj();
        w.finish()
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

/// THE taxonomy: every crate error classifies to exactly one code. The
/// message is the error's `Display` text, so existing diagnostics (which
/// callers and tests match on) ride along unchanged.
impl From<&Error> for ApiError {
    fn from(e: &Error) -> ApiError {
        let code = match e {
            Error::Json { .. } | Error::BadRequest(_) => ErrorCode::InvalidRequest,
            Error::UnknownTier { .. } => ErrorCode::UnknownTier,
            Error::Overloaded(_) => ErrorCode::Overloaded,
            _ => ErrorCode::Internal,
        };
        ApiError { code, message: e.to_string() }
    }
}

// ---- request ---------------------------------------------------------------

/// A completions-style request — the single entry type for both the
/// in-process path and the HTTP edge. Build with [`CompletionRequest::new`]
/// + the chainable setters, or decode a wire body with
/// [`CompletionRequest::from_json`].
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionRequest {
    pub prompt: String,
    /// Generation budget (tokens). Admission validates it against the
    /// model's ctx before any KV slot is claimed.
    pub max_tokens: usize,
    /// Serving tier (a manifest plan variant, e.g. `dense`/`lp`/`lp_aggr`);
    /// `None` = the model's default tier.
    pub tier: Option<String>,
    /// HTTP edge only: stream per-token SSE chunks instead of one final
    /// JSON body. Ignored by the in-process path (which always exposes
    /// both via `ResponseHandle`).
    pub stream: bool,
    /// `Some(k)` switches sampling from greedy to top-k.
    pub top_k: Option<usize>,
    /// Softmax temperature for top-k sampling (ignored under greedy).
    pub temperature: f32,
    /// RNG seed for top-k sampling (ignored under greedy).
    pub seed: u64,
    /// Session key for multi-turn conversations. Purely advisory: a
    /// cluster front door pins all requests of one session to the same
    /// replica, so paged-KV shared-prefix reuse stays local. Ignored by a
    /// single server.
    pub session: Option<String>,
}

impl CompletionRequest {
    pub fn new(prompt: impl Into<String>) -> CompletionRequest {
        CompletionRequest {
            prompt: prompt.into(),
            max_tokens: 32,
            tier: None,
            stream: false,
            top_k: None,
            temperature: 1.0,
            seed: 0,
            session: None,
        }
    }

    pub fn max_tokens(mut self, n: usize) -> CompletionRequest {
        self.max_tokens = n;
        self
    }

    pub fn tier(mut self, tier: &str) -> CompletionRequest {
        self.tier = Some(tier.to_string());
        self
    }

    pub fn stream(mut self, on: bool) -> CompletionRequest {
        self.stream = on;
        self
    }

    pub fn top_k(mut self, k: usize) -> CompletionRequest {
        self.top_k = Some(k);
        self
    }

    pub fn temperature(mut self, t: f32) -> CompletionRequest {
        self.temperature = t;
        self
    }

    pub fn seed(mut self, s: u64) -> CompletionRequest {
        self.seed = s;
        self
    }

    pub fn session(mut self, key: &str) -> CompletionRequest {
        self.session = Some(key.to_string());
        self
    }

    /// The sampling policy this request asks for.
    pub fn sampler(&self) -> Sampler {
        match self.top_k {
            Some(k) => Sampler::TopK { k, temperature: self.temperature, seed: self.seed },
            None => Sampler::Greedy,
        }
    }

    /// Lower to the scheduler's option struct.
    pub fn options(&self) -> RequestOptions {
        RequestOptions {
            max_new_tokens: self.max_tokens,
            sampler: self.sampler(),
            tier: self.tier.clone(),
        }
    }

    /// Lift legacy `(prompt, RequestOptions)` pairs — the deprecated
    /// `submit`/`submit_blocking` shims go through here.
    pub fn from_options(prompt: &str, opts: &RequestOptions) -> CompletionRequest {
        let mut req = CompletionRequest::new(prompt).max_tokens(opts.max_new_tokens);
        req.tier = opts.tier.clone();
        if let Sampler::TopK { k, temperature, seed } = opts.sampler {
            req = req.top_k(k).temperature(temperature).seed(seed);
        }
        req
    }

    /// Decode a wire body in one event pass (no DOM): the top level must
    /// be a flat JSON object; unknown fields, duplicate fields, wrong
    /// types and non-positive budgets are each rejected with a specific
    /// `bad request` message.
    pub fn from_json(text: &str) -> Result<CompletionRequest> {
        fn bad(msg: String) -> Error {
            Error::BadRequest(msg)
        }
        fn uint(name: &str, n: f64, min: u64) -> Result<usize> {
            if n.fract() != 0.0 || !n.is_finite() || n < min as f64 || n > 1e12 {
                return Err(bad(format!("field `{name}` must be an integer >= {min}")));
            }
            Ok(n as usize)
        }
        let mut req = CompletionRequest::new("");
        let mut has_prompt = false;
        let mut seen: Vec<String> = Vec::new();
        let mut key: Option<String> = None;
        let mut started = false;
        json::read_events(text, |ev| {
            if !started {
                return match ev {
                    Event::BeginObject => {
                        started = true;
                        Ok(())
                    }
                    _ => Err(bad("request body must be a JSON object".into())),
                };
            }
            match ev {
                Event::Key(k) => {
                    let k = k.into_owned();
                    if !KNOWN_FIELDS.contains(&k.as_str()) {
                        return Err(bad(format!(
                            "unknown field `{k}` (known: {})",
                            KNOWN_FIELDS.join(", ")
                        )));
                    }
                    if seen.iter().any(|s| *s == k) {
                        return Err(bad(format!("duplicate field `{k}`")));
                    }
                    seen.push(k.clone());
                    key = Some(k);
                    Ok(())
                }
                Event::EndObject => Ok(()),
                Event::BeginObject | Event::BeginArray | Event::EndArray => Err(bad(format!(
                    "field `{}`: nested objects/arrays are not supported",
                    key.as_deref().unwrap_or("?")
                ))),
                scalar => {
                    let k = key.take().expect("parser yields values only after keys");
                    match (k.as_str(), scalar) {
                        (fields::PROMPT, Event::Str(s)) => {
                            req.prompt = s.into_owned();
                            has_prompt = true;
                        }
                        (fields::TIER, Event::Str(s)) => req.tier = Some(s.into_owned()),
                        (fields::SESSION, Event::Str(s)) => req.session = Some(s.into_owned()),
                        (fields::STREAM, Event::Bool(b)) => req.stream = b,
                        (fields::MAX_TOKENS, Event::Num(n)) => {
                            req.max_tokens = uint(fields::MAX_TOKENS, n, 1)?;
                        }
                        (fields::TOP_K, Event::Num(n)) => {
                            req.top_k = Some(uint(fields::TOP_K, n, 1)?);
                        }
                        (fields::SEED, Event::Num(n)) => {
                            req.seed = uint(fields::SEED, n, 0)? as u64;
                        }
                        (fields::TEMPERATURE, Event::Num(n)) => {
                            if !n.is_finite() || n <= 0.0 {
                                return Err(bad(format!(
                                    "field `{}` must be a positive number",
                                    fields::TEMPERATURE
                                )));
                            }
                            req.temperature = n as f32;
                        }
                        (_, _) => {
                            return Err(bad(format!("field `{k}`: wrong type")));
                        }
                    }
                    Ok(())
                }
            }
        })?;
        if !has_prompt {
            return Err(bad(format!("missing required field `{}`", fields::PROMPT)));
        }
        Ok(req)
    }

    /// Encode as a wire body (defaulted fields are omitted).
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key(fields::PROMPT).str(&self.prompt);
        w.key(fields::MAX_TOKENS).int(self.max_tokens as i64);
        if let Some(t) = &self.tier {
            w.key(fields::TIER).str(t);
        }
        if self.stream {
            w.key(fields::STREAM).bool(true);
        }
        if let Some(k) = self.top_k {
            w.key(fields::TOP_K).int(k as i64);
            w.key(fields::TEMPERATURE).num(self.temperature as f64);
            w.key(fields::SEED).int(self.seed as i64);
        }
        if let Some(s) = &self.session {
            w.key(fields::SESSION).str(s);
        }
        w.end_obj();
        w.finish()
    }
}

// ---- streamed chunk --------------------------------------------------------

/// One streamed token — the SSE `data:` payload, built straight from the
/// scheduler's per-token event.
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionChunk {
    /// Request id (matches the final response's `id`).
    pub id: u64,
    /// 0-based position of this token in the completion.
    pub index: usize,
    /// The sampled token id.
    pub token: i32,
    /// The token decoded to text (may be empty for special tokens).
    pub text: String,
}

impl CompletionChunk {
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key(fields::ID).int(self.id as i64);
        w.key(fields::INDEX).int(self.index as i64);
        w.key(fields::TOKEN).int(self.token as i64);
        w.key(fields::TEXT).str(&self.text);
        w.end_obj();
        w.finish()
    }
}

// ---- final response --------------------------------------------------------

/// The completed request as it crosses the wire (success shape; failures
/// use the [`ApiError`] envelope instead).
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionResponse {
    pub id: u64,
    /// The serving tier that decoded this request (named even when the
    /// request left tier selection to the model's default).
    pub tier: Option<String>,
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Wall-clock time to first token, ms.
    pub ttft_ms: f64,
    /// Wall-clock total latency, ms.
    pub latency_ms: f64,
}

impl CompletionResponse {
    /// Project the coordinator's internal response onto the wire shape.
    /// Only valid for successes; failures serialize via `ApiError`.
    pub fn from_response(r: &Response) -> CompletionResponse {
        CompletionResponse {
            id: r.id,
            tier: r.tier.clone(),
            text: r.text.clone(),
            tokens: r.tokens.clone(),
            prompt_tokens: r.prompt_tokens,
            ttft_ms: r.ttft_ms,
            latency_ms: r.latency_ms,
        }
    }

    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key(fields::ID).int(self.id as i64);
        if let Some(t) = &self.tier {
            w.key(fields::TIER).str(t);
        }
        w.key(fields::TEXT).str(&self.text);
        w.key(fields::TOKENS).begin_arr();
        for &t in &self.tokens {
            w.int(t as i64);
        }
        w.end_arr();
        w.key(fields::PROMPT_TOKENS).int(self.prompt_tokens as i64);
        w.key(fields::COMPLETION_TOKENS).int(self.tokens.len() as i64);
        w.key(fields::TTFT_MS).num(self.ttft_ms);
        w.key(fields::LATENCY_MS).num(self.latency_ms);
        w.end_obj();
        w.finish()
    }
}

// ---- models listing --------------------------------------------------------

/// One served model as listed by `GET /v1/models`: its name, the serving
/// tiers its manifest registers, and the default tier.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfo {
    pub model: String,
    pub tiers: Vec<String>,
    pub default_tier: String,
}

/// The `GET /v1/models` body: every model the deployment serves plus the
/// replica count behind the edge (1 for a single server, R for a cluster).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelsResponse {
    pub models: Vec<ModelInfo>,
    pub replicas: usize,
}

impl ModelsResponse {
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key(fields::MODELS).begin_arr();
        for m in &self.models {
            w.begin_obj();
            w.key(fields::MODEL).str(&m.model);
            w.key(fields::TIERS).begin_arr();
            for t in &m.tiers {
                w.str(t);
            }
            w.end_arr();
            w.key(fields::DEFAULT_TIER).str(&m.default_tier);
            w.end_obj();
        }
        w.end_arr();
        w.key(fields::REPLICAS).int(self.replicas as i64);
        w.end_obj();
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite: the taxonomy, table-driven — one constructible value per
    /// `Error` variant, its expected code, wire string and HTTP status.
    /// (`Error::Xla` carries an opaque runtime error that cannot be built
    /// here; the `From` impl's wildcard arm classifies it `Internal` like
    /// every other runtime fault.)
    #[test]
    fn every_error_variant_maps_to_a_stable_code_and_status() {
        let table: Vec<(Error, ErrorCode, &str, u16)> = vec![
            (
                Error::Json { at: 3, msg: "bad".into() },
                ErrorCode::InvalidRequest,
                "invalid_request",
                400,
            ),
            (
                Error::BadRequest("empty prompt".into()),
                ErrorCode::InvalidRequest,
                "invalid_request",
                400,
            ),
            (
                Error::UnknownTier { tier: "turbo".into(), available: "dense, lp".into() },
                ErrorCode::UnknownTier,
                "unknown_tier",
                404,
            ),
            (
                Error::Overloaded("queue full".into()),
                ErrorCode::Overloaded,
                "overloaded",
                429,
            ),
            (Error::Io(std::io::Error::other("disk")), ErrorCode::Internal, "internal", 500),
            (Error::Config("c".into()), ErrorCode::Internal, "internal", 500),
            (Error::Weights("w".into()), ErrorCode::Internal, "internal", 500),
            (Error::MissingArtifact("a".into()), ErrorCode::Internal, "internal", 500),
            (Error::Plan("p".into()), ErrorCode::Internal, "internal", 500),
            (Error::Serving("s".into()), ErrorCode::Internal, "internal", 500),
            (Error::Verify("v".into()), ErrorCode::Internal, "internal", 500),
            (Error::Msg("m".into()), ErrorCode::Internal, "internal", 500),
        ];
        for (err, code, wire, status) in table {
            let api = ApiError::from(&err);
            assert_eq!(api.code, code, "{err}");
            assert_eq!(api.code.as_str(), wire, "{err}");
            assert_eq!(api.code.http_status(), status, "{err}");
            // the message is the error's Display text, verbatim
            assert_eq!(api.message, err.to_string());
        }
        // NotFound is minted by the HTTP router (unknown path), not by a
        // crate error — still part of the contract
        assert_eq!(ErrorCode::NotFound.as_str(), "not_found");
        assert_eq!(ErrorCode::NotFound.http_status(), 404);
        // ALL covers every code exactly once
        assert_eq!(ErrorCode::ALL.len(), 5);
        for c in ErrorCode::ALL {
            assert_eq!(ErrorCode::ALL.iter().filter(|&&x| x == c).count(), 1);
        }
    }

    #[test]
    fn error_envelope_and_context() {
        let e = ApiError::new(ErrorCode::Overloaded, "queue full (back-pressure)");
        assert_eq!(
            e.to_json(),
            r#"{"error":{"code":"overloaded","message":"queue full (back-pressure)"}}"#
        );
        let wrapped = e.context("prefill failed");
        assert_eq!(wrapped.code, ErrorCode::Overloaded, "context keeps the code");
        assert_eq!(wrapped.to_string(), "prefill failed: queue full (back-pressure)");
    }

    #[test]
    fn request_roundtrips_through_json() {
        let req = CompletionRequest::new("the red fox").max_tokens(8).tier("lp").stream(true);
        let body = req.to_json();
        assert_eq!(body, r#"{"prompt":"the red fox","max_tokens":8,"tier":"lp","stream":true}"#);
        assert_eq!(CompletionRequest::from_json(&body).unwrap(), req);
        // sampling knobs roundtrip too
        let req = CompletionRequest::new("hi").top_k(5).temperature(0.5).seed(7);
        let back = CompletionRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        assert!(matches!(back.sampler(), Sampler::TopK { k: 5, seed: 7, .. }));
        // defaults: greedy, 32 tokens, no tier, no streaming
        let d = CompletionRequest::from_json(r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(d, CompletionRequest::new("x"));
        assert!(matches!(d.sampler(), Sampler::Greedy));
    }

    #[test]
    fn request_decode_rejects_bad_bodies_with_specific_messages() {
        let cases: Vec<(&str, &str)> = vec![
            (r#"[1,2]"#, "must be a JSON object"),
            (r#""hi""#, "must be a JSON object"),
            (r#"{"max_tokens":4}"#, "missing required field `prompt`"),
            (r#"{"prompt":"x","promt":"y"}"#, "unknown field `promt`"),
            (r#"{"prompt":"x","prompt":"y"}"#, "duplicate field `prompt`"),
            (r#"{"prompt":42}"#, "wrong type"),
            (r#"{"prompt":"x","stream":"yes"}"#, "wrong type"),
            (r#"{"prompt":"x","max_tokens":0}"#, "integer >= 1"),
            (r#"{"prompt":"x","max_tokens":2.5}"#, "integer >= 1"),
            (r#"{"prompt":"x","top_k":-1}"#, "integer >= 1"),
            (r#"{"prompt":"x","temperature":0}"#, "positive number"),
            (r#"{"prompt":"x","tier":{"name":"lp"}}"#, "nested objects"),
            (r#"{"prompt":"x""#, "eof"),
        ];
        for (body, needle) in cases {
            let e = CompletionRequest::from_json(body).unwrap_err();
            let api = ApiError::from(&e);
            assert_eq!(api.code, ErrorCode::InvalidRequest, "{body}: {e}");
            assert!(e.to_string().contains(needle), "{body}: {e}");
        }
    }

    /// Satellite (PR 10): the `session` affinity key rides the wire like
    /// any other field — roundtrips, rejects wrong types, and stays out of
    /// the body when unset (defaults are omitted).
    #[test]
    fn session_field_roundtrips_and_validates() {
        let req = CompletionRequest::new("turn two").max_tokens(4).session("user-7");
        let body = req.to_json();
        assert_eq!(
            body,
            r#"{"prompt":"turn two","max_tokens":4,"session":"user-7"}"#
        );
        assert_eq!(CompletionRequest::from_json(&body).unwrap(), req);
        assert!(!CompletionRequest::new("x").to_json().contains("session"));
        let e = CompletionRequest::from_json(r#"{"prompt":"x","session":7}"#).unwrap_err();
        assert!(e.to_string().contains("wrong type"), "{e}");
    }

    /// Satellite (PR 10): the models listing wire shape is pinned byte for
    /// byte (it is also embedded in the generated docs).
    #[test]
    fn models_response_wire_shape() {
        let resp = ModelsResponse {
            models: vec![ModelInfo {
                model: "td-small".into(),
                tiers: vec!["dense".into(), "lp".into(), "lp_aggr".into()],
                default_tier: "lp".into(),
            }],
            replicas: 2,
        };
        assert_eq!(
            resp.to_json(),
            r#"{"models":[{"model":"td-small","tiers":["dense","lp","lp_aggr"],"default_tier":"lp"}],"replicas":2}"#
        );
        let v = json::Value::parse(&resp.to_json()).unwrap();
        assert_eq!(v.get(fields::REPLICAS).unwrap().as_usize(), Some(2));
    }

    #[test]
    fn options_lowering_roundtrips() {
        let req = CompletionRequest::new("p").max_tokens(4).tier("lp_aggr");
        let opts = req.options();
        assert_eq!(opts.max_new_tokens, 4);
        assert_eq!(opts.tier.as_deref(), Some("lp_aggr"));
        assert!(matches!(opts.sampler, Sampler::Greedy));
        assert_eq!(CompletionRequest::from_options("p", &opts), req);
        let opts = RequestOptions {
            max_new_tokens: 9,
            sampler: Sampler::TopK { k: 3, temperature: 0.7, seed: 11 },
            tier: None,
        };
        let req = CompletionRequest::from_options("q", &opts);
        assert_eq!(req.top_k, Some(3));
        assert_eq!(req.seed, 11);
        assert!(matches!(req.options().sampler, Sampler::TopK { k: 3, .. }));
    }

    #[test]
    fn chunk_and_response_wire_shapes() {
        let chunk = CompletionChunk { id: 42, index: 0, token: 104, text: "h".into() };
        assert_eq!(chunk.to_json(), r#"{"id":42,"index":0,"token":104,"text":"h"}"#);
        let resp = CompletionResponse {
            id: 42,
            tier: Some("lp".into()),
            text: "hi".into(),
            tokens: vec![104, 105],
            prompt_tokens: 5,
            ttft_ms: 12.0,
            latency_ms: 96.0,
        };
        assert_eq!(
            resp.to_json(),
            r#"{"id":42,"tier":"lp","text":"hi","tokens":[104,105],"prompt_tokens":5,"completion_tokens":2,"ttft_ms":12,"latency_ms":96}"#
        );
        // the wire body reparses under the DOM (writer escaping is sound)
        let v = json::Value::parse(&resp.to_json()).unwrap();
        assert_eq!(v.get(fields::COMPLETION_TOKENS).unwrap().as_usize(), Some(2));
    }
}
