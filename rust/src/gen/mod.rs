//! Generation: sampling policies + a single-request greedy loop over the
//! serving executor (the coordinator's scheduler drives the batched path).

use crate::error::Result;
use crate::model::ServingModel;
use crate::tensor::{argmax, top_k};
use crate::text::tokenizer::{self, EOS};
use crate::util::rng::SplitMix64;

/// Sampling policy for picking the next token from a logits row.
#[derive(Clone, Debug)]
pub enum Sampler {
    Greedy,
    /// Top-k sampling with temperature.
    TopK { k: usize, temperature: f32, seed: u64 },
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut SplitMix64) -> i32 {
        match self {
            Sampler::Greedy => argmax(logits) as i32,
            Sampler::TopK { k, temperature, .. } => {
                // NaN logits sort behind every finite logit (tensor::top_k)
                // and are dropped here: NaN must never be sampled.
                let idx: Vec<usize> = top_k(logits, (*k).max(1))
                    .into_iter()
                    .filter(|&i| !logits[i].is_nan())
                    .collect();
                if idx.is_empty() {
                    // Every logit is NaN — the distribution is garbage and
                    // no pick can avoid a NaN logit; return a deterministic
                    // token 0 (argmax's behavior on all-NaN) rather than
                    // panicking in the weight math below.
                    return argmax(logits) as i32;
                }
                let t = temperature.max(1e-4);
                let mx = logits[idx[0]];
                let weights: Vec<f64> =
                    idx.iter().map(|&i| (((logits[i] - mx) / t) as f64).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut r = rng.next_f64() * total;
                for (j, w) in weights.iter().enumerate() {
                    r -= w;
                    if r <= 0.0 {
                        return idx[j] as i32;
                    }
                }
                idx[idx.len() - 1] as i32
            }
        }
    }
}

/// Outcome of a single-request generation.
#[derive(Clone, Debug)]
pub struct Generation {
    pub prompt_tokens: usize,
    pub tokens: Vec<i32>,
    pub text: String,
    pub prefill_ms: f64,
    pub decode_ms: f64,
}

/// Greedy/sampled generation of up to `max_new` tokens for one prompt,
/// using slot 0 of the serving model (batch-of-one; the batched path lives
/// in `coordinator::scheduler`).
pub fn generate(
    model: &ServingModel,
    prompt: &str,
    max_new: usize,
    sampler: &Sampler,
) -> Result<Generation> {
    let cfg = &model.entry.config;
    let ids = tokenizer::encode(prompt, true, false);
    let mut rng = SplitMix64::new(match sampler {
        Sampler::TopK { seed, .. } => *seed,
        _ => 0,
    });

    let t0 = std::time::Instant::now();
    // Streaming chunk protocol (monolithic fallback on legacy manifests):
    // bit-identical to `prefill`, but billed per chunk actually run.
    let logits = model.prefill_chunked(0, &ids)?;
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut out = Vec::new();
    let mut next = sampler.sample(&logits, &mut rng);
    let mut pos = ids.len();
    let t1 = std::time::Instant::now();
    for _ in 0..max_new {
        if next == EOS || pos + 1 >= cfg.ctx {
            break;
        }
        out.push(next);
        // Compact batch of one: only slot 0 is active.
        let rows = model.decode_active(&[(0, next, pos as i32)])?;
        next = sampler.sample(&rows[0].1, &mut rng);
        pos += 1;
    }
    let decode_ms = t1.elapsed().as_secs_f64() * 1e3;

    Ok(Generation {
        prompt_tokens: ids.len(),
        text: tokenizer::decode(&out),
        tokens: out,
        prefill_ms,
        decode_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = SplitMix64::new(0);
        let s = Sampler::Greedy;
        assert_eq!(s.sample(&[0.0, 5.0, 1.0], &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let mut rng = SplitMix64::new(3);
        let s = Sampler::TopK { k: 2, temperature: 1.0, seed: 3 };
        let logits = [10.0, 9.5, -50.0, -60.0];
        for _ in 0..50 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn topk_never_samples_nan() {
        // regression: NaN logits used to panic in top_k; they must also
        // never be *sampled* even when they fall inside the top-k window.
        let mut rng = SplitMix64::new(9);
        let s = Sampler::TopK { k: 4, temperature: 1.0, seed: 9 };
        let logits = [f32::NAN, 0.5, f32::NAN, 1.0, 0.8];
        for _ in 0..100 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 3 || t == 4, "sampled NaN index {t}");
        }
    }

    #[test]
    fn topk_low_temperature_is_greedy() {
        let mut rng = SplitMix64::new(1);
        let s = Sampler::TopK { k: 4, temperature: 1e-6, seed: 1 };
        for _ in 0..20 {
            assert_eq!(s.sample(&[1.0, 3.0, 2.0, 0.0], &mut rng), 1);
        }
    }
}
