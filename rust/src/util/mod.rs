//! Small zero-dependency substrates: PRNG, JSON, run statistics.

pub mod json;
pub mod rng;
pub mod stats;
