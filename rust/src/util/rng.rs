//! SplitMix64 — bit-exact twin of `python/compile/data.py::SplitMix64`.
//!
//! Both sides of the corpus generator (python: training data; rust: eval
//! data and workload generators) must draw identical streams; golden tests
//! on both sides pin the values.

/// SplitMix64 PRNG (Steele et al., the canonical constants).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)` (modulo method — matches python mirror).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_stream_seed42_matches_python() {
        // Same four values asserted in python/tests/test_data.py.
        let mut r = SplitMix64::new(42);
        assert_eq!(r.next_u64(), 13679457532755275413);
        assert_eq!(r.next_u64(), 2949826092126892291);
        assert_eq!(r.next_u64(), 5139283748462763858);
        assert_eq!(r.next_u64(), 6349198060258255764);
    }

    #[test]
    fn below_is_bounded_and_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = a.below(13);
            assert!(x < 13);
            assert_eq!(x, b.below(13));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = SplitMix64::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 800 && c < 1200), "{counts:?}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
