//! Run statistics for benches and experiment harnesses.

use crate::util::rng::SplitMix64;

/// Summary statistics over a sample of measurements (e.g. latencies in ns).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: pct(&v, 0.50),
            p90: pct(&v, 0.90),
            p99: pct(&v, 0.99),
            max: v[n - 1],
        }
    }
}

/// Bounded latency reservoir: Algorithm R reservoir sampling over a
/// deterministic [`SplitMix64`] stream, so memory stays fixed under
/// sustained load while the kept sample remains uniform over everything
/// pushed — and two identical runs keep bit-identical samples (the
/// property `ServerMetrics` and the metrics-snapshot export rely on).
/// Count, min and max are tracked exactly; percentiles come from the
/// sample.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    count: u64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    rng: SplitMix64,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir {
            cap,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
            rng: SplitMix64::new(seed),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: the i-th value replaces a kept sample with
            // probability cap/i, keeping the sample uniform.
            let j = self.rng.below(self.count);
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Values pushed so far (not the kept sample size).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Kept sample size (≤ capacity); exposed so tests can pin the bound.
    pub fn sample_len(&self) -> usize {
        self.samples.len()
    }

    /// Summary over the kept sample, with the exactly-tracked `n`, `min`
    /// and `max` patched over the sampled figures. `None` while empty.
    pub fn summary(&self) -> Option<Summary> {
        if self.samples.is_empty() {
            return None;
        }
        let mut s = Summary::from(&self.samples);
        s.n = self.count as usize;
        s.min = self.min;
        s.max = self.max;
        Some(s)
    }
}

/// Percentile over a sorted slice (nearest-rank with linear interpolation).
pub fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pretty duration: picks ns/µs/ms/s.
pub fn fmt_duration(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((pct(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(pct(&v, 0.0), 0.0);
        assert_eq!(pct(&v, 1.0), 10.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(500.0), "500 ns");
        assert_eq!(fmt_duration(1500.0), "1.50 µs");
        assert_eq!(fmt_duration(2.5e6), "2.50 ms");
        assert_eq!(fmt_duration(3.0e9), "3.000 s");
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::from(&[]);
    }

    /// Edge cases the metrics-snapshot export relies on: a single sample
    /// collapses every figure onto that value with zero spread.
    #[test]
    fn summary_single_sample() {
        let s = Summary::from(&[42.5]);
        assert_eq!(s.n, 1);
        assert_eq!((s.mean, s.std), (42.5, 0.0));
        assert_eq!((s.min, s.p50, s.p90, s.p99, s.max), (42.5, 42.5, 42.5, 42.5, 42.5));
    }

    /// All-equal samples: interpolation between equal neighbors must not
    /// introduce float noise anywhere in the summary.
    #[test]
    fn summary_all_equal_samples() {
        let s = Summary::from(&[7.0; 9]);
        assert_eq!(s.n, 9);
        assert_eq!((s.mean, s.std), (7.0, 0.0));
        assert_eq!((s.min, s.p50, s.p90, s.p99, s.max), (7.0, 7.0, 7.0, 7.0, 7.0));
    }

    /// p99 on small N: with linear interpolation over n-1 intervals, p99
    /// of a 3-sample set sits 98% of the way into the last interval — it
    /// must NOT snap to the max (nearest-rank would).
    #[test]
    fn summary_p99_small_n_interpolates() {
        let s = Summary::from(&[1.0, 2.0, 3.0]);
        // pos = 0.99 * 2 = 1.98 → 2.0 + 0.98 * (3.0 - 2.0)
        assert!((s.p99 - 2.98).abs() < 1e-12, "{}", s.p99);
        assert_eq!(s.max, 3.0);
        // two samples: p99 is 99% of the way from lo to hi
        let s2 = Summary::from(&[0.0, 10.0]);
        assert!((s2.p99 - 9.9).abs() < 1e-12, "{}", s2.p99);
    }

    /// The bounded reservoir: memory stays at the cap, count/min/max stay
    /// exact, the sampled percentiles stay near the true distribution, and
    /// two identical runs produce bit-identical summaries.
    #[test]
    fn reservoir_bounds_memory_and_stays_deterministic() {
        let run = || {
            let mut r = Reservoir::new(256, 0x0b5e_c0de);
            assert!(r.is_empty() && r.summary().is_none());
            for i in 0..10_000u64 {
                r.push(i as f64);
            }
            r
        };
        let r = run();
        assert_eq!(r.sample_len(), 256, "sample must be capped");
        assert_eq!(r.count(), 10_000);
        let s = r.summary().unwrap();
        assert_eq!(s.n, 10_000);
        assert_eq!((s.min, s.max), (0.0, 9999.0), "min/max are tracked exactly");
        // uniform input: the sampled median stays near the true median
        assert!((s.p50 - 5000.0).abs() < 1500.0, "sampled p50 drifted: {}", s.p50);
        assert_eq!(run().summary().unwrap(), s, "summaries must be stable across runs");
    }

    /// Below the cap the reservoir keeps everything, so summaries are
    /// exact — the common small-run case must not be perturbed by sampling.
    #[test]
    fn reservoir_below_cap_is_exact() {
        let mut r = Reservoir::new(1024, 1);
        for x in [5.0, 1.0, 3.0] {
            r.push(x);
        }
        assert_eq!(r.sample_len(), 3);
        let s = r.summary().unwrap();
        assert_eq!(s, Summary::from(&[5.0, 1.0, 3.0]));
    }
}
