//! Run statistics for benches and experiment harnesses.

/// Summary statistics over a sample of measurements (e.g. latencies in ns).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: pct(&v, 0.50),
            p90: pct(&v, 0.90),
            p99: pct(&v, 0.99),
            max: v[n - 1],
        }
    }
}

/// Percentile over a sorted slice (nearest-rank with linear interpolation).
pub fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pretty duration: picks ns/µs/ms/s.
pub fn fmt_duration(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((pct(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(pct(&v, 0.0), 0.0);
        assert_eq!(pct(&v, 1.0), 10.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(500.0), "500 ns");
        assert_eq!(fmt_duration(1500.0), "1.50 µs");
        assert_eq!(fmt_duration(2.5e6), "2.50 ms");
        assert_eq!(fmt_duration(3.0e9), "3.000 s");
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::from(&[]);
    }
}
