//! Minimal JSON parser + serializer (substrate: no serde in the offline
//! vendor set). Covers the full JSON grammar; used for the artifact
//! manifest, checkpoint configs and experiment result files.
//!
//! Two access styles share one lexer:
//!
//! * [`Value::parse`] — the DOM: a [`Value`] tree, used wherever the
//!   document is small and random access is convenient (manifests,
//!   configs, result files).
//! * [`read_events`] — a callback/visitor reader for the serving hot
//!   path: one left-to-right pass handing each syntactic [`Event`] to a
//!   closure, borrowing escape-free strings straight from the input so a
//!   typical request body decodes with no per-field allocation. The
//!   write side is [`JsonWriter`], which streams straight into a
//!   `String` — request decode → response encode never round-trips
//!   through an intermediate `Value`.
//!
//! Both paths reject unescaped control characters in strings and cap
//! nesting at [`MAX_DEPTH`] (a deep `[[[[…` body from the network must
//! error, not overflow the parser's stack).

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// Maximum container nesting either parser accepts. Far above any real
/// manifest or API body, far below stack exhaustion.
pub const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Objects use BTreeMap for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser::new(text);
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the path name — for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::msg(format!("missing json key `{key}`")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- serializer --------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Shared number formatting: integral values print without a fractional
/// part so counters stay diff-friendly; everything else uses the shortest
/// round-trip float form.
fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(x: impl Into<String>) -> Value {
    Value::Str(x.into())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Parser<'a> {
        Parser { b: text.as_bytes(), i: 0, depth: 0 }
    }

    fn err(&self, msg: &str) -> Error {
        Error::Json { at: self.i, msg: msg.to_string() }
    }

    /// Bump the container nesting level, erroring past [`MAX_DEPTH`].
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => {
                self.enter()?;
                let v = self.object()?;
                self.depth -= 1;
                Ok(v)
            }
            b'[' => {
                self.enter()?;
                let v = self.array()?;
                self.depth -= 1;
                Ok(v)
            }
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            // RFC 8259 leaves duplicate names undefined behaviour; for the
            // manifest they are always a bug (e.g. two plan variants under
            // one id, where last-wins would silently drop a tier) — reject.
            if m.contains_key(&k) {
                return Err(self.err(&format!("duplicate object key `{k}`")));
            }
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.string_cow().map(Cow::into_owned)
    }

    /// Borrow the string straight from the input when it contains no
    /// escapes — the event-reader fast path. Falls back to the owned
    /// decoder at the first backslash.
    fn string_cow(&mut self) -> Result<Cow<'a, str>> {
        self.eat(b'"')?;
        let start = self.i;
        loop {
            match self.b.get(self.i).copied() {
                None => return Err(self.err("eof in string")),
                Some(b'"') => {
                    let raw = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("bad utf-8"))?;
                    self.i += 1;
                    return Ok(Cow::Borrowed(raw));
                }
                Some(b'\\') => {
                    // rewind to just past the opening quote; re-decode owned
                    self.i = start;
                    return self.string_owned().map(Cow::Owned);
                }
                Some(c) if c < 0x20 => {
                    return Err(self.err("unescaped control character in string"));
                }
                Some(_) => self.i += 1,
            }
        }
    }

    /// Decode a string with escapes into an owned buffer; the cursor must
    /// sit just past the opening quote.
    fn string_owned(&mut self) -> Result<String> {
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                    && self.i + 6 <= self.b.len()
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => {
                    // RFC 8259 §7: control characters MUST be escaped
                    return Err(self.err("unescaped control character in string"));
                }
                c => {
                    // re-assemble utf-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let sfx = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(sfx);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        self.number_f64().map(Value::Num)
    }

    fn number_f64(&mut self) -> Result<f64> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map_err(|_| self.err("bad number"))
    }

    // ---- event (callback/visitor) reader -----------------------------------

    fn event_value<F: FnMut(Event<'a>) -> Result<()>>(&mut self, f: &mut F) -> Result<()> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => {
                self.enter()?;
                self.event_object(f)?;
                self.depth -= 1;
                Ok(())
            }
            b'[' => {
                self.enter()?;
                self.event_array(f)?;
                self.depth -= 1;
                Ok(())
            }
            b'"' => {
                let s = self.string_cow()?;
                f(Event::Str(s))
            }
            b't' => {
                self.lit("true", Value::Bool(true))?;
                f(Event::Bool(true))
            }
            b'f' => {
                self.lit("false", Value::Bool(false))?;
                f(Event::Bool(false))
            }
            b'n' => {
                self.lit("null", Value::Null)?;
                f(Event::Null)
            }
            _ => {
                let n = self.number_f64()?;
                f(Event::Num(n))
            }
        }
    }

    fn event_object<F: FnMut(Event<'a>) -> Result<()>>(&mut self, f: &mut F) -> Result<()> {
        self.eat(b'{')?;
        f(Event::BeginObject)?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return f(Event::EndObject);
        }
        loop {
            self.ws();
            let k = self.string_cow()?;
            // duplicate-key policy is the visitor's call: it sees every key
            // in order (the api module rejects repeats with field context)
            f(Event::Key(k))?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            self.event_value(f)?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return f(Event::EndObject);
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn event_array<F: FnMut(Event<'a>) -> Result<()>>(&mut self, f: &mut F) -> Result<()> {
        self.eat(b'[')?;
        f(Event::BeginArray)?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return f(Event::EndArray);
        }
        loop {
            self.ws();
            self.event_value(f)?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return f(Event::EndArray);
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }
}

/// One syntactic element from [`read_events`]. Strings and keys are
/// `Cow::Borrowed` straight from the input whenever they contain no
/// escapes, so the common request body decodes without per-field copies.
#[derive(Debug, PartialEq)]
pub enum Event<'a> {
    BeginObject,
    /// Object member name (always precedes its value's events).
    Key(Cow<'a, str>),
    EndObject,
    BeginArray,
    EndArray,
    Str(Cow<'a, str>),
    Num(f64),
    Bool(bool),
    Null,
}

/// Single-pass callback reader over one complete JSON document. The
/// closure sees every [`Event`] left-to-right and may abort the parse by
/// returning an error (propagated verbatim). Trailing non-whitespace
/// after the document is rejected, same as [`Value::parse`].
pub fn read_events<'a, F: FnMut(Event<'a>) -> Result<()>>(text: &'a str, mut f: F) -> Result<()> {
    let mut p = Parser::new(text);
    p.ws();
    p.event_value(&mut f)?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(())
}

/// Streaming JSON serializer: writes straight into a `String` with no
/// intermediate [`Value`] tree. Commas and colons are inserted
/// automatically; the caller provides structure via
/// `begin_obj`/`key`/…/`end_obj`. Escaping matches [`Value`]'s writer, so
/// output always re-parses.
#[derive(Default)]
pub struct JsonWriter {
    out: String,
    /// One entry per open container: `true` until the first element lands.
    first: Vec<bool>,
    /// Set between `key()` and the value that follows it.
    pending_key: bool,
}

impl JsonWriter {
    pub fn new() -> JsonWriter {
        JsonWriter::default()
    }

    /// Comma/placement bookkeeping before a value or key is emitted.
    fn sep(&mut self) {
        if self.pending_key {
            self.pending_key = false;
            return;
        }
        if let Some(first) = self.first.last_mut() {
            if *first {
                *first = false;
            } else {
                self.out.push(',');
            }
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.sep();
        self.out.push('{');
        self.first.push(true);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.first.pop();
        self.out.push('}');
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.sep();
        self.out.push('[');
        self.first.push(true);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.first.pop();
        self.out.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.sep();
        write_escaped(&mut self.out, k);
        self.out.push(':');
        self.pending_key = true;
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.sep();
        write_escaped(&mut self.out, v);
        self
    }

    pub fn num(&mut self, v: f64) -> &mut Self {
        self.sep();
        write_num(&mut self.out, v);
        self
    }

    pub fn int(&mut self, v: i64) -> &mut Self {
        self.sep();
        let _ = write!(self.out, "{v}");
        self
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.sep();
        self.out.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.sep();
        self.out.push_str("null");
        self
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse(r#""hi\nthere""#).unwrap(), Value::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("hello").is_err());
        assert!(Value::parse("{}extra").is_err());
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        let e = Value::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert!(e.to_string().contains("duplicate object key `a`"), "{e}");
        // nested objects are checked too; distinct keys still parse
        assert!(Value::parse(r#"{"v": {"lp": 1, "lp": 2}}"#).is_err());
        assert!(Value::parse(r#"{"a": 1, "b": {"a": 2}}"#).is_ok());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse(r#""A😀""#).unwrap(), Value::Str("A😀".into()));
        assert_eq!(Value::parse("\"é中\"").unwrap(), Value::Str("é中".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"x":{"n":3,"f":1.5,"arr":[true,false,null],"s":"a\"b"}}}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = crate::repo_root().join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("models").is_some());
        }
    }

    #[test]
    fn rejects_unescaped_control_characters() {
        // RFC 8259 §7: raw control bytes inside strings are invalid — both
        // the borrowed fast path and the escape decoder must reject them
        let e = Value::parse("\"a\u{1}b\"").unwrap_err();
        assert!(e.to_string().contains("unescaped control character"), "{e}");
        assert!(Value::parse("\"a\nb\"").is_err()); // raw newline
        assert!(Value::parse("\"x\\n a\u{1}\"").is_err()); // after an escape (owned path)
        // the escaped form is fine and decodes to the control character
        assert_eq!(Value::parse("\"a\\u0001b\"").unwrap(), Value::Str("a\u{1}b".into()));
    }

    #[test]
    fn rejects_nesting_past_max_depth() {
        let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        let e = Value::parse(&deep).unwrap_err();
        assert!(e.to_string().contains("nesting deeper"), "{e}");
        assert!(read_events(&deep, |_| Ok(())).is_err());
        // exactly MAX_DEPTH is accepted
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Value::parse(&ok).is_ok());
        assert!(read_events(&ok, |_| Ok(())).is_ok());
    }

    #[test]
    fn event_reader_walks_document_in_order() {
        let src = r#"{"prompt": "hi", "n": 3.5, "opts": {"stream": true, "t": null}, "a": [1, "x\n"]}"#;
        let mut got = Vec::new();
        read_events(src, |e| {
            got.push(format!("{e:?}"));
            Ok(())
        })
        .unwrap();
        assert_eq!(
            got,
            vec![
                "BeginObject",
                "Key(\"prompt\")",
                "Str(\"hi\")",
                "Key(\"n\")",
                "Num(3.5)",
                "Key(\"opts\")",
                "BeginObject",
                "Key(\"stream\")",
                "Bool(true)",
                "Key(\"t\")",
                "Null",
                "EndObject",
                "Key(\"a\")",
                "BeginArray",
                "Num(1.0)",
                "Str(\"x\\n\")",
                "EndArray",
                "EndObject",
            ]
        );
    }

    #[test]
    fn event_reader_borrows_escape_free_strings() {
        // escape-free strings (ascii and multibyte utf-8 alike) are handed
        // out as Cow::Borrowed; escaped ones fall back to Cow::Owned
        let src = r#"{"a": "plain é中", "b": "esc\naped"}"#;
        let mut borrowed = Vec::new();
        let mut owned = Vec::new();
        read_events(src, |e| {
            if let Event::Str(s) = e {
                match s {
                    Cow::Borrowed(v) => borrowed.push(v.to_string()),
                    Cow::Owned(v) => owned.push(v),
                }
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(borrowed, vec!["plain é中"]);
        assert_eq!(owned, vec!["esc\naped"]);
    }

    #[test]
    fn event_reader_propagates_visitor_errors_and_rejects_trailing() {
        let e = read_events("[1, 2]", |ev| match ev {
            Event::Num(n) if n == 2.0 => Err(Error::msg("stop")),
            _ => Ok(()),
        })
        .unwrap_err();
        assert!(e.to_string().contains("stop"));
        assert!(read_events("{} junk", |_| Ok(())).is_err());
    }

    #[test]
    fn writer_output_reparses_to_expected_value() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("id").int(7);
        w.key("text").str("a\"b\nc");
        w.key("ratio").num(1.5);
        w.key("count").num(3.0); // integral floats print without `.0`
        w.key("flags").begin_arr();
        w.bool(true).null().str("x");
        w.end_arr();
        w.key("inner").begin_obj();
        w.key("empty").begin_arr();
        w.end_arr();
        w.end_obj();
        w.end_obj();
        let out = w.finish();
        assert!(out.contains("\"count\":3,"), "{out}");
        let v = Value::parse(&out).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("text").unwrap().as_str(), Some("a\"b\nc"));
        assert_eq!(v.get("ratio").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("flags").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("inner").unwrap().get("empty").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn http_body_corpus_cases() {
        // request-body shapes the network edge must handle (PR 6 corpus
        // extension): dup keys surface to the event visitor in order, the
        // DOM still rejects them, surrogate pairs and multibyte prompts
        // decode, truncated bodies error instead of hanging
        let dup = r#"{"prompt": "a", "prompt": "b"}"#;
        assert!(Value::parse(dup).is_err());
        let mut keys = Vec::new();
        read_events(dup, |e| {
            if let Event::Key(k) = e {
                keys.push(k.into_owned());
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(keys, vec!["prompt", "prompt"]);

        assert_eq!(
            Value::parse(r#"{"prompt": "😀"}"#).unwrap().get("prompt").unwrap().as_str(),
            Some("😀")
        );
        assert!(Value::parse(r#"{"prompt": "tru"#).is_err());
        assert!(Value::parse("").is_err());
        assert!(read_events("", |_| Ok(())).is_err());
    }
}
