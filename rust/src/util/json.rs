//! Minimal JSON parser + serializer (substrate: no serde in the offline
//! vendor set). Covers the full JSON grammar; used for the artifact
//! manifest, checkpoint configs and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value. Objects use BTreeMap for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the path name — for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::msg(format!("missing json key `{key}`")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- serializer --------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(x: impl Into<String>) -> Value {
    Value::Str(x.into())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            // RFC 8259 leaves duplicate names undefined behaviour; for the
            // manifest they are always a bug (e.g. two plan variants under
            // one id, where last-wins would silently drop a tier) — reject.
            if m.contains_key(&k) {
                return Err(self.err(&format!("duplicate object key `{k}`")));
            }
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                    && self.i + 6 <= self.b.len()
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // re-assemble utf-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let sfx = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(sfx);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse(r#""hi\nthere""#).unwrap(), Value::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("hello").is_err());
        assert!(Value::parse("{}extra").is_err());
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        let e = Value::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert!(e.to_string().contains("duplicate object key `a`"), "{e}");
        // nested objects are checked too; distinct keys still parse
        assert!(Value::parse(r#"{"v": {"lp": 1, "lp": 2}}"#).is_err());
        assert!(Value::parse(r#"{"a": 1, "b": {"a": 2}}"#).is_ok());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse(r#""A😀""#).unwrap(), Value::Str("A😀".into()));
        assert_eq!(Value::parse("\"é中\"").unwrap(), Value::Str("é中".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"x":{"n":3,"f":1.5,"arr":[true,false,null],"s":"a\"b"}}}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = crate::repo_root().join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("models").is_some());
        }
    }
}
