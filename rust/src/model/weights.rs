//! Weight store: `.tdw` reader (format defined in `python/compile/params.py`)
//! plus the shard/merge views the executors need.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::pjrt::HostValue;
use crate::runtime::ModelConfig;

/// A named tensor: shape + row-major f32 data.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn host(&self) -> HostValue {
        HostValue::f32(self.shape.clone(), self.data.clone())
    }

    /// Columns `[c0, c1)` of a 2-D tensor (TP sharding of W_q/W_k/W_v/W_g/W_u).
    pub fn col_slice(&self, c0: usize, c1: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        assert!(c1 <= c && c0 < c1, "cols [{c0},{c1}) of {c}");
        let mut data = Vec::with_capacity(r * (c1 - c0));
        for row in 0..r {
            data.extend_from_slice(&self.data[row * c + c0..row * c + c1]);
        }
        Tensor { shape: vec![r, c1 - c0], data }
    }

    /// Rows `[r0, r1)` of a 2-D tensor (TP sharding of W_o/W_d).
    pub fn row_slice(&self, r0: usize, r1: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        assert!(r1 <= self.shape[0] && r0 < r1);
        Tensor { shape: vec![r1 - r0, c], data: self.data[r0 * c..r1 * c].to_vec() }
    }

    /// Element-wise average with another tensor (the §3 merge transform).
    pub fn average(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| 0.5 * (a + b))
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }
}

/// Per-layer weight field names, in artifact argument order.
pub const ATTN_FIELDS: [&str; 5] = ["ln1", "wq", "wk", "wv", "wo"];
pub const FFN_FIELDS: [&str; 4] = ["ln2", "wg", "wu", "wd"];

#[derive(Clone)]
pub struct Weights {
    pub cfg: ModelConfig,
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    /// Load `<ckpt_dir>/weights.tdw`, validating against `cfg`.
    pub fn load(ckpt_dir: &Path, cfg: &ModelConfig) -> Result<Weights> {
        let path = ckpt_dir.join("weights.tdw");
        let buf = std::fs::read(&path).map_err(|e| {
            Error::Weights(format!(
                "cannot open {} (run `make models` first): {e}",
                path.display()
            ))
        })?;
        let tensors = parse_tdw(&buf)?;
        let w = Weights { cfg: cfg.clone(), tensors };
        w.validate()?;
        Ok(w)
    }

    pub fn from_tensors(cfg: ModelConfig, tensors: HashMap<String, Tensor>) -> Weights {
        Weights { cfg, tensors }
    }

    /// Synthetic random weights (tests / benches without a checkpoint).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        let mut tensors = HashMap::new();
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let mut mk = |name: String, shape: Vec<usize>, scale: f32| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n)
                .map(|_| (rng.next_f64() as f32 - 0.5) * 2.0 * scale)
                .collect();
            tensors.insert(name, Tensor { shape, data });
        };
        mk("emb".into(), vec![v, d], 0.02);
        mk("lnf".into(), vec![d], 1.0);
        mk("wout".into(), vec![d, v], 0.05);
        let ws = 1.0 / (d as f32).sqrt();
        for i in 0..cfg.n_layers {
            mk(format!("layers.{i}.ln1"), vec![d], 1.0);
            mk(format!("layers.{i}.wq"), vec![d, d], ws);
            mk(format!("layers.{i}.wk"), vec![d, d], ws);
            mk(format!("layers.{i}.wv"), vec![d, d], ws);
            mk(format!("layers.{i}.wo"), vec![d, d], ws * 0.2);
            mk(format!("layers.{i}.ln2"), vec![d], 1.0);
            mk(format!("layers.{i}.wg"), vec![d, f], ws);
            mk(format!("layers.{i}.wu"), vec![d, f], ws);
            mk(format!("layers.{i}.wd"), vec![f, d], ws * 0.2);
        }
        Weights { cfg: cfg.clone(), tensors }
    }

    fn validate(&self) -> Result<()> {
        let (d, f, v) = (self.cfg.d_model, self.cfg.d_ff, self.cfg.vocab);
        let expect: &[(&str, Vec<usize>)] =
            &[("emb", vec![v, d]), ("lnf", vec![d]), ("wout", vec![d, v])];
        for (name, shape) in expect {
            let t = self.get(name)?;
            if &t.shape != shape {
                return Err(Error::Weights(format!(
                    "{name}: expected {shape:?}, got {:?}",
                    t.shape
                )));
            }
        }
        for i in 0..self.cfg.n_layers {
            for (field, shape) in [
                ("ln1", vec![d]),
                ("wq", vec![d, d]),
                ("wk", vec![d, d]),
                ("wv", vec![d, d]),
                ("wo", vec![d, d]),
                ("ln2", vec![d]),
                ("wg", vec![d, f]),
                ("wu", vec![d, f]),
                ("wd", vec![f, d]),
            ] {
                let t = self.layer(i, field)?;
                if t.shape != shape {
                    return Err(Error::Weights(format!(
                        "layers.{i}.{field}: expected {shape:?}, got {:?}",
                        t.shape
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Weights(format!("missing tensor `{name}`")))
    }

    pub fn layer(&self, i: usize, field: &str) -> Result<&Tensor> {
        self.get(&format!("layers.{i}.{field}"))
    }

    /// Merged (weight-averaged) layer tensors — the §3 merge transform.
    /// Returns the 9 per-layer tensors of the averaged stack.
    pub fn merged_layer(&self, layers: &[usize]) -> Result<HashMap<String, Tensor>> {
        assert!(!layers.is_empty());
        let fields = ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"];
        let mut out = HashMap::new();
        for f in fields {
            let mut acc = self.layer(layers[0], f)?.clone();
            for &l in &layers[1..] {
                let t = self.layer(l, f)?;
                for (a, b) in acc.data.iter_mut().zip(&t.data) {
                    *a += *b;
                }
            }
            let n = layers.len() as f32;
            for a in acc.data.iter_mut() {
                *a /= n;
            }
            out.insert(f.to_string(), acc);
        }
        Ok(out)
    }

    /// TP shard of layer `i` for `rank` of `g`: attention columns/rows.
    pub fn attn_shard(&self, i: usize, rank: usize, g: usize) -> Result<Vec<Tensor>> {
        let d = self.cfg.d_model;
        let w = d / g;
        let (c0, c1) = (rank * w, (rank + 1) * w);
        Ok(vec![
            self.layer(i, "ln1")?.clone(),
            self.layer(i, "wq")?.col_slice(c0, c1),
            self.layer(i, "wk")?.col_slice(c0, c1),
            self.layer(i, "wv")?.col_slice(c0, c1),
            self.layer(i, "wo")?.row_slice(c0, c1),
        ])
    }

    pub fn ffn_shard(&self, i: usize, rank: usize, g: usize) -> Result<Vec<Tensor>> {
        let f = self.cfg.d_ff;
        let w = f / g;
        let (c0, c1) = (rank * w, (rank + 1) * w);
        Ok(vec![
            self.layer(i, "ln2")?.clone(),
            self.layer(i, "wg")?.col_slice(c0, c1),
            self.layer(i, "wu")?.col_slice(c0, c1),
            self.layer(i, "wd")?.row_slice(c0, c1),
        ])
    }

    /// Full-width layer tensors in artifact order (LP paths, scoring).
    pub fn attn_full(&self, i: usize) -> Result<Vec<Tensor>> {
        Ok(ATTN_FIELDS
            .iter()
            .map(|f| self.layer(i, f).cloned())
            .collect::<Result<_>>()?)
    }

    pub fn ffn_full(&self, i: usize) -> Result<Vec<Tensor>> {
        Ok(FFN_FIELDS
            .iter()
            .map(|f| self.layer(i, f).cloned())
            .collect::<Result<_>>()?)
    }
}

fn parse_tdw(buf: &[u8]) -> Result<HashMap<String, Tensor>> {
    let mut p = 0usize;
    let take = |p: &mut usize, n: usize| -> Result<&[u8]> {
        if *p + n > buf.len() {
            return Err(Error::Weights("truncated .tdw".into()));
        }
        let s = &buf[*p..*p + n];
        *p += n;
        Ok(s)
    };
    if take(&mut p, 4)? != b"TDW1" {
        return Err(Error::Weights("bad magic (not a .tdw file)".into()));
    }
    let count = u32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap());
    let mut out = HashMap::new();
    for _ in 0..count {
        let nlen = u16::from_le_bytes(take(&mut p, 2)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut p, nlen)?.to_vec())
            .map_err(|_| Error::Weights("bad tensor name".into()))?;
        let dt = take(&mut p, 1)?[0];
        let ndim = take(&mut p, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap()) as usize);
        }
        let nbytes = u64::from_le_bytes(take(&mut p, 8)?.try_into().unwrap()) as usize;
        let raw = take(&mut p, nbytes)?;
        if dt != 0 {
            return Err(Error::Weights(format!("{name}: only f32 weights supported")));
        }
        let n: usize = shape.iter().product();
        if n * 4 != nbytes {
            return Err(Error::Weights(format!("{name}: shape/bytes mismatch")));
        }
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 260,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            d_ff: 16,
            ctx: 16,
            slots: 2,
        }
    }

    #[test]
    fn random_weights_validate() {
        let w = Weights::random(&tiny_cfg(), 1);
        w.validate().unwrap();
        assert_eq!(w.layer(0, "wq").unwrap().shape, vec![8, 8]);
    }

    #[test]
    fn col_and_row_slices() {
        let t = Tensor {
            shape: vec![2, 4],
            data: vec![0., 1., 2., 3., 10., 11., 12., 13.],
        };
        let c = t.col_slice(1, 3);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![1., 2., 11., 12.]);
        let r = t.row_slice(1, 2);
        assert_eq!(r.shape, vec![1, 4]);
        assert_eq!(r.data, vec![10., 11., 12., 13.]);
    }

    #[test]
    fn shards_partition_the_tensor() {
        let w = Weights::random(&tiny_cfg(), 2);
        let full = w.layer(0, "wq").unwrap();
        let s0 = w.attn_shard(0, 0, 2).unwrap();
        let s1 = w.attn_shard(0, 1, 2).unwrap();
        // wq is index 1 in ATTN_FIELDS order
        let (a, b) = (&s0[1], &s1[1]);
        assert_eq!(a.shape, vec![8, 4]);
        for row in 0..8 {
            for col in 0..4 {
                assert_eq!(a.data[row * 4 + col], full.data[row * 8 + col]);
                assert_eq!(b.data[row * 4 + col], full.data[row * 8 + 4 + col]);
            }
        }
    }

    #[test]
    fn merge_is_elementwise_average() {
        let w = Weights::random(&tiny_cfg(), 3);
        let m = w.merged_layer(&[0, 1]).unwrap();
        let a = w.layer(0, "wq").unwrap();
        let b = w.layer(1, "wq").unwrap();
        for (i, v) in m["wq"].data.iter().enumerate() {
            assert!((v - 0.5 * (a.data[i] + b.data[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn tdw_parser_roundtrips_via_python_format() {
        // hand-build a tiny .tdw blob matching params.py layout
        let mut blob: Vec<u8> = b"TDW1".to_vec();
        blob.extend(1u32.to_le_bytes());
        let name = b"x";
        blob.extend((name.len() as u16).to_le_bytes());
        blob.extend(name);
        blob.push(0); // f32
        blob.push(2); // ndim
        blob.extend(2u32.to_le_bytes());
        blob.extend(3u32.to_le_bytes());
        let data: Vec<f32> = vec![1., 2., 3., 4., 5., 6.];
        blob.extend((24u64).to_le_bytes());
        for v in &data {
            blob.extend(v.to_le_bytes());
        }
        let out = parse_tdw(&blob).unwrap();
        assert_eq!(out["x"].shape, vec![2, 3]);
        assert_eq!(out["x"].data, data);
    }

    #[test]
    fn tdw_parser_rejects_garbage() {
        assert!(parse_tdw(b"NOPE").is_err());
        assert!(parse_tdw(b"TDW1\x01\x00\x00\x00").is_err());
    }

    #[test]
    fn real_checkpoint_loads_if_present() {
        let root = crate::repo_root();
        let Ok(m) = crate::runtime::Manifest::load_default() else { return };
        let dir = root.join("checkpoints/td-small");
        if dir.join("weights.tdw").exists() {
            let cfg = &m.model("td-small").unwrap().config;
            let w = Weights::load(&dir, cfg).unwrap();
            assert_eq!(w.get("emb").unwrap().shape, vec![cfg.vocab, cfg.d_model]);
        }
    }
}
