//! Chunked streaming prefill: the resumable-prefill protocol of the
//! serving executor.
//!
//! ## Why
//!
//! The monolithic prefill ([`ServingModel::prefill`]) pads every prompt to
//! the smallest covering seq bucket `T` and monopolizes the mesh for the
//! whole pass — the head-of-line serialization that stalls every live
//! decode slot while a long prompt runs, and bills compute for `T` padded
//! tokens regardless of the true prompt length `L`.
//!
//! The chunked protocol replaces the per-`T` executable family on the hot
//! path with ONE fixed-`K` executable per stage kind (`{tp,lp}attn_chunk`,
//! `{tp,lp}ffn_chunk`, `embed_chunk`, `logits_chunk`; K = the manifest's
//! `prefill_chunk`): a prompt of `L` tokens runs `ceil(L / K)` chunk steps,
//! each consuming `K` tokens at position offset `off` against the live
//! `[S, C, w]` KV caches. Modelled flops and the α–β all-reduce payload
//! scale with the chunk count, and — because the state between chunks is
//! nothing but the KV cache rows already written plus a host-side cursor —
//! prefill becomes *resumable*: the scheduler runs at most one chunk per
//! iteration and decodes all live slots in between
//! (`coordinator::scheduler`).
//!
//! ## Protocol
//!
//! 1. [`ServingModel::begin_prefill`] validates the prompt and returns a
//!    [`ChunkedPrefill`] cursor;
//! 2. each [`ServingModel::prefill_step`] uploads the chunk's token ids
//!    plus the `slot`/`off`/`valid` scalars, embeds on rank 0, fans the
//!    chunk activation out as the resident `act` buffer, and chains the
//!    stages exactly like the monolithic pass (attention partial →
//!    [`crate::parallel::Mesh::reduce_into`] → FFN partial → reduce; two
//!    all-reduces per stage per chunk). The chunk attention executable
//!    inserts its own K/V rows — masked by `valid`, so the PAD tail of the
//!    final partial chunk never writes the cache — and attends over the
//!    cache prefix `[0, off + row]`;
//! 3. the final chunk additionally runs `logits_chunk` and returns the
//!    last real token's logits row, exactly like the monolithic path.
//!
//! ## Bit-exactness
//!
//! The chunk executables share the per-token math of the monolithic
//! lowering (row-wise projections/RoPE/softmax are batch-size-invariant on
//! XLA CPU, and masked cache columns are exact zeros), so a chunked prefill
//! followed by decode is bit-identical to the fixed-`T` path row for row —
//! pinned by `chunked_prefill_bit_identical_to_monolithic` below and by
//! `python/tests/test_chunk_prefill.py` at the JAX level.

use crate::error::{Error, Result};
use crate::model::serving::{
    cache_name, chunk_exec_keys, paged_chunk_exec_keys, stage_weight_args,
    stage_weight_names, ServeStage, ServingModel, ATTN_FIELDS, FFN_FIELDS,
};
use crate::parallel::worker::ArgRef;
use crate::runtime::buckets::{prefill_bytes, prefill_flops};
use crate::runtime::pjrt::HostValue;
use crate::runtime::VariantId;
use crate::verify::{DispatchTrace, RankIo, TraceOp};

/// Executable keys of the chunk prefill family — all six must exist in the
/// manifest for the chunked path to activate (`ServingModel::prefill_chunk`).
pub const CHUNK_ARTIFACT_KEYS: [&str; 6] = [
    "embed_chunk",
    "logits_chunk",
    "tpattn_chunk",
    "tpffn_chunk",
    "lpattn_chunk",
    "lpffn_chunk",
];

/// Resumable prefill cursor: which slot (and plan-variant tier) is being
/// filled, the full prompt, and how many tokens the chunk steps have
/// consumed so far. The device state between steps lives entirely in the
/// tier's KV cache rows for the slot, so the scheduler can run decode
/// rounds (which reuse the resident `act` buffer) between any two steps —
/// including rounds of *other* tiers.
#[derive(Debug)]
pub struct ChunkedPrefill {
    slot: usize,
    variant: VariantId,
    tokens: Vec<i32>,
    consumed: usize,
}

impl ChunkedPrefill {
    pub fn slot(&self) -> usize {
        self.slot
    }

    /// The plan-variant tier this prefill streams into.
    pub fn variant(&self) -> &VariantId {
        &self.variant
    }

    /// Prompt length in tokens.
    pub fn total_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Tokens consumed by completed chunk steps.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    pub fn is_done(&self) -> bool {
        self.consumed == self.tokens.len()
    }

    /// Steps still to run under chunk size `k` (1 for the legacy
    /// monolithic fallback, which consumes everything in one step).
    pub fn steps_remaining(&self, k: Option<usize>) -> usize {
        let left = self.tokens.len() - self.consumed;
        match k {
            Some(k) => left.div_ceil(k),
            None => usize::from(left > 0),
        }
    }
}

impl ServingModel {
    /// [`ServingModel::begin_prefill_v`] on the default tier.
    pub fn begin_prefill(&self, slot: usize, tokens: &[i32]) -> Result<ChunkedPrefill> {
        self.begin_prefill_v(self.default_tier(), slot, tokens)
    }

    /// Start a resumable prefill of `tokens` into `slot` under tier `vid`.
    /// Validates the tier and the prompt against the active prefill path's
    /// bound up front (chunked: the KV context; legacy fixed-`T`: the
    /// largest seq bucket) so a cursor, once issued, cannot fail on tier
    /// or length mid-flight.
    pub fn begin_prefill_v(
        &self,
        vid: &VariantId,
        slot: usize,
        tokens: &[i32],
    ) -> Result<ChunkedPrefill> {
        self.variant(vid)?;
        let cfg = &self.entry.config;
        if tokens.is_empty() {
            return Err(Error::Serving("empty prompt (nothing to prefill)".into()));
        }
        if slot >= cfg.slots {
            return Err(Error::Serving(format!("prefill slot {slot} >= {}", cfg.slots)));
        }
        // Same bound as `check_admission` — the protocol entry point and
        // the scheduler's admission check can never disagree on length.
        if tokens.len() > self.max_prompt_len() {
            return Err(Error::Serving(format!(
                "prompt of {} tokens exceeds the admission limit {} (ctx {})",
                tokens.len(),
                self.max_prompt_len(),
                cfg.ctx
            )));
        }
        // Under paging, probe the shared-prefix index: every leading block
        // some earlier prompt already prefilled is mapped into this slot's
        // page tables (bumping page refs) and the cursor starts past them —
        // those chunk steps never run and charge zero modelled compute.
        // The final chunk is never shareable, so `consumed` starts strictly
        // below the prompt length and the logits head always runs.
        let consumed = match &self.paged {
            Some(pg) => pg.lock().unwrap().attach_prefix(vid, slot, tokens),
            None => 0,
        };
        Ok(ChunkedPrefill {
            slot,
            variant: vid.clone(),
            tokens: tokens.to_vec(),
            consumed,
        })
    }

    /// Run ONE chunk step (or, on a legacy manifest without chunk
    /// executables, the whole monolithic prefill) under the cursor's tier.
    /// Returns `Some(logits row)` of the last real token once the prompt
    /// is fully consumed, `None` while chunks remain.
    pub fn prefill_step(&self, st: &mut ChunkedPrefill) -> Result<Option<Vec<f32>>> {
        if st.is_done() {
            return Err(Error::Serving("prefill_step on a completed prefill".into()));
        }
        let var = self.variant(&st.variant)?;
        let Some(k) = self.prefill_chunk else {
            let logits = self.prefill_v(&st.variant, st.slot, &st.tokens)?;
            st.consumed = st.tokens.len();
            return Ok(Some(logits));
        };
        if self.paging_enabled() {
            return self.prefill_step_paged(st, k);
        }
        self.ensure_execs(&chunk_exec_keys(&var.stages))?;

        let cfg = &self.entry.config;
        let d = cfg.d_model;
        let off = st.consumed;
        let valid = (st.tokens.len() - off).min(k);
        let last = off + valid == st.tokens.len();
        let mut chunk_tokens = st.tokens[off..off + valid].to_vec();
        chunk_tokens.resize(k, crate::text::tokenizer::PAD);
        // modelled device compute: K padded tokens at prefix offset `off`,
        // plus the [K, V] logits head on the final chunk only — priced on
        // the roofline with the chunk's memory traffic (each chunk pass
        // re-streams the layer weights, so modelled time scales with
        // ceil(L / K), the property bench_prefill's sweep gates on).
        // Charged with the cursor tier's own depth scale.
        let logits_rows = if last { k } else { 0 };
        self.mesh.charge_compute(
            prefill_flops(cfg, var.layers_equiv, off, k, logits_rows),
            prefill_bytes(cfg, var.layers_equiv, off, k, logits_rows),
        );

        // chunk coordinates are fresh host data, resident for the stages
        self.mesh.upload_all("slot", HostValue::scalar_i32(st.slot as i32))?;
        self.mesh.upload_all("off", HostValue::scalar_i32(off as i32))?;
        self.mesh.upload_all("valid", HostValue::scalar_i32(valid as i32))?;

        // rank 0: embed the chunk (host→device edge), fan out as `act`
        let mut shadow = self
            .mesh
            .exec_rank(
                0,
                "embed_chunk",
                vec![
                    ArgRef::Host(HostValue::i32(vec![k], chunk_tokens)),
                    ArgRef::Resident("emb".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        self.mesh
            .broadcast_resident("act", &HostValue::f32(vec![k, d], shadow.clone()))?;

        for (sidx, stage) in var.stages.iter().enumerate() {
            let (attn_key, ffn_key) = match stage {
                ServeStage::Tp(_) => ("tpattn_chunk", "tpffn_chunk"),
                ServeStage::Lp(..) => ("lpattn_chunk", "lpffn_chunk"),
            };
            let kname = cache_name(&st.variant, "k", sidx);
            let vname = cache_name(&st.variant, "v", sidx);
            // --- attention partials; the executable gathers the slot's
            // cache rows, inserts this chunk's K/V (masked by `valid`) and
            // attends over the prefix — caches persist in place
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &ATTN_FIELDS));
                    args.push(ArgRef::Resident(kname.clone()));
                    args.push(ArgRef::Resident(vname.clone()));
                    args.push(ArgRef::Resident("slot".into()));
                    args.push(ArgRef::Resident("off".into()));
                    args.push(ArgRef::Resident("valid".into()));
                    (
                        attn_key.to_string(),
                        args,
                        vec![
                            Some("act.partial".to_string()),
                            Some(kname.clone()),
                            Some(vname.clone()),
                        ],
                        vec![false, false, false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;

            // --- FFN partials (device-resident)
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &FFN_FIELDS));
                    (
                        ffn_key.to_string(),
                        args,
                        vec![Some("act.partial".to_string())],
                        vec![false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;
        }

        st.consumed = off + valid;
        if !last {
            return Ok(None);
        }

        // rank 0: logits of the last real token (the device→host edge)
        let logits = self
            .mesh
            .exec_rank(
                0,
                "logits_chunk",
                vec![
                    ArgRef::Resident("act".into()),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        let v = cfg.vocab;
        Ok(Some(logits[(valid - 1) * v..valid * v].to_vec()))
    }

    /// The paged counterpart of one chunk step: the attention executables
    /// bind the width-matched shared pools plus the slot's `[nb]` page
    /// table — there is no `slot` scalar upload; the page table *is* the
    /// slot indirection. Each chunk step covers exactly one page
    /// (`enable_paging` enforces `page_tokens == K`), so the step maps its
    /// block up front (copy-on-write-forking a mapping still shared with
    /// other holders) and publishes the completed block to the prefix index
    /// afterwards — making this slot the leader future identical prompts
    /// attach to. Cost charges are identical to the dense chunk step; the
    /// savings of paging are the steps followers *skip*, not cheaper steps.
    fn prefill_step_paged(&self, st: &mut ChunkedPrefill, k: usize) -> Result<Option<Vec<f32>>> {
        let var = self.variant(&st.variant)?;
        self.ensure_execs(&paged_chunk_exec_keys(&var.stages))?;

        let cfg = &self.entry.config;
        let d = cfg.d_model;
        let off = st.consumed;
        let valid = (st.tokens.len() - off).min(k);
        let last = off + valid == st.tokens.len();
        let mut chunk_tokens = st.tokens[off..off + valid].to_vec();
        chunk_tokens.resize(k, crate::text::tokenizer::PAD);
        let logits_rows = if last { k } else { 0 };
        self.mesh.charge_compute(
            prefill_flops(cfg, var.layers_equiv, off, k, logits_rows),
            prefill_bytes(cfg, var.layers_equiv, off, k, logits_rows),
        );

        // map this chunk's block (off is always page-aligned: attach_prefix
        // consumes whole blocks and every prior step consumed k tokens),
        // then freeze the per-stage [nb] page-table operands under one lock
        let block = off / k;
        let pts: Vec<Vec<i32>> = {
            let mut pg = self.paged_kv();
            pg.ensure_block(&st.variant, st.slot, block)?;
            (0..var.stages.len())
                .map(|sidx| pg.page_table(&st.variant, sidx, st.slot).to_vec())
                .collect()
        };

        self.mesh.upload_all("off", HostValue::scalar_i32(off as i32))?;
        self.mesh.upload_all("valid", HostValue::scalar_i32(valid as i32))?;

        // rank 0: embed the chunk (host→device edge), fan out as `act`
        let mut shadow = self
            .mesh
            .exec_rank(
                0,
                "embed_chunk",
                vec![
                    ArgRef::Host(HostValue::i32(vec![k], chunk_tokens)),
                    ArgRef::Resident("emb".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        self.mesh
            .broadcast_resident("act", &HostValue::f32(vec![k, d], shadow.clone()))?;

        for (sidx, stage) in var.stages.iter().enumerate() {
            let (attn_key, ffn_key, width) = match stage {
                ServeStage::Tp(_) => ("tpattn_chunk_paged", "tpffn_chunk", "half"),
                ServeStage::Lp(..) => ("lpattn_chunk_paged", "lpffn_chunk", "full"),
            };
            let poolk = crate::runtime::keys::kv_pool(width, "k");
            let poolv = crate::runtime::keys::kv_pool(width, "v");
            // the page table differs per stage: uploaded inside the stage
            // loop (paged host traffic is O(stages), the price of pooling)
            let nb = pts[sidx].len();
            self.mesh.upload_all("pt", HostValue::i32(vec![nb], pts[sidx].clone()))?;
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &ATTN_FIELDS));
                    args.push(ArgRef::Resident(poolk.clone()));
                    args.push(ArgRef::Resident(poolv.clone()));
                    args.push(ArgRef::Resident("pt".into()));
                    args.push(ArgRef::Resident("off".into()));
                    args.push(ArgRef::Resident("valid".into()));
                    (
                        attn_key.to_string(),
                        args,
                        vec![
                            Some("act.partial".to_string()),
                            Some(poolk.clone()),
                            Some(poolv.clone()),
                        ],
                        vec![false, false, false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;

            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &FFN_FIELDS));
                    (
                        ffn_key.to_string(),
                        args,
                        vec![Some("act.partial".to_string())],
                        vec![false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;
        }

        st.consumed = off + valid;
        // publish the completed block for shared-prefix reuse (a no-op for
        // the final chunk — only strictly-interior blocks are shareable)
        self.paged_kv().register_block(&st.variant, st.slot, &st.tokens, block);

        if !last {
            return Ok(None);
        }
        let logits = self
            .mesh
            .exec_rank(
                0,
                "logits_chunk",
                vec![
                    ArgRef::Resident("act".into()),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        let v = cfg.vocab;
        Ok(Some(logits[(valid - 1) * v..valid * v].to_vec()))
    }

    /// Convenience: run a full prefill through the chunked protocol (the
    /// streaming counterpart of [`ServingModel::prefill`]; falls back to
    /// the monolithic pass on legacy manifests). Returns the last real
    /// token's logits row.
    pub fn prefill_chunked(&self, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        self.prefill_chunked_v(self.default_tier(), slot, tokens)
    }

    /// [`ServingModel::prefill_chunked`] under an explicit tier.
    pub fn prefill_chunked_v(
        &self,
        vid: &VariantId,
        slot: usize,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let mut st = self.begin_prefill_v(vid, slot, tokens)?;
        loop {
            if let Some(logits) = self.prefill_step(&mut st)? {
                return Ok(logits);
            }
        }
    }
}

/// Emit the abstract dispatch trace of one chunk step — the op sequence
/// [`ServingModel::prefill_step`] issues for a mid-stream (`last = false`)
/// or final (`last = true`, adds the `logits_chunk` head) chunk. Kept next
/// to the dispatch body it mirrors; [`crate::verify::crosscheck_trace`]
/// pins the two together.
pub fn chunk_step_trace(
    vid: &VariantId,
    stages: &[ServeStage],
    ranks: usize,
    d_model: usize,
    k: usize,
    last: bool,
) -> DispatchTrace {
    let elems = k * d_model;
    let mut ops = vec![TraceOp::EnsureExecs { keys: chunk_exec_keys(stages) }];
    for name in ["slot", "off", "valid"] {
        ops.push(TraceOp::UploadAll { name: name.into() });
    }
    ops.push(TraceOp::ExecRank {
        rank: 0,
        key: "embed_chunk".into(),
        reads: vec!["emb".into()],
        writes: vec![],
    });
    ops.push(TraceOp::BroadcastResident { name: "act".into(), elems });
    for (sidx, stage) in stages.iter().enumerate() {
        let (attn_key, ffn_key) = match stage {
            ServeStage::Tp(_) => ("tpattn_chunk", "tpffn_chunk"),
            ServeStage::Lp(..) => ("lpattn_chunk", "lpffn_chunk"),
        };
        let kname = cache_name(vid, "k", sidx);
        let vname = cache_name(vid, "v", sidx);
        ops.push(TraceOp::ExecAll {
            key: attn_key.into(),
            per_rank: (0..ranks)
                .map(|rank| {
                    let mut reads = vec!["act".to_string()];
                    reads.extend(stage_weight_names(stage, rank, &ATTN_FIELDS));
                    reads.push(kname.clone());
                    reads.push(vname.clone());
                    reads.extend(["slot".into(), "off".into(), "valid".into()]);
                    RankIo {
                        reads,
                        writes: vec!["act.partial".into(), kname.clone(), vname.clone()],
                    }
                })
                .collect(),
        });
        ops.push(TraceOp::ReduceInto {
            partial: "act.partial".into(),
            dest: "act".into(),
            elems,
        });
        ops.push(TraceOp::ExecAll {
            key: ffn_key.into(),
            per_rank: (0..ranks)
                .map(|rank| {
                    let mut reads = vec!["act".to_string()];
                    reads.extend(stage_weight_names(stage, rank, &FFN_FIELDS));
                    RankIo { reads, writes: vec!["act.partial".into()] }
                })
                .collect(),
        });
        ops.push(TraceOp::ReduceInto {
            partial: "act.partial".into(),
            dest: "act".into(),
            elems,
        });
    }
    if last {
        ops.push(TraceOp::ExecRank {
            rank: 0,
            key: "logits_chunk".into(),
            reads: vec!["act".into(), "lnf".into(), "wout".into()],
            writes: vec![],
        });
    }
    DispatchTrace {
        label: format!("chunk[{vid}]@k{k}{}", if last { "+logits" } else { "" }),
        ranks,
        ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::InterconnectConfig;
    use crate::model::transform;
    use crate::model::weights::Weights;
    use crate::runtime::Manifest;

    fn quiet() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    fn build(window: (usize, usize)) -> Option<ServingModel> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 41);
        let plan = transform::pair_parallel(cfg.n_layers, window.0, window.1, true);
        ServingModel::new(&manifest, "td-small", &weights, &plan, quiet()).ok()
    }

    #[test]
    fn empty_prompt_is_rejected_not_a_panic() {
        let Some(m) = build((4, 10)) else { return };
        assert!(m.prefill(0, &[]).is_err(), "monolithic path must reject");
        assert!(m.begin_prefill(0, &[]).is_err(), "chunked path must reject");
        assert!(m.begin_prefill(m.entry.config.slots, &[1]).is_err(), "slot bounds");
    }

    /// Tentpole regression: a prompt prefilled in ceil(L/K) chunk steps
    /// must reproduce the monolithic fixed-T pass bit for bit — the
    /// returned first-token logits row AND every subsequent decode row —
    /// while charging modelled flops for the chunks actually run, not the
    /// covering bucket.
    #[test]
    fn chunked_prefill_bit_identical_to_monolithic() {
        let Some(m) = build((4, 10)) else { return };
        let Some(k) = m.prefill_chunk() else { return };
        let cfg = m.entry.config.clone();
        // L = 77: covering bucket T = 128, but only 3 chunks of 32
        let prompt: Vec<i32> = (0..77).map(|i| 40 + (i % 50)).collect();
        let steps = prompt.len().div_ceil(k);

        let le = m.default_variant().layers_equiv;
        m.mesh.metrics.reset();
        let mono = m.prefill(1, &prompt).unwrap();
        let mono_flops = m.mesh.metrics.modelled_flops();
        let (mono_sync, _, _, _) = m.mesh.metrics.snapshot();

        m.mesh.metrics.reset();
        let mut st = m.begin_prefill(0, &prompt).unwrap();
        let mut got = None;
        let mut ran = 0;
        while got.is_none() {
            assert_eq!(st.steps_remaining(Some(k)), steps - ran);
            got = m.prefill_step(&mut st).unwrap();
            ran += 1;
        }
        assert!(st.is_done());
        assert_eq!(ran, steps, "ceil(L / K) chunk steps expected");
        let chunked = got.unwrap();
        let chunk_flops = m.mesh.metrics.modelled_flops();
        let (chunk_sync, _, _, _) = m.mesh.metrics.snapshot();

        assert_eq!(chunked, mono, "first-token logits diverged");

        // modelled compute scales with the chunks actually run (96 padded
        // positions + [K, V] head), not the covering bucket (128 + [T, V])
        let expect_chunk: u64 = (0..steps)
            .map(|j| prefill_flops(&cfg, le, j * k, k, if j == steps - 1 { k } else { 0 }))
            .sum();
        assert_eq!(chunk_flops, expect_chunk);
        assert_eq!(mono_flops, prefill_flops(&cfg, le, 0, 128, 128));
        assert!(chunk_flops < mono_flops, "chunked must bill fewer modelled flops");
        // α–β accounting: 2 reduces per stage per pass vs per chunk
        assert_eq!(mono_sync as usize, m.all_reduces_per_token());
        assert_eq!(chunk_sync as usize, steps * m.all_reduces_per_token());

        // decode continuation: both slots hold the same sequence, so the
        // decode rows must be bit-identical lane for lane
        let next = crate::tensor::argmax(&mono) as i32;
        let rows = m
            .decode_active(&[(0, next, prompt.len() as i32), (1, next, prompt.len() as i32)])
            .unwrap();
        assert_eq!(rows[0].1, rows[1].1, "decode after chunked prefill diverged");
    }

    /// Plan-variant registry: a chunked prefill under a named tier is
    /// bit-identical to the monolithic pass under the same tier, and the
    /// cursor rejects tiers the model does not serve.
    #[test]
    fn chunked_prefill_respects_the_cursor_tier() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 41);
        let Ok(m) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        if m.prefill_chunk().is_none() || m.variant_ids().len() < 3 {
            return;
        }
        let prompt: Vec<i32> = (0..50).map(|i| 40 + (i % 50)).collect();
        for vid in m.variant_ids() {
            let mono = m.prefill_v(&vid, 0, &prompt).unwrap();
            let chunked = m.prefill_chunked_v(&vid, 1, &prompt).unwrap();
            assert_eq!(chunked, mono, "tier {vid}: chunked diverged from monolithic");
            let st = m.begin_prefill_v(&vid, 0, &prompt).unwrap();
            assert_eq!(st.variant(), &vid);
        }
        assert!(m.begin_prefill_v(&crate::runtime::VariantId::new("nope"), 0, &prompt).is_err());
    }

    /// A prompt longer than the largest seq bucket can't run monolithically
    /// but streams fine through chunks (admission frees the batch-1 /
    /// bucket-bound restriction up to ctx).
    #[test]
    fn chunked_prefill_handles_prompts_beyond_seq_buckets() {
        let Some(m) = build((2, 10)) else { return };
        if m.prefill_chunk().is_none() {
            return;
        }
        let ctx = m.entry.config.ctx;
        let largest = m.buckets.iter().copied().max().unwrap_or(0);
        if largest >= ctx {
            // buckets already cover ctx; the admission bound is ctx - 1
            assert_eq!(m.max_prompt_len(), ctx - 1);
        }
        let prompt: Vec<i32> = (0..(ctx - 1) as i32).map(|i| 40 + (i % 50)).collect();
        let logits = m.prefill_chunked(0, &prompt).unwrap();
        assert_eq!(logits.len(), m.entry.config.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    /// The shared-prefix acceptance criterion: two requests with the same
    /// hashed prefix charge the prefix prefill ONCE. The follower attaches
    /// the leader's blocks, runs only the final chunk, bills exactly that
    /// chunk's modelled flops — and still produces bit-identical logits.
    #[test]
    fn shared_prefix_prefills_once_and_charges_zero_for_reuse() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let entry = manifest.model("td-small").unwrap().clone();
        if entry.kv_pages.is_none() {
            return;
        }
        let cfg = entry.config.clone();
        let weights = Weights::random(&cfg, 41);
        let Ok(mut m) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        m.enable_paging().unwrap();
        let k = m.prefill_chunk().unwrap();
        let le = m.default_variant().layers_equiv;
        // 77-token prompt: blocks 0 and 1 (2k tokens) are shareable; the
        // final partial chunk never is
        let prompt: Vec<i32> = (0..77).map(|i| 40 + (i % 50)).collect();

        // leader pays the full ceil(L/K) chunk walk into slot 0
        m.mesh.metrics.reset();
        let lead = m.prefill_chunked(0, &prompt).unwrap();
        let lead_flops = m.mesh.metrics.modelled_flops();

        // follower attaches 2k tokens and runs ONE chunk into slot 1
        m.mesh.metrics.reset();
        let mut cur = m.begin_prefill(1, &prompt).unwrap();
        assert_eq!(cur.consumed(), 2 * k, "two shareable blocks attached");
        assert_eq!(cur.steps_remaining(Some(k)), 1);
        let follow = m.prefill_step(&mut cur).unwrap().expect("single step finishes");
        let follow_flops = m.mesh.metrics.modelled_flops();

        assert_eq!(follow, lead, "shared-prefix prefill diverged from the leader");
        // the skipped chunks charge ZERO modelled compute: the follower
        // bills exactly the final chunk at offset 2k
        assert_eq!(follow_flops, prefill_flops(&cfg, le, 2 * k, k, k));
        assert!(follow_flops < lead_flops, "reuse must be cheaper than the full walk");

        let ks = m.kv_stats().unwrap();
        assert_eq!(ks.prefix_hits, 1);
        assert_eq!(ks.prefix_lookups, 2, "leader probe missed, follower probe hit");
        assert_eq!(ks.prefix_shared_tokens, 2 * k as u64);

        // both slots decode in one bucketed round, bit-identical lanes
        let next = crate::tensor::argmax(&lead) as i32;
        let p = prompt.len() as i32;
        let rows = m.decode_active(&[(0, next, p), (1, next, p)]).unwrap();
        assert_eq!(rows[0].1, rows[1].1, "decode after shared-prefix attach diverged");
    }

    /// Satellite regression: decode must never attend to cache positions
    /// >= L. The monolithic path writes PAD-token K/V at [L, T); poisoning
    /// every row >= L (stand-in for any stale garbage) must not change a
    /// single decode bit, because each step overwrites row `pos` before
    /// attending and masks columns > pos.
    #[test]
    fn decode_never_attends_past_prompt_length() {
        let Some(m) = build((4, 10)) else { return };
        let cfg = m.entry.config.clone();
        let prompt: Vec<i32> = (0..42).map(|i| 60 + (i % 30)).collect();
        let l = prompt.len();
        // identical prefills; slot 1's cache tail then gets poisoned
        m.prefill(0, &prompt).unwrap();
        m.prefill(1, &prompt).unwrap();
        let tier = m.default_tier().clone();
        for sidx in 0..m.stages().len() {
            for kv in ["k", "v"] {
                let name = cache_name(&tier, kv, sidx);
                for w in &m.mesh.workers {
                    let hv = w.fetch(&name).unwrap();
                    let shape = hv.shape().to_vec();
                    let mut data = hv.as_f32().unwrap().to_vec();
                    let (c, width) = (shape[1], shape[2]);
                    let slot1 = c * width; // row-major [S, C, w]: slot 1's block
                    for row in l..c {
                        let base = slot1 + row * width;
                        for x in &mut data[base..base + width] {
                            *x = 1e9;
                        }
                    }
                    w.store(&name, HostValue::f32(shape, data)).unwrap();
                }
            }
        }
        // two decode steps so the second attends rows the first wrote
        let mut next = 65i32;
        for (i, pos) in (l..l + 2).enumerate() {
            let rows = m
                .decode_active(&[(0, next, pos as i32), (1, next, pos as i32)])
                .unwrap();
            assert_eq!(
                rows[0].1, rows[1].1,
                "decode step {i} attended to a position >= L"
            );
            next = crate::tensor::argmax(&rows[0].1) as i32;
            assert_eq!(rows[0].1.len(), cfg.vocab);
        }
    }
}
