//! Graph plans: the execution order of transformer sub-blocks after a §3
//! transformation of the computational graph.

use std::fmt;

/// One stage of the plan — one *effective layer* in the paper's sense
/// (stages execute strictly sequentially; everything inside a stage is
/// parallel / fused).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Stage {
    /// A single original layer, run sequentially.
    Seq(usize),
    /// The paper's Layer Parallelism pair, deployed (LP-TP) numerics:
    /// `m = x + A_a(x) + A_b(x); y = m + F_a(m) + F_b(m)` — 2 all-reduces.
    PairLp(usize, usize),
    /// PAR approximation (paper eq. 2) over an arbitrary set of layers:
    /// each path computes its own `x + A_i(x)` and `F_i` on that; paths sum
    /// into the residual once. Used for the §3 heatmap analysis.
    ParBlock(Vec<usize>),
    /// Weight-averaged merge of the listed layers, run as one layer.
    Merged(Vec<usize>),
}

impl Stage {
    /// Layers consumed by this stage.
    pub fn layers(&self) -> Vec<usize> {
        match self {
            Stage::Seq(i) => vec![*i],
            Stage::PairLp(a, b) => vec![*a, *b],
            Stage::ParBlock(v) | Stage::Merged(v) => v.clone(),
        }
    }
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stage::Seq(i) => write!(f, "{i}"),
            Stage::PairLp(a, b) => write!(f, "[{a}∥{b}]"),
            Stage::ParBlock(v) => write!(
                f,
                "par({})",
                v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
            ),
            Stage::Merged(v) => write!(
                f,
                "merge({})",
                v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
            ),
        }
    }
}

/// A full model plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GraphPlan {
    pub n_layers: usize,
    pub stages: Vec<Stage>,
}

impl GraphPlan {
    /// Build from a manifest plan variant's stage lists (see
    /// `runtime::artifacts::VariantSpec`): `[i]` → [`Stage::Seq`],
    /// `[a, b]` → [`Stage::PairLp`]. Validates the result, so a malformed
    /// manifest variant errors here instead of at serve time.
    pub fn from_stage_lists(
        n_layers: usize,
        stages: &[Vec<usize>],
    ) -> crate::Result<GraphPlan> {
        if stages.is_empty() {
            // a zero-stage plan would "serve" embed→logits with every
            // transformer layer skipped — reject it up front
            return Err(crate::Error::Plan("variant has no stages".into()));
        }
        let mut out = Vec::with_capacity(stages.len());
        for st in stages {
            match st.as_slice() {
                [i] => out.push(Stage::Seq(*i)),
                [a, b] => out.push(Stage::PairLp(*a, *b)),
                other => {
                    return Err(crate::Error::Plan(format!(
                        "variant stage arity {} unsupported (want 1 or 2 layers)",
                        other.len()
                    )))
                }
            }
        }
        let plan = GraphPlan { n_layers, stages: out };
        plan.validate()?;
        Ok(plan)
    }

    /// Paper's *effective depth*: sequential stages from input to output.
    pub fn effective_depth(&self) -> usize {
        self.stages.len()
    }

    /// Δ in the paper's figures: number of original layers absorbed into
    /// parallel groups (e.g. 4 pairs → Δ=8, depth reduced by 4).
    pub fn delta(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s {
                Stage::Seq(_) => 0,
                other => other.layers().len(),
            })
            .sum()
    }

    /// Validate: every original layer used at most once, indices in range
    /// (pruning = layers absent entirely).
    pub fn validate(&self) -> crate::Result<()> {
        let mut seen = vec![false; self.n_layers];
        for st in &self.stages {
            for l in st.layers() {
                if l >= self.n_layers {
                    return Err(crate::Error::Plan(format!("layer {l} out of range")));
                }
                if seen[l] {
                    return Err(crate::Error::Plan(format!("layer {l} used twice")));
                }
                seen[l] = true;
            }
            if let Stage::PairLp(a, b) = st {
                if a == b {
                    return Err(crate::Error::Plan("degenerate pair".into()));
                }
            }
            if let Stage::ParBlock(v) | Stage::Merged(v) = st {
                if v.is_empty() {
                    return Err(crate::Error::Plan("empty block".into()));
                }
            }
        }
        Ok(())
    }

    pub fn describe(&self) -> String {
        self.stages
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join(" → ")
    }

    /// All-reduce count per token under tensor parallelism (the quantity
    /// the paper's speedup derives from): Seq/Merged = 2 per stage,
    /// PairLp = 2 per stage (vs 4 for its two layers run sequentially),
    /// ParBlock = 2 per stage.
    pub fn all_reduces_per_token(&self) -> usize {
        self.stages.len() * 2
    }

    /// Layers covered by [`Stage::PairLp`] stages, in stage order.
    pub fn lp_layers(&self) -> Vec<usize> {
        self.stages
            .iter()
            .filter_map(|s| match s {
                Stage::PairLp(a, b) => Some([*a, *b]),
                _ => None,
            })
            .flatten()
            .collect()
    }

    /// Whether the LP pairs cover one contiguous band of layers — the shape
    /// the paper's §3 transform always produces (parallelize layers
    /// `[start, end)`). A gapped band still serves, but the verifier warns:
    /// it is almost always a manifest typo. Vacuously true with no pairs.
    pub fn lp_band_contiguous(&self) -> bool {
        let mut layers = self.lp_layers();
        layers.sort_unstable();
        layers.windows(2).all(|w| w[1] == w[0] + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_and_delta() {
        let p = GraphPlan {
            n_layers: 6,
            stages: vec![
                Stage::Seq(0),
                Stage::PairLp(1, 2),
                Stage::PairLp(3, 4),
                Stage::Seq(5),
            ],
        };
        p.validate().unwrap();
        assert_eq!(p.effective_depth(), 4);
        assert_eq!(p.delta(), 4);
        assert_eq!(p.all_reduces_per_token(), 8); // vs 12 sequential
    }

    #[test]
    fn validation_catches_reuse_and_range() {
        let p = GraphPlan { n_layers: 3, stages: vec![Stage::Seq(0), Stage::Seq(0)] };
        assert!(p.validate().is_err());
        let p = GraphPlan { n_layers: 3, stages: vec![Stage::Seq(7)] };
        assert!(p.validate().is_err());
        let p = GraphPlan { n_layers: 3, stages: vec![Stage::ParBlock(vec![])] };
        assert!(p.validate().is_err());
    }

    #[test]
    fn from_stage_lists_maps_variant_specs() {
        let p =
            GraphPlan::from_stage_lists(6, &[vec![0], vec![1, 2], vec![3], vec![4, 5]])
                .unwrap();
        assert_eq!(
            p.stages,
            vec![Stage::Seq(0), Stage::PairLp(1, 2), Stage::Seq(3), Stage::PairLp(4, 5)]
        );
        assert_eq!(p.effective_depth(), 4);
        // arity, emptiness, reuse and range all rejected
        assert!(GraphPlan::from_stage_lists(6, &[vec![0, 1, 2]]).is_err());
        assert!(GraphPlan::from_stage_lists(6, &[vec![]]).is_err());
        assert!(GraphPlan::from_stage_lists(6, &[]).is_err(), "zero-stage plan");
        assert!(GraphPlan::from_stage_lists(6, &[vec![0], vec![0, 1]]).is_err());
        assert!(GraphPlan::from_stage_lists(2, &[vec![5]]).is_err());
    }

    #[test]
    fn lp_band_contiguity() {
        let band = GraphPlan::from_stage_lists(
            8,
            &[vec![0], vec![1, 2], vec![3, 4], vec![5], vec![6], vec![7]],
        )
        .unwrap();
        assert_eq!(band.lp_layers(), vec![1, 2, 3, 4]);
        assert!(band.lp_band_contiguous());

        let gapped =
            GraphPlan::from_stage_lists(8, &[vec![0, 1], vec![2], vec![4, 5], vec![3]])
                .unwrap();
        assert!(!gapped.lp_band_contiguous());

        let none = GraphPlan::from_stage_lists(2, &[vec![0], vec![1]]).unwrap();
        assert!(none.lp_layers().is_empty());
        assert!(none.lp_band_contiguous(), "vacuously contiguous");
    }

    #[test]
    fn display_reads_well() {
        let p = GraphPlan {
            n_layers: 4,
            stages: vec![Stage::Seq(0), Stage::PairLp(1, 2), Stage::Merged(vec![3])],
        };
        assert_eq!(p.describe(), "0 → [1∥2] → merge(3)");
    }
}
