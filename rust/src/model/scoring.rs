//! Scoring executor: run any [`GraphPlan`] (including every §3 transform)
//! and produce logits / negative-log-likelihood for perplexity.
//!
//! Composition happens per *sub-block delta*: the AOT artifacts `attn_t{T}`
//! and `ffn_t{T}` compute A(x) and F(x) (pre-norm deltas, no residual), so
//! the coordinator is free to rewire the residual stream arbitrarily —
//! shuffling, pruning, merging and both parallel forms all reduce to
//! different sequences of delta calls + host-side adds. No per-transform
//! compilation is needed, which is what makes the Fig. 3 heatmaps (hundreds
//! of configurations) tractable.

use std::collections::HashMap;
use std::rc::Rc;

use xla::PjRtLoadedExecutable;

use crate::error::{Error, Result};
use crate::model::plan::{GraphPlan, Stage};
use crate::model::weights::{Tensor, Weights, ATTN_FIELDS, FFN_FIELDS};
use crate::runtime::pjrt::{Engine, HostValue};
use crate::runtime::ModelEntry;
use crate::tensor::{add_slices, log_softmax_at};
use crate::text::tokenizer::PAD;

pub struct Scorer<'a> {
    engine: &'a Engine,
    pub entry: &'a ModelEntry,
    weights: &'a Weights,
    /// Sequence bucket (T) this scorer is compiled for.
    pub bucket: usize,
    exe_embed: Rc<PjRtLoadedExecutable>,
    exe_attn: Rc<PjRtLoadedExecutable>,
    exe_ffn: Rc<PjRtLoadedExecutable>,
    exe_logits: Rc<PjRtLoadedExecutable>,
    /// Merged-layer weights are derived; cache them per stage signature.
    merged_cache: std::cell::RefCell<HashMap<Vec<usize>, HashMap<String, Tensor>>>,
}

impl<'a> Scorer<'a> {
    pub fn new(
        engine: &'a Engine,
        entry: &'a ModelEntry,
        weights: &'a Weights,
        bucket: usize,
    ) -> Result<Scorer<'a>> {
        let load = |name: String| -> Result<Rc<PjRtLoadedExecutable>> {
            engine.load(&entry.artifact(&name)?.file)
        };
        Ok(Scorer {
            engine,
            entry,
            weights,
            bucket,
            exe_embed: load(format!("embed_t{bucket}"))?,
            exe_attn: load(format!("attn_t{bucket}"))?,
            exe_ffn: load(format!("ffn_t{bucket}"))?,
            exe_logits: load(format!("logits_t{bucket}"))?,
            merged_cache: Default::default(),
        })
    }

    fn d(&self) -> usize {
        self.entry.config.d_model
    }

    fn call1(
        &self,
        exe: &PjRtLoadedExecutable,
        h: &[f32],
        hshape: [usize; 2],
        ws: &[Tensor],
    ) -> Result<Vec<f32>> {
        let mut args = Vec::with_capacity(1 + ws.len());
        args.push(HostValue::f32(hshape.to_vec(), h.to_vec()));
        for t in ws {
            args.push(t.host());
        }
        let mut outs = self.engine.call(exe, &args)?;
        if outs.len() != 1 {
            return Err(Error::msg("expected single output"));
        }
        outs.remove(0).into_f32()
    }

    fn attn_delta_t(&self, h: &[f32], ws: &[Tensor]) -> Result<Vec<f32>> {
        self.call1(&self.exe_attn, h, [self.bucket, self.d()], ws)
    }

    fn ffn_delta_t(&self, h: &[f32], ws: &[Tensor]) -> Result<Vec<f32>> {
        self.call1(&self.exe_ffn, h, [self.bucket, self.d()], ws)
    }

    fn layer_tensors(&self, i: usize) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        Ok((self.weights.attn_full(i)?, self.weights.ffn_full(i)?))
    }

    fn merged_tensors(&self, layers: &[usize]) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let mut cache = self.merged_cache.borrow_mut();
        if !cache.contains_key(layers) {
            cache.insert(layers.to_vec(), self.weights.merged_layer(layers)?);
        }
        let m = &cache[layers];
        let attn = ATTN_FIELDS.iter().map(|f| m[*f].clone()).collect();
        let ffn = FFN_FIELDS.iter().map(|f| m[*f].clone()).collect();
        Ok((attn, ffn))
    }

    /// Run one sequential layer in place: `h += A(h); h += F(h)`.
    fn apply_seq(&self, h: &mut Vec<f32>, attn: &[Tensor], ffn: &[Tensor]) -> Result<()> {
        let da = self.attn_delta_t(h, attn)?;
        add_slices(h, &da);
        let df = self.ffn_delta_t(h, ffn)?;
        add_slices(h, &df);
        Ok(())
    }

    /// Run one plan stage in place.
    pub fn apply_stage(&self, h: &mut Vec<f32>, stage: &Stage) -> Result<()> {
        match stage {
            Stage::Seq(i) => {
                let (a, f) = self.layer_tensors(*i)?;
                self.apply_seq(h, &a, &f)
            }
            Stage::Merged(v) => {
                let (a, f) = self.merged_tensors(v)?;
                self.apply_seq(h, &a, &f)
            }
            Stage::PairLp(a, b) => {
                // deployed LP-TP numerics: shared post-attention residual
                let (aa, fa) = self.layer_tensors(*a)?;
                let (ab, fb) = self.layer_tensors(*b)?;
                let da = self.attn_delta_t(h, &aa)?;
                let db = self.attn_delta_t(h, &ab)?;
                add_slices(h, &da);
                add_slices(h, &db); // h is now m
                let fa_ = self.ffn_delta_t(h, &fa)?;
                let fb_ = self.ffn_delta_t(h, &fb)?;
                add_slices(h, &fa_);
                add_slices(h, &fb_);
                Ok(())
            }
            Stage::ParBlock(v) => {
                // PAR approximation (paper eq. 2): each path sees the same
                // input and computes its own intermediate x + A_i(x).
                let base = h.clone();
                for &i in v {
                    let (a, f) = self.layer_tensors(i)?;
                    let da = self.attn_delta_t(&base, &a)?;
                    let mut xi = base.clone();
                    add_slices(&mut xi, &da);
                    let df = self.ffn_delta_t(&xi, &f)?;
                    add_slices(h, &da);
                    add_slices(h, &df);
                }
                Ok(())
            }
        }
    }

    /// Hidden states after the full plan. `tokens.len()` must equal bucket.
    pub fn hidden(&self, tokens: &[i32], plan: &GraphPlan) -> Result<Vec<f32>> {
        if tokens.len() != self.bucket {
            return Err(Error::msg(format!(
                "expected {} tokens, got {}",
                self.bucket,
                tokens.len()
            )));
        }
        let outs = self.engine.call(
            &self.exe_embed,
            &[
                HostValue::i32(vec![self.bucket], tokens.to_vec()),
                self.weights.get("emb")?.host(),
            ],
        )?;
        let mut h = outs.into_iter().next().unwrap().into_f32()?;
        for stage in &plan.stages {
            self.apply_stage(&mut h, stage)?;
        }
        Ok(h)
    }

    /// Logits `[T, V]` after the plan.
    pub fn logits(&self, tokens: &[i32], plan: &GraphPlan) -> Result<Vec<f32>> {
        let h = self.hidden(tokens, plan)?;
        let mut outs = self.engine.call(
            &self.exe_logits,
            &[
                HostValue::f32(vec![self.bucket, self.d()], h),
                self.weights.get("lnf")?.host(),
                self.weights.get("wout")?.host(),
            ],
        )?;
        outs.remove(0).into_f32()
    }

    /// Sum of next-token NLL over a window of `bucket + 1` tokens
    /// (input = first T, target = shifted by one). PAD targets are masked.
    pub fn window_nll(&self, window: &[i32], plan: &GraphPlan) -> Result<(f64, usize)> {
        if window.len() != self.bucket + 1 {
            return Err(Error::msg(format!(
                "window must be bucket+1 = {} tokens, got {}",
                self.bucket + 1,
                window.len()
            )));
        }
        let logits = self.logits(&window[..self.bucket], plan)?;
        let v = self.entry.config.vocab;
        let mut nll = 0.0f64;
        let mut count = 0usize;
        for t in 0..self.bucket {
            let target = window[t + 1];
            if target == PAD || window[t] == PAD {
                continue;
            }
            nll -= log_softmax_at(&logits[t * v..(t + 1) * v], target as usize);
            count += 1;
        }
        Ok((nll, count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transform;
    use crate::runtime::Manifest;

    struct Ctx {
        engine: Engine,
        manifest: Manifest,
        weights: Weights,
    }

    fn ctx() -> Option<Ctx> {
        let manifest = Manifest::load_default().ok()?;
        let engine = Engine::cpu().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 42);
        Some(Ctx { engine, manifest, weights })
    }

    fn toks(n: usize, seed: u64) -> Vec<i32> {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        (0..n).map(|_| rng.below(255) as i32).collect()
    }

    #[test]
    fn sequential_logits_are_finite_and_shaped() {
        let Some(c) = ctx() else { return };
        let entry = c.manifest.model("td-small").unwrap();
        let s = Scorer::new(&c.engine, entry, &c.weights, 32).unwrap();
        let plan = transform::sequential(entry.config.n_layers);
        let l = s.logits(&toks(32, 1), &plan).unwrap();
        assert_eq!(l.len(), 32 * entry.config.vocab);
        assert!(l.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prune_changes_output_but_prune_nothing_matches_seq() {
        let Some(c) = ctx() else { return };
        let entry = c.manifest.model("td-small").unwrap();
        let s = Scorer::new(&c.engine, entry, &c.weights, 32).unwrap();
        let n = entry.config.n_layers;
        let t = toks(32, 2);
        let seq = s.logits(&t, &transform::sequential(n)).unwrap();
        // prune an empty window == sequential
        let noop = s.logits(&t, &transform::prune(n, 3, 3)).unwrap();
        assert_eq!(seq, noop);
        let pruned = s.logits(&t, &transform::prune(n, 3, 6)).unwrap();
        assert_ne!(seq, pruned);
    }

    #[test]
    fn lp_pair_and_par_block_agree_only_in_first_half() {
        // PairLp and ParBlock share the attention phase but differ on the
        // FFN inputs — outputs must differ (abl3's whole point).
        let Some(c) = ctx() else { return };
        let entry = c.manifest.model("td-small").unwrap();
        let s = Scorer::new(&c.engine, entry, &c.weights, 32).unwrap();
        let n = entry.config.n_layers;
        let t = toks(32, 3);
        let lp = s.logits(&t, &transform::pair_parallel(n, 4, 6, true)).unwrap();
        let par = s.logits(&t, &transform::pair_parallel(n, 4, 6, false)).unwrap();
        assert_ne!(lp, par);
        // both still finite
        assert!(lp.iter().chain(par.iter()).all(|x| x.is_finite()));
    }

    #[test]
    fn window_nll_masks_pad() {
        let Some(c) = ctx() else { return };
        let entry = c.manifest.model("td-small").unwrap();
        let s = Scorer::new(&c.engine, entry, &c.weights, 32).unwrap();
        let plan = transform::sequential(entry.config.n_layers);
        let mut w = toks(33, 4);
        for x in w.iter_mut().skip(20) {
            *x = PAD;
        }
        let (nll, count) = s.window_nll(&w, &plan).unwrap();
        assert!(count < 20);
        assert!(nll.is_finite() && nll > 0.0);
    }

    #[test]
    fn merge_of_identical_layer_is_identity() {
        // merging a layer with itself must equal running that layer
        let Some(c) = ctx() else { return };
        let entry = c.manifest.model("td-small").unwrap();
        let s = Scorer::new(&c.engine, entry, &c.weights, 32).unwrap();
        let n = entry.config.n_layers;
        let t = toks(32, 5);
        let plan_a = transform::sequential(n);
        let mut stages = plan_a.stages.clone();
        stages[2] = Stage::Merged(vec![2]);
        let plan_b = GraphPlan { n_layers: n, stages };
        let a = s.logits(&t, &plan_a).unwrap();
        let b = s.logits(&t, &plan_b).unwrap();
        assert_eq!(a, b);
    }
}
