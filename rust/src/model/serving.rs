//! Serving executor: the paper's §4 tensor-parallel deployment, with LP
//! pairs as a first-class stage kind.
//!
//! Layout over a 2-rank mesh (paper's setup — one accelerator per LP path):
//!
//! * `Tp(i)` stage — classic Megatron sharding: each rank holds half the
//!   heads of layer i (and half the FFN hidden), computes a low-rank
//!   partial, and the pair of partials is **all-reduced twice per layer**
//!   (after attention, after FFN).
//! * `Lp(a, b)` stage — the paper's transform: rank 0 holds *all* of layer
//!   a, rank 1 all of layer b. One all-reduce combines `A_a(x) + A_b(x)`
//!   into the shared residual m, one more combines `F_a(m) + F_b(m)` —
//!   **two all-reduces per layer pair**, i.e. half of sequential TP.
//!
//! KV caches live as named resident buffers on the owning rank(s); decode
//! carries them in/out of the layer executables (see worker.rs for the
//! tuple-output caveat).

use std::path::Path;

use crate::config::InterconnectConfig;
use crate::error::{Error, Result};
use crate::model::plan::{GraphPlan, Stage};
use crate::model::weights::Weights;
use crate::parallel::worker::ArgRef;
use crate::parallel::Mesh;
use crate::runtime::pjrt::HostValue;
use crate::runtime::{Manifest, ModelEntry};
use crate::tensor::add_slices;

/// Serving-mode stage (subset of [`Stage`] that the TP runtime supports).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeStage {
    Tp(usize),
    Lp(usize, usize),
}

pub struct ServingModel {
    pub mesh: Mesh,
    pub entry: ModelEntry,
    pub stages: Vec<ServeStage>,
    pub buckets: Vec<usize>,
    ranks: usize,
}

impl ServingModel {
    /// Build from a graph plan (Seq → Tp, PairLp → Lp; other stages are a
    /// scoring-only feature and rejected here).
    pub fn new(
        manifest: &Manifest,
        model_name: &str,
        weights: &Weights,
        plan: &GraphPlan,
        net: InterconnectConfig,
    ) -> Result<ServingModel> {
        plan.validate().map_err(|e| Error::Serving(format!("bad plan: {e}")))?;
        let entry = manifest.model(model_name)?.clone();
        let mut stages = Vec::new();
        for st in &plan.stages {
            match st {
                Stage::Seq(i) => stages.push(ServeStage::Tp(*i)),
                Stage::PairLp(a, b) => stages.push(ServeStage::Lp(*a, *b)),
                other => {
                    return Err(Error::Serving(format!(
                        "stage {other} not servable under TP (scoring only)"
                    )))
                }
            }
        }
        let ranks = 2;
        let mesh = Mesh::new(ranks, net);
        let m = ServingModel {
            mesh,
            entry,
            stages,
            buckets: manifest.seq_buckets.clone(),
            ranks,
        };
        m.compile_artifacts()?;
        m.upload_weights(weights)?;
        m.init_caches()?;
        Ok(m)
    }

    fn art(&self, name: &str) -> Result<&Path> {
        Ok(self.entry.artifact(name)?.file.as_path())
    }

    fn compile_artifacts(&self) -> Result<()> {
        let mut keys: Vec<String> = vec![
            "tpattn_decode".into(),
            "tpffn_decode".into(),
            "lpattn_decode".into(),
            "lpffn_decode".into(),
            "embed_decode".into(),
            "logits_decode".into(),
        ];
        for t in &self.buckets {
            keys.push(format!("embed_t{t}"));
            keys.push(format!("logits_t{t}"));
            keys.push(format!("tpattn_prefill_t{t}"));
            keys.push(format!("tpffn_prefill_t{t}"));
            keys.push(format!("lpattn_prefill_t{t}"));
            keys.push(format!("ffn_t{t}")); // LP FFN prefill (full width)
            keys.push(format!("cache_insert_half_t{t}"));
            keys.push(format!("cache_insert_full_t{t}"));
        }
        for key in keys {
            self.mesh.compile_all(&key, self.art(&key)?)?;
        }
        Ok(())
    }

    fn upload_weights(&self, w: &Weights) -> Result<()> {
        // rank 0 additionally owns embedding + head
        self.mesh.workers[0].store("emb", w.get("emb")?.host())?;
        self.mesh.workers[0].store("lnf", w.get("lnf")?.host())?;
        self.mesh.workers[0].store("wout", w.get("wout")?.host())?;
        for (sidx, stage) in self.stages.iter().enumerate() {
            match stage {
                ServeStage::Tp(i) => {
                    for (rank, worker) in self.mesh.workers.iter().enumerate() {
                        let attn = w.attn_shard(*i, rank, self.ranks)?;
                        for (t, field) in
                            attn.iter().zip(["ln1", "wq", "wk", "wv", "wo"])
                        {
                            worker.store(&format!("s{sidx}.{field}"), t.host())?;
                        }
                        let ffn = w.ffn_shard(*i, rank, self.ranks)?;
                        for (t, field) in ffn.iter().zip(["ln2", "wg", "wu", "wd"]) {
                            worker.store(&format!("s{sidx}.{field}"), t.host())?;
                        }
                    }
                }
                ServeStage::Lp(a, b) => {
                    // rank r owns the r-th layer of the pair, full width
                    for (rank, layer) in [(0usize, *a), (1usize, *b)] {
                        let worker = &self.mesh.workers[rank];
                        let attn = w.attn_full(layer)?;
                        for (t, field) in
                            attn.iter().zip(["ln1", "wq", "wk", "wv", "wo"])
                        {
                            worker.store(&format!("s{sidx}.{field}"), t.host())?;
                        }
                        let ffn = w.ffn_full(layer)?;
                        for (t, field) in ffn.iter().zip(["ln2", "wg", "wu", "wd"]) {
                            worker.store(&format!("s{sidx}.{field}"), t.host())?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn cache_width(&self, stage: &ServeStage) -> usize {
        match stage {
            ServeStage::Tp(_) => self.entry.config.d_model / self.ranks,
            ServeStage::Lp(..) => self.entry.config.d_model,
        }
    }

    fn init_caches(&self) -> Result<()> {
        let cfg = &self.entry.config;
        for (sidx, stage) in self.stages.iter().enumerate() {
            let w = self.cache_width(stage);
            let zeros = HostValue::f32(
                vec![cfg.slots, cfg.ctx, w],
                vec![0.0; cfg.slots * cfg.ctx * w],
            );
            for worker in &self.mesh.workers {
                worker.store(&format!("kv.k.{sidx}"), zeros.clone())?;
                worker.store(&format!("kv.v.{sidx}"), zeros.clone())?;
            }
        }
        Ok(())
    }

    /// Effective depth of the serving plan (stages count).
    pub fn effective_depth(&self) -> usize {
        self.stages.len()
    }

    /// All-reduce operations per decode token: 2 per stage.
    pub fn all_reduces_per_token(&self) -> usize {
        self.stages.len() * 2
    }

    fn weight_args(sidx: usize, fields: &[&str]) -> Vec<ArgRef> {
        fields
            .iter()
            .map(|f| ArgRef::Resident(format!("s{sidx}.{f}")))
            .collect()
    }

    /// Prefill `tokens` into `slot`. Returns the logits row for the last
    /// real token ([V]) — the distribution of the first generated token.
    pub fn prefill(&self, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.entry.config;
        let t = crate::text::tokenizer::bucket_for(tokens.len(), &self.buckets)
            .ok_or_else(|| Error::Serving(format!("prompt too long: {}", tokens.len())))?;
        let padded = crate::text::tokenizer::pad_to(tokens, t);
        let d = cfg.d_model;

        // rank 0: embed
        let mut h = self.mesh.workers[0]
            .exec(
                &format!("embed_t{t}"),
                vec![
                    ArgRef::Host(HostValue::i32(vec![t], padded)),
                    ArgRef::Resident("emb".into()),
                ],
            )?
            .remove(0)
            .into_f32()?;

        for (sidx, stage) in self.stages.iter().enumerate() {
            let (attn_key, ffn_key, insert_key) = match stage {
                ServeStage::Tp(_) => (
                    format!("tpattn_prefill_t{t}"),
                    format!("tpffn_prefill_t{t}"),
                    format!("cache_insert_half_t{t}"),
                ),
                ServeStage::Lp(..) => (
                    format!("lpattn_prefill_t{t}"),
                    format!("ffn_t{t}"),
                    format!("cache_insert_full_t{t}"),
                ),
            };
            // --- attention partials + KV stripes
            let calls = (0..self.ranks)
                .map(|_| {
                    let mut args =
                        vec![ArgRef::Host(HostValue::f32(vec![t, d], h.clone()))];
                    args.extend(Self::weight_args(sidx, &["ln1", "wq", "wk", "wv", "wo"]));
                    (
                        attn_key.clone(),
                        args,
                        vec![None, Some("tmp.k".to_string()), Some("tmp.v".to_string())],
                        vec![true, false, false],
                    )
                })
                .collect();
            let mut outs = self.mesh.exec_all(calls)?;
            let parts: Vec<HostValue> =
                outs.iter_mut().map(|o| o.remove(0)).collect();
            let reduced = self.mesh.all_reduce(parts)?;
            add_slices(&mut h, reduced.as_f32()?);

            // --- insert KV stripes into the slot (both ranks, k then v)
            for (stripe, cache) in [("tmp.k", "kv.k"), ("tmp.v", "kv.v")] {
                let calls = (0..self.ranks)
                    .map(|_| {
                        (
                            insert_key.clone(),
                            vec![
                                ArgRef::Resident(format!("{cache}.{sidx}")),
                                ArgRef::Resident(stripe.to_string()),
                                ArgRef::Host(HostValue::scalar_i32(slot as i32)),
                            ],
                            vec![Some(format!("{cache}.{sidx}"))],
                            vec![false],
                        )
                    })
                    .collect();
                self.mesh.exec_all(calls)?;
            }

            // --- FFN partials
            let calls = (0..self.ranks)
                .map(|_| {
                    let mut args =
                        vec![ArgRef::Host(HostValue::f32(vec![t, d], h.clone()))];
                    args.extend(Self::weight_args(sidx, &["ln2", "wg", "wu", "wd"]));
                    (ffn_key.clone(), args, vec![], vec![true])
                })
                .collect();
            let mut outs = self.mesh.exec_all(calls)?;
            let parts: Vec<HostValue> =
                outs.iter_mut().map(|o| o.remove(0)).collect();
            let reduced = self.mesh.all_reduce(parts)?;
            add_slices(&mut h, reduced.as_f32()?);
        }

        // rank 0: logits of the last real token
        let logits = self.mesh.workers[0]
            .exec(
                &format!("logits_t{t}"),
                vec![
                    ArgRef::Host(HostValue::f32(vec![t, d], h)),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
            )?
            .remove(0)
            .into_f32()?;
        let v = cfg.vocab;
        let last = tokens.len() - 1;
        Ok(logits[last * v..(last + 1) * v].to_vec())
    }

    /// One decode step over all S slots. `tokens[s]` / `pos[s]` from the
    /// slot manager. Returns `[S, V]` logits (row-major).
    pub fn decode_step(&self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.entry.config;
        let s = cfg.slots;
        if tokens.len() != s || pos.len() != s {
            return Err(Error::Serving(format!(
                "decode_step wants {s} slot tokens/positions"
            )));
        }
        let d = cfg.d_model;
        let mut x = self.mesh.workers[0]
            .exec(
                "embed_decode",
                vec![
                    ArgRef::Host(HostValue::i32(vec![s], tokens.to_vec())),
                    ArgRef::Resident("emb".into()),
                ],
            )?
            .remove(0)
            .into_f32()?;

        for (sidx, stage) in self.stages.iter().enumerate() {
            let (attn_key, ffn_key) = match stage {
                ServeStage::Tp(_) => ("tpattn_decode", "tpffn_decode"),
                ServeStage::Lp(..) => ("lpattn_decode", "lpffn_decode"),
            };
            let calls = (0..self.ranks)
                .map(|_| {
                    let mut args =
                        vec![ArgRef::Host(HostValue::f32(vec![s, d], x.clone()))];
                    args.extend(Self::weight_args(sidx, &["ln1", "wq", "wk", "wv", "wo"]));
                    args.push(ArgRef::Resident(format!("kv.k.{sidx}")));
                    args.push(ArgRef::Resident(format!("kv.v.{sidx}")));
                    args.push(ArgRef::Host(HostValue::i32(vec![s], pos.to_vec())));
                    (
                        attn_key.to_string(),
                        args,
                        vec![
                            None,
                            Some(format!("kv.k.{sidx}")),
                            Some(format!("kv.v.{sidx}")),
                        ],
                        vec![true, false, false],
                    )
                })
                .collect();
            let mut outs = self.mesh.exec_all(calls)?;
            let parts: Vec<HostValue> = outs.iter_mut().map(|o| o.remove(0)).collect();
            let reduced = self.mesh.all_reduce(parts)?;
            add_slices(&mut x, reduced.as_f32()?);

            let calls = (0..self.ranks)
                .map(|_| {
                    let mut args =
                        vec![ArgRef::Host(HostValue::f32(vec![s, d], x.clone()))];
                    args.extend(Self::weight_args(sidx, &["ln2", "wg", "wu", "wd"]));
                    (ffn_key.to_string(), args, vec![], vec![true])
                })
                .collect();
            let mut outs = self.mesh.exec_all(calls)?;
            let parts: Vec<HostValue> = outs.iter_mut().map(|o| o.remove(0)).collect();
            let reduced = self.mesh.all_reduce(parts)?;
            add_slices(&mut x, reduced.as_f32()?);
        }

        self.mesh.workers[0]
            .exec(
                "logits_decode",
                vec![
                    ArgRef::Host(HostValue::f32(vec![s, d], x)),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
            )?
            .remove(0)
            .into_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transform;
    use crate::runtime::Manifest;

    fn quiet() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    fn build(plan_fn: impl Fn(usize) -> GraphPlan) -> Option<ServingModel> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 7);
        let plan = plan_fn(cfg.n_layers);
        ServingModel::new(&manifest, "td-small", &weights, &plan, quiet()).ok()
    }

    #[test]
    fn rejects_unservable_plans() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 7);
        let plan = transform::merge(cfg.n_layers, 2, 5);
        let r = ServingModel::new(&manifest, "td-small", &weights, &plan, quiet());
        assert!(r.is_err());
    }

    #[test]
    fn lp_plan_halves_all_reduces_in_window() {
        let Some(m) = build(|n| transform::pair_parallel(n, 0, 12, true)) else { return };
        assert_eq!(m.effective_depth(), 6);
        assert_eq!(m.all_reduces_per_token(), 12); // vs 24 sequential
    }

    #[test]
    fn prefill_then_decode_produces_finite_logits_and_counts_syncs() {
        let Some(m) = build(|n| transform::pair_parallel(n, 4, 10, true)) else { return };
        let prompt: Vec<i32> = "the red fox".bytes().map(|b| b as i32).collect();
        let logits = m.prefill(0, &prompt).unwrap();
        assert_eq!(logits.len(), m.entry.config.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));

        m.mesh.metrics.reset();
        let s = m.entry.config.slots;
        let mut tokens = vec![0i32; s];
        let mut pos = vec![0i32; s];
        tokens[0] = crate::tensor::argmax(&logits) as i32;
        pos[0] = prompt.len() as i32;
        let out = m.decode_step(&tokens, &pos).unwrap();
        assert_eq!(out.len(), s * m.entry.config.vocab);
        assert!(out.iter().all(|x| x.is_finite()));
        let (sync_ops, _, _, _) = m.mesh.metrics.snapshot();
        assert_eq!(sync_ops as usize, m.all_reduces_per_token());
    }
}
