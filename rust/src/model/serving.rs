//! Serving executor: the paper's §4 tensor-parallel deployment, with LP
//! pairs as a first-class stage kind and a **plan-variant registry** so one
//! resident weight set serves several computational graphs concurrently.
//!
//! Layout over a 2-rank mesh (paper's setup — one accelerator per LP path):
//!
//! * `Tp(i)` stage — classic Megatron sharding: each rank holds half the
//!   heads of layer i (and half the FFN hidden), computes a low-rank
//!   partial, and the pair of partials is **all-reduced twice per layer**
//!   (after attention, after FFN).
//! * `Lp(a, b)` stage — the paper's transform: rank 0 holds *all* of layer
//!   a, rank 1 all of layer b. One all-reduce combines `A_a(x) + A_b(x)`
//!   into the shared residual m, one more combines `F_a(m) + F_b(m)` —
//!   **two all-reduces per layer pair**, i.e. half of sequential TP.
//!
//! ## Plan-variant registry (per-request depth tiers)
//!
//! The paper's point is that one checkpoint supports many computational
//! graphs trading accuracy for speed. [`ServingModel`] therefore no longer
//! hard-wires a single [`GraphPlan`]: it holds a [`VariantId`]-keyed
//! registry of [`PlanVariant`]s — each a stage walk with its own
//! [`BucketSet`], flop/byte model and KV caches — built either from an
//! explicit plan list ([`ServingModel::with_variants`]; the single-plan
//! [`ServingModel::new`] wraps it with one variant named `plan`) or from
//! the manifest's `variants` section ([`ServingModel::from_manifest`]:
//! `dense`, `lp`, `lp_aggr`, default tier `dense`).
//!
//! One weight set, many graphs: weights are uploaded once, keyed by layer
//! and sharding form (`l{i}.tp.*` = this rank's Megatron shard,
//! `l{i}.full.*` = the full-width copy an LP stage binds), and every
//! variant's stage walk references the same resident buffers. KV caches
//! are per-variant (`kv.{tier}.{k,v}.{sidx}` — stage widths differ across
//! tiers) but share the slot dimension, so slots of different tiers
//! coexist and the scheduler batches each decode round per tier.
//! Executables are plan-agnostic (weights arrive as arguments), so all
//! variants share one lazily-filled [`ExecCache`]: each dispatch path
//! ensures exactly the keys it binds, compiling on first use and — under
//! the `[runtime] max_cached_execs` cap — evicting least-recently-used
//! executables, which transparently recompile on their next use.
//!
//! The cost model is charged per variant: a tier's decode round bills
//! `shape ·` [`PlanVariant::decode_flops_per_lane`] and pays one α–β
//! charge per stage reduce, so modelled tokens/sec reflects each tier's
//! `effective_depth()` / `all_reduces_per_token()` — the speed/quality
//! dial `bench_decode`'s tier sweep and `table3_profile` report.
//!
//! ## Resident-activation protocol
//!
//! The activation never round-trips through the host between stages. Each
//! token enters the mesh once (token ids + positions uploaded, counted in
//! [`crate::parallel::MeshMetrics::host_transfers`]) and leaves once
//! (logits fetched on rank 0). In between, stages chain the named resident
//! buffer `act`:
//!
//! 1. embed on rank 0, fan the embedding out to every rank as `act`
//!    (device-to-device broadcast, not host traffic);
//! 2. each stage half executes with `ArgRef::Resident("act")` as input and
//!    persists its partial as `act.partial` on its own rank — nothing is
//!    fetched;
//! 3. [`Mesh::reduce_into`] gathers the per-rank partials, sums them into
//!    the coordinator's shadow copy of the residual stream, and scatters
//!    the combined activation back into `act` on every rank — one sync op
//!    and one α–β charge, exactly like the value-level all-reduce it
//!    replaces (2 per stage, `all_reduces_per_token` unchanged);
//! 4. logits read `act` on rank 0 — the single device→host edge.
//!
//! The pre-refactor host-round-trip implementation is kept as
//! [`ServingModel::decode_step_host_reference`]: it is the bit-exactness
//! oracle for the resident path (same executables, same reduction order,
//! same floats) and the baseline `bench_decode` reports against.
//!
//! ## Shape-bucket dispatch
//!
//! Decode rounds are dispatched at the granularity the hardware executes:
//! [`ServingModel::decode_active_v`] asks the variant's [`BucketSet`] for
//! the smallest batch bucket B ∈ `batch_buckets` covering the live-lane
//! count and runs the per-bucket executables
//! (`{tp,lp}attn_decode_b{B}`, …), so device compute, the α–β-charged
//! all-reduce payload and the `[B, V]` logits download all scale with
//! occupancy instead of the slot count. Lane i serves slot `lanes[i]`; the
//! full `[S, C, w]` KV caches stay resident and the bucket executables
//! gather/scatter only the addressed rows. Pad lanes (live < B) duplicate
//! the first live lane — an idempotent recomputation that rewrites the
//! same cache row with identical bits, so padding never touches any other
//! slot's state. Rounds with no covering bucket (legacy manifest,
//! occupancy above a truncated registry) fall back to the fixed-`[S]`
//! [`ServingModel::decode_step`]; both paths are bit-identical per row
//! because the AOT side lowers the same per-lane HLO for every batch
//! width.
//!
//! ## Chunked streaming prefill
//!
//! The serving hot path no longer pads a prompt to the covering fixed-`T`
//! bucket: [`ServingModel::begin_prefill`] / [`ServingModel::prefill_step`]
//! (in [`crate::model::prefill`]) consume the prompt in fixed-`K` chunk
//! steps against the live KV caches, so modelled compute and the α–β
//! payload scale with `ceil(L / K)` and the scheduler can interleave
//! decode rounds between chunks. [`ServingModel::prefill`] keeps the
//! monolithic fixed-`T` pass as the bit-exactness oracle and the
//! legacy-manifest fallback. Admission validates BOTH bounds up front via
//! [`ServingModel::check_admission`]; the tier itself is validated by
//! [`ServingModel::resolve_tier`] (an unknown tier is rejected before any
//! slot is claimed).
//!
//! KV caches live as named resident buffers on the owning rank(s); decode
//! carries them in/out of the layer executables (see worker.rs for the
//! tuple-output caveat).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use crate::config::InterconnectConfig;
use crate::error::{Error, Result};
use crate::model::kvcache::{KvStats, PageWidth, PagedKv};
use crate::model::plan::{GraphPlan, Stage};
use crate::model::weights::Weights;
use crate::parallel::worker::ArgRef;
use crate::parallel::Mesh;
use crate::runtime::buckets::{
    decode_bytes, decode_flops_per_lane, BucketChoice, BucketSet, ExecCache,
};
use crate::runtime::pjrt::HostValue;
use crate::runtime::{Manifest, ModelEntry, VariantId};
use crate::tensor::add_slices;
use crate::verify::{DispatchTrace, RankIo, TraceOp};

/// Serving-mode stage (subset of [`Stage`] that the TP runtime supports).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeStage {
    Tp(usize),
    Lp(usize, usize),
}

/// One active slot's decode input: (slot index, token to feed, position).
pub type ActiveSlot = (usize, i32, i32);

/// Mesh ranks the serving executor spans — the paper's 2-accelerator
/// deployment, one LP path per rank.
pub const SERVE_RANKS: usize = 2;

/// Per-layer attention weight fields, in executable binding order.
pub const ATTN_FIELDS: [&str; 5] = ["ln1", "wq", "wk", "wv", "wo"];

/// Per-layer FFN weight fields, in executable binding order.
pub const FFN_FIELDS: [&str; 4] = ["ln2", "wg", "wu", "wd"];

/// Lower a [`GraphPlan`] to the serve-time stage walk (Seq → Tp, PairLp →
/// Lp). Other stage kinds are a scoring-only feature and rejected — the
/// error the caller prefixes with its variant id.
pub fn serve_stages(plan: &GraphPlan) -> Result<Vec<ServeStage>> {
    plan.stages
        .iter()
        .map(|st| match st {
            Stage::Seq(i) => Ok(ServeStage::Tp(*i)),
            Stage::PairLp(a, b) => Ok(ServeStage::Lp(*a, *b)),
            other => Err(Error::Serving(format!(
                "stage {other} not servable under TP (scoring only)"
            ))),
        })
        .collect()
}

fn stages_have_tp(stages: &[ServeStage]) -> bool {
    stages.iter().any(|s| matches!(s, ServeStage::Tp(_)))
}

fn stages_have_lp(stages: &[ServeStage]) -> bool {
    stages.iter().any(|s| matches!(s, ServeStage::Lp(..)))
}

/// Fixed-shape decode executable keys a stage walk binds (`suffix` = ""
/// for the full-`[S]` path, `_b{B}` for a batch bucket). Walks without Lp
/// stages never touch the `lp*` family and vice versa — the "reuse shared
/// kernels where shapes agree" half of the registry.
pub fn decode_exec_keys(stages: &[ServeStage], suffix: &str) -> Vec<String> {
    let mut keys = vec![format!("embed_decode{suffix}"), format!("logits_decode{suffix}")];
    if stages_have_tp(stages) {
        keys.push(format!("tpattn_decode{suffix}"));
        keys.push(format!("tpffn_decode{suffix}"));
    }
    if stages_have_lp(stages) {
        keys.push(format!("lpattn_decode{suffix}"));
        keys.push(format!("lpffn_decode{suffix}"));
    }
    keys
}

/// Monolithic fixed-`T` prefill executable keys a stage walk binds.
pub fn prefill_exec_keys(stages: &[ServeStage], t: usize) -> Vec<String> {
    let mut keys = vec![format!("embed_t{t}"), format!("logits_t{t}")];
    if stages_have_tp(stages) {
        keys.push(format!("tpattn_prefill_t{t}"));
        keys.push(format!("tpffn_prefill_t{t}"));
        keys.push(format!("cache_insert_half_t{t}"));
    }
    if stages_have_lp(stages) {
        keys.push(format!("lpattn_prefill_t{t}"));
        keys.push(format!("ffn_t{t}")); // LP FFN prefill (full width)
        keys.push(format!("cache_insert_full_t{t}"));
    }
    keys
}

/// Chunk-prefill executable keys a stage walk binds (see
/// [`crate::model::prefill`]).
pub fn chunk_exec_keys(stages: &[ServeStage]) -> Vec<String> {
    let mut keys = vec!["embed_chunk".to_string(), "logits_chunk".to_string()];
    if stages_have_tp(stages) {
        keys.push("tpattn_chunk".to_string());
        keys.push("tpffn_chunk".to_string());
    }
    if stages_have_lp(stages) {
        keys.push("lpattn_chunk".to_string());
        keys.push("lpffn_chunk".to_string());
    }
    keys
}

/// Paged-decode executable keys a stage walk binds for batch bucket `b`:
/// the attention kernels swap to the `*_decode_paged_b{B}` family (pool +
/// page-table operands instead of the per-variant `[S, C, w]` cache);
/// embed/FFN/logits shapes are cache-free and reuse the dense bucketed
/// executables unchanged.
pub fn paged_decode_exec_keys(stages: &[ServeStage], b: usize) -> Vec<String> {
    let mut keys = vec![format!("embed_decode_b{b}"), format!("logits_decode_b{b}")];
    if stages_have_tp(stages) {
        keys.push(format!("tpattn_decode_paged_b{b}"));
        keys.push(format!("tpffn_decode_b{b}"));
    }
    if stages_have_lp(stages) {
        keys.push(format!("lpattn_decode_paged_b{b}"));
        keys.push(format!("lpffn_decode_b{b}"));
    }
    keys
}

/// Paged chunk-prefill executable keys a stage walk binds (the paged
/// counterpart of [`chunk_exec_keys`]; embed/FFN/logits chunk executables
/// are shared with the dense path).
pub fn paged_chunk_exec_keys(stages: &[ServeStage]) -> Vec<String> {
    let mut keys = vec!["embed_chunk".to_string(), "logits_chunk".to_string()];
    if stages_have_tp(stages) {
        keys.push("tpattn_chunk_paged".to_string());
        keys.push("tpffn_chunk".to_string());
    }
    if stages_have_lp(stages) {
        keys.push("lpattn_chunk_paged".to_string());
        keys.push("lpffn_chunk".to_string());
    }
    keys
}

/// The resident-buffer names of one stage's weights on `rank`: a Tp stage
/// binds the rank's Megatron shard of its layer (`l{i}.tp.*`), an Lp stage
/// the full width of the rank's layer of the pair (`l{a|b}.full.*`).
/// Constructed through [`crate::runtime::keys`] — the schema the loader,
/// the dispatch paths and `verify::binding_check` all share.
pub fn stage_weight_names(stage: &ServeStage, rank: usize, fields: &[&str]) -> Vec<String> {
    let (layer, form) = match stage {
        ServeStage::Tp(i) => (*i, "tp"),
        ServeStage::Lp(a, b) => (if rank == 0 { *a } else { *b }, "full"),
    };
    fields.iter().map(|f| crate::runtime::keys::weight(layer, form, f)).collect()
}

/// [`stage_weight_names`] as executable arguments.
pub fn stage_weight_args(stage: &ServeStage, rank: usize, fields: &[&str]) -> Vec<ArgRef> {
    stage_weight_names(stage, rank, fields).into_iter().map(ArgRef::Resident).collect()
}

/// Resident KV-cache buffer name of one variant stage (`kv` ∈ {k, v}) —
/// [`crate::runtime::keys::kv_cache`] under the serving module's
/// traditional name.
pub fn cache_name(vid: &VariantId, kv: &str, sidx: usize) -> String {
    crate::runtime::keys::kv_cache(vid, kv, sidx)
}

/// The per-rank resident-buffer sets `upload_weights` + `init_caches`
/// establish for a set of plan variants — the initial abstract state of
/// [`crate::verify::binding_check`] and the ground truth
/// [`ServingModel::static_residents`] exposes.
pub fn initial_resident_names(
    variants: &[(VariantId, Vec<ServeStage>)],
    ranks: usize,
) -> Vec<BTreeSet<String>> {
    let mut sets: Vec<BTreeSet<String>> = vec![BTreeSet::new(); ranks];
    // rank 0 additionally owns embedding + head
    for name in crate::runtime::keys::HEAD_WEIGHT_KEYS {
        sets[0].insert(name.to_string());
    }
    let fields: Vec<&str> = ATTN_FIELDS.iter().chain(FFN_FIELDS.iter()).copied().collect();
    for (vid, stages) in variants {
        for (sidx, stage) in stages.iter().enumerate() {
            for (rank, set) in sets.iter_mut().enumerate() {
                match stage {
                    // every rank holds its shard of a Tp layer; an Lp rank
                    // holds the full width of its own layer of the pair
                    ServeStage::Tp(_) | ServeStage::Lp(..) => {
                        set.extend(stage_weight_names(stage, rank, &fields));
                    }
                }
                set.insert(cache_name(vid, "k", sidx));
                set.insert(cache_name(vid, "v", sidx));
            }
        }
    }
    sets
}

/// Emit the abstract dispatch trace of one decode round — the same op
/// sequence [`ServingModel::decode_step_shaped`] issues, with every
/// `ArgRef::Resident` binding named per rank (`suffix` / `lanes` select
/// the fixed-`[S]` or bucketed path). Kept next to the dispatch body it
/// mirrors; [`crate::verify::crosscheck_trace`] pins the two together.
pub fn decode_trace(
    vid: &VariantId,
    stages: &[ServeStage],
    ranks: usize,
    d_model: usize,
    shape: usize,
    suffix: &str,
    lanes: bool,
) -> DispatchTrace {
    let elems = shape * d_model;
    let mut ops = vec![
        TraceOp::EnsureExecs { keys: decode_exec_keys(stages, suffix) },
        TraceOp::UploadAll { name: "pos".into() },
    ];
    if lanes {
        ops.push(TraceOp::UploadAll { name: "lanes".into() });
    }
    ops.push(TraceOp::ExecRank {
        rank: 0,
        key: format!("embed_decode{suffix}"),
        reads: vec!["emb".into()],
        writes: vec![],
    });
    ops.push(TraceOp::BroadcastResident { name: "act".into(), elems });
    for (sidx, stage) in stages.iter().enumerate() {
        let (attn_base, ffn_base) = match stage {
            ServeStage::Tp(_) => ("tpattn_decode", "tpffn_decode"),
            ServeStage::Lp(..) => ("lpattn_decode", "lpffn_decode"),
        };
        let kname = cache_name(vid, "k", sidx);
        let vname = cache_name(vid, "v", sidx);
        ops.push(TraceOp::ExecAll {
            key: format!("{attn_base}{suffix}"),
            per_rank: (0..ranks)
                .map(|rank| {
                    let mut reads = vec!["act".to_string()];
                    reads.extend(stage_weight_names(stage, rank, &ATTN_FIELDS));
                    reads.push(kname.clone());
                    reads.push(vname.clone());
                    reads.push("pos".into());
                    if lanes {
                        reads.push("lanes".into());
                    }
                    RankIo {
                        reads,
                        writes: vec!["act.partial".into(), kname.clone(), vname.clone()],
                    }
                })
                .collect(),
        });
        ops.push(TraceOp::ReduceInto {
            partial: "act.partial".into(),
            dest: "act".into(),
            elems,
        });
        ops.push(TraceOp::ExecAll {
            key: format!("{ffn_base}{suffix}"),
            per_rank: (0..ranks)
                .map(|rank| {
                    let mut reads = vec!["act".to_string()];
                    reads.extend(stage_weight_names(stage, rank, &FFN_FIELDS));
                    RankIo { reads, writes: vec!["act.partial".into()] }
                })
                .collect(),
        });
        ops.push(TraceOp::ReduceInto {
            partial: "act.partial".into(),
            dest: "act".into(),
            elems,
        });
    }
    ops.push(TraceOp::ExecRank {
        rank: 0,
        key: format!("logits_decode{suffix}"),
        reads: vec!["act".into(), "lnf".into(), "wout".into()],
        writes: vec![],
    });
    DispatchTrace { label: format!("decode[{vid}]{suffix}@{shape}"), ranks, ops }
}

/// Emit the abstract dispatch trace of one monolithic fixed-`T` prefill
/// pass — the op sequence [`ServingModel::prefill_v`] issues, including
/// the per-stage KV-stripe insert pair.
pub fn prefill_trace(
    vid: &VariantId,
    stages: &[ServeStage],
    ranks: usize,
    d_model: usize,
    t: usize,
) -> DispatchTrace {
    let elems = t * d_model;
    let mut ops = vec![
        TraceOp::EnsureExecs { keys: prefill_exec_keys(stages, t) },
        TraceOp::UploadAll { name: "slot".into() },
        TraceOp::ExecRank {
            rank: 0,
            key: format!("embed_t{t}"),
            reads: vec!["emb".into()],
            writes: vec![],
        },
        TraceOp::BroadcastResident { name: "act".into(), elems },
    ];
    for (sidx, stage) in stages.iter().enumerate() {
        let (attn_key, insert_key, ffn_key) = match stage {
            ServeStage::Tp(_) => (
                format!("tpattn_prefill_t{t}"),
                format!("cache_insert_half_t{t}"),
                format!("tpffn_prefill_t{t}"),
            ),
            ServeStage::Lp(..) => (
                format!("lpattn_prefill_t{t}"),
                format!("cache_insert_full_t{t}"),
                format!("ffn_t{t}"),
            ),
        };
        ops.push(TraceOp::ExecAll {
            key: attn_key,
            per_rank: (0..ranks)
                .map(|rank| {
                    let mut reads = vec!["act".to_string()];
                    reads.extend(stage_weight_names(stage, rank, &ATTN_FIELDS));
                    RankIo {
                        reads,
                        writes: vec!["act.partial".into(), "tmp.k".into(), "tmp.v".into()],
                    }
                })
                .collect(),
        });
        ops.push(TraceOp::ReduceInto {
            partial: "act.partial".into(),
            dest: "act".into(),
            elems,
        });
        for (stripe, kv) in [("tmp.k", "k"), ("tmp.v", "v")] {
            let cache = cache_name(vid, kv, sidx);
            ops.push(TraceOp::ExecAll {
                key: insert_key.clone(),
                per_rank: (0..ranks)
                    .map(|_| RankIo {
                        reads: vec![cache.clone(), stripe.to_string(), "slot".into()],
                        writes: vec![cache.clone()],
                    })
                    .collect(),
            });
        }
        ops.push(TraceOp::ExecAll {
            key: ffn_key,
            per_rank: (0..ranks)
                .map(|rank| {
                    let mut reads = vec!["act".to_string()];
                    reads.extend(stage_weight_names(stage, rank, &FFN_FIELDS));
                    RankIo { reads, writes: vec!["act.partial".into()] }
                })
                .collect(),
        });
        ops.push(TraceOp::ReduceInto {
            partial: "act.partial".into(),
            dest: "act".into(),
            elems,
        });
    }
    ops.push(TraceOp::ExecRank {
        rank: 0,
        key: format!("logits_t{t}"),
        reads: vec!["act".into(), "lnf".into(), "wout".into()],
        writes: vec![],
    });
    DispatchTrace { label: format!("prefill[{vid}]@t{t}"), ranks, ops }
}

/// One registered plan variant: the stage walk of a serving tier plus its
/// per-tier bucket registry and cost-model constants. All variants of a
/// [`ServingModel`] execute over the same resident weight set and share
/// the compiled-executable pool; what differs is which stages they walk —
/// and therefore their effective depth, all-reduce count and modelled
/// device time per token.
#[derive(Debug)]
pub struct PlanVariant {
    pub id: VariantId,
    pub stages: Vec<ServeStage>,
    /// Decode batch-bucket registry (selection + live/padded stats are
    /// per-tier; the executables themselves are shared via the model's
    /// [`ExecCache`]).
    pub bucket_set: BucketSet,
    /// Modelled device compute of one decode lane through this plan.
    flops_per_lane: u64,
    /// Whole-layer equivalents of the plan (Tp = 1, Lp = 2) — the depth
    /// scale of the modelled prefill/decode flop charges.
    pub(crate) layers_equiv: usize,
}

impl PlanVariant {
    fn from_plan(id: VariantId, plan: &GraphPlan, entry: &ModelEntry) -> Result<PlanVariant> {
        plan.validate()
            .map_err(|e| Error::Serving(format!("variant `{id}`: bad plan: {e}")))?;
        let stages = serve_stages(plan).map_err(|e| match e {
            Error::Serving(msg) => Error::Serving(format!("variant `{id}`: {msg}")),
            other => other,
        })?;
        // Register only buckets whose executables all exist (guards a
        // manifest listing shapes it never emitted).
        let usable: Vec<usize> = entry
            .batch_buckets
            .iter()
            .copied()
            .filter(|&b| {
                BucketSet::artifact_keys(b)
                    .iter()
                    .all(|k| entry.artifacts.contains_key(k))
            })
            .collect();
        let bucket_set = BucketSet::new(&usable, entry.config.slots);
        // Tp stages split one layer across the mesh; Lp stages run two
        // whole layers in parallel — twice the device compute per stage.
        let layers_equiv = stages
            .iter()
            .map(|s| match s {
                ServeStage::Tp(_) => 1,
                ServeStage::Lp(..) => 2,
            })
            .sum();
        let flops_per_lane = decode_flops_per_lane(&entry.config, layers_equiv);
        Ok(PlanVariant { id, stages, bucket_set, flops_per_lane, layers_equiv })
    }

    /// Effective depth of this tier's plan (stage count).
    pub fn effective_depth(&self) -> usize {
        self.stages.len()
    }

    /// All-reduce operations per decode token: 2 per stage.
    pub fn all_reduces_per_token(&self) -> usize {
        self.stages.len() * 2
    }

    /// Modelled device compute one decode lane pays per token under this
    /// tier (see [`crate::runtime::buckets::decode_flops_per_lane`]).
    pub fn decode_flops_per_lane(&self) -> u64 {
        self.flops_per_lane
    }

}

pub struct ServingModel {
    pub mesh: Mesh,
    pub entry: ModelEntry,
    /// The plan-variant registry, keyed by tier name.
    variants: BTreeMap<VariantId, PlanVariant>,
    /// Tier served when a request names none (`dense` on manifest builds).
    default_id: VariantId,
    /// Prefill sequence-length buckets (manifest `seq_buckets`).
    pub buckets: Vec<usize>,
    /// Streaming-prefill chunk size K (manifest `prefill_chunk`; `None`
    /// for legacy manifests — prefill then runs the monolithic path).
    pub(crate) prefill_chunk: Option<usize>,
    /// Compiled-executable pool shared by every variant (lazy compile +
    /// LRU eviction under `[runtime] max_cached_execs`).
    exec_cache: ExecCache,
    /// Paged-KV state, present once [`ServingModel::enable_paging`] ran
    /// (opt-in: the default dense `[S, C, w]` caches stay authoritative
    /// otherwise). Behind a mutex because dispatch methods take `&self`.
    pub(crate) paged: Option<Mutex<PagedKv>>,
    pub(crate) ranks: usize,
}

impl ServingModel {
    /// Build a single-variant model from an explicit graph plan (Seq → Tp,
    /// PairLp → Lp; other stages are a scoring-only feature and rejected).
    /// The variant is registered under the tier name `plan`.
    pub fn new(
        manifest: &Manifest,
        model_name: &str,
        weights: &Weights,
        plan: &GraphPlan,
        net: InterconnectConfig,
    ) -> Result<ServingModel> {
        Self::new_with_cost(
            manifest,
            model_name,
            weights,
            plan,
            crate::parallel::CostModel::from_net(net),
        )
    }

    /// Single-variant build with an explicit cost model (custom
    /// [`crate::config::DeviceProfile`], e.g. from `RunConfig::device`).
    pub fn new_with_cost(
        manifest: &Manifest,
        model_name: &str,
        weights: &Weights,
        plan: &GraphPlan,
        cost: crate::parallel::CostModel,
    ) -> Result<ServingModel> {
        Self::with_variants(
            manifest,
            model_name,
            weights,
            vec![(VariantId::new("plan"), plan.clone())],
            cost,
        )
    }

    /// Build every plan variant the manifest's `variants` section names —
    /// the registry behind per-request depth tiers. One resident weight
    /// set serves all of them; the default tier is `dense` when present
    /// (legacy manifests synthesize exactly that one variant).
    pub fn from_manifest(
        manifest: &Manifest,
        model_name: &str,
        weights: &Weights,
        net: InterconnectConfig,
    ) -> Result<ServingModel> {
        Self::from_manifest_with_cost(
            manifest,
            model_name,
            weights,
            crate::parallel::CostModel::from_net(net),
        )
    }

    /// [`ServingModel::from_manifest`] with an explicit cost model.
    pub fn from_manifest_with_cost(
        manifest: &Manifest,
        model_name: &str,
        weights: &Weights,
        cost: crate::parallel::CostModel,
    ) -> Result<ServingModel> {
        let entry = manifest.model(model_name)?;
        let n = entry.config.n_layers;
        let mut plans = Vec::new();
        for spec in entry.variants.values() {
            let plan = GraphPlan::from_stage_lists(n, &spec.stages)
                .map_err(|e| Error::Serving(format!("variant `{}`: {e}", spec.id)))?;
            plans.push((spec.id.clone(), plan));
        }
        Self::with_variants(manifest, model_name, weights, plans, cost)
    }

    /// The core constructor: register one [`PlanVariant`] per `(id, plan)`
    /// pair over one resident weight set. The default tier is `dense` when
    /// present, else the first pair's id. Executable *paths* are validated
    /// up front; compilation itself is lazy (first dispatch per key, via
    /// the shared [`ExecCache`]).
    pub fn with_variants(
        manifest: &Manifest,
        model_name: &str,
        weights: &Weights,
        plans: Vec<(VariantId, GraphPlan)>,
        cost: crate::parallel::CostModel,
    ) -> Result<ServingModel> {
        if plans.is_empty() {
            return Err(Error::Serving("at least one plan variant required".into()));
        }
        let entry = manifest.model(model_name)?.clone();
        let ranks = SERVE_RANKS;
        let mesh = Mesh::with_cost(ranks, cost);
        let default_id = plans
            .iter()
            .map(|(id, _)| id)
            .find(|id| **id == VariantId::dense())
            .unwrap_or(&plans[0].0)
            .clone();
        let mut variants = BTreeMap::new();
        for (id, plan) in &plans {
            let var = PlanVariant::from_plan(id.clone(), plan, &entry)?;
            if variants.insert(id.clone(), var).is_some() {
                return Err(Error::Serving(format!("duplicate variant id `{id}`")));
            }
        }
        // Chunked streaming prefill is available only when every chunk
        // executable exists (guards a manifest naming a chunk size it
        // never emitted artifacts for).
        let prefill_chunk = manifest.prefill_chunk.filter(|_| {
            crate::model::prefill::CHUNK_ARTIFACT_KEYS
                .iter()
                .all(|k| entry.artifacts.contains_key(*k))
        });
        let m = ServingModel {
            mesh,
            entry,
            variants,
            default_id,
            buckets: manifest.seq_buckets.clone(),
            prefill_chunk,
            exec_cache: ExecCache::new(None),
            paged: None,
            ranks,
        };
        m.validate_artifacts()?;
        m.upload_weights(weights)?;
        m.init_caches()?;
        Ok(m)
    }

    // ---- registry ----------------------------------------------------------

    /// Look up a tier; the error names the tiers this model does serve.
    pub fn variant(&self, id: &VariantId) -> Result<&PlanVariant> {
        self.variants.get(id).ok_or_else(|| {
            let have: Vec<&str> = self.variants.keys().map(|v| v.as_str()).collect();
            Error::UnknownTier { tier: id.to_string(), available: have.join(", ") }
        })
    }

    /// Registered tier ids, in [`VariantId`] order.
    pub fn variant_ids(&self) -> Vec<VariantId> {
        self.variants.keys().cloned().collect()
    }

    /// The tier served when a request names none.
    pub fn default_tier(&self) -> &VariantId {
        &self.default_id
    }

    pub fn default_variant(&self) -> &PlanVariant {
        &self.variants[&self.default_id]
    }

    /// Map a request's optional tier name to a [`VariantId`] — the
    /// admission-time half of tier validation (`None` = default tier; an
    /// unknown name is rejected before any slot is claimed).
    pub fn resolve_tier(&self, tier: Option<&str>) -> Result<VariantId> {
        match tier {
            None => Ok(self.default_id.clone()),
            Some(name) => {
                let id = VariantId::new(name);
                self.variant(&id)?;
                Ok(id)
            }
        }
    }

    /// The shared compiled-executable pool (stats: compiles/evictions).
    pub fn exec_cache(&self) -> &ExecCache {
        &self.exec_cache
    }

    /// Apply the `[runtime] max_cached_execs` knob (`None` = unbounded).
    pub fn set_exec_cache_cap(&self, cap: Option<usize>) {
        self.exec_cache.set_cap(cap);
    }

    // ---- default-tier conveniences (single-plan API, benches, tests) ------

    /// Modelled device compute one decode lane pays per token under the
    /// default tier.
    pub fn decode_flops_per_lane(&self) -> u64 {
        self.default_variant().flops_per_lane
    }

    /// Streaming-prefill chunk size, when the manifest carries the chunk
    /// executable family (see [`crate::model::prefill`]).
    pub fn prefill_chunk(&self) -> Option<usize> {
        self.prefill_chunk
    }

    /// Effective depth of the default tier's plan (stage count).
    pub fn effective_depth(&self) -> usize {
        self.default_variant().effective_depth()
    }

    /// All-reduce operations per decode token under the default tier.
    pub fn all_reduces_per_token(&self) -> usize {
        self.default_variant().all_reduces_per_token()
    }

    /// The default tier's stage walk.
    pub fn stages(&self) -> &[ServeStage] {
        &self.default_variant().stages
    }

    /// The default tier's decode bucket registry.
    pub fn bucket_set(&self) -> &BucketSet {
        &self.default_variant().bucket_set
    }

    // ---- executables / weights / caches ------------------------------------

    pub(crate) fn art(&self, name: &str) -> Result<&std::path::Path> {
        Ok(self.entry.artifact(name)?.file.as_path())
    }

    /// Compile-or-touch `keys` through the shared [`ExecCache`] (lazy
    /// per-variant compile caching: every dispatch path calls this with
    /// exactly the keys it is about to bind, so an evicted executable
    /// transparently recompiles on its next use).
    pub(crate) fn ensure_execs(&self, keys: &[String]) -> Result<()> {
        self.exec_cache.ensure(
            keys,
            |k| self.mesh.compile_all(k, self.art(k)?),
            |k| self.mesh.release_all(k),
        )
    }

    /// Every executable each variant can bind must exist in the manifest —
    /// checked at build time so a broken manifest fails construction, not a
    /// live decode round (compilation itself stays lazy).
    fn validate_artifacts(&self) -> Result<()> {
        for var in self.variants.values() {
            for key in decode_exec_keys(&var.stages, "") {
                self.entry.artifact(&key)?;
            }
            for &t in &self.buckets {
                for key in prefill_exec_keys(&var.stages, t) {
                    self.entry.artifact(&key)?;
                }
            }
            if self.prefill_chunk.is_some() {
                for key in chunk_exec_keys(&var.stages) {
                    self.entry.artifact(&key)?;
                }
            }
        }
        Ok(())
    }

    /// Upload the single resident weight set, keyed by layer and sharding
    /// form instead of by plan position: `l{i}.tp.{field}` holds each
    /// rank's Megatron shard of layer i, `l{i}.full.{field}` the full-width
    /// copy on the rank(s) whose Lp stages run the layer. Every variant's
    /// stage walk references these shared buffers — no per-tier
    /// duplication, which is the point of the registry.
    fn upload_weights(&self, w: &Weights) -> Result<()> {
        // rank 0 additionally owns embedding + head
        self.mesh.workers[0].store("emb", w.get("emb")?.host())?;
        self.mesh.workers[0].store("lnf", w.get("lnf")?.host())?;
        self.mesh.workers[0].store("wout", w.get("wout")?.host())?;
        let mut tp_layers: BTreeSet<usize> = BTreeSet::new();
        let mut full_needs: BTreeSet<(usize, usize)> = BTreeSet::new(); // (rank, layer)
        for var in self.variants.values() {
            for st in &var.stages {
                match st {
                    ServeStage::Tp(i) => {
                        tp_layers.insert(*i);
                    }
                    ServeStage::Lp(a, b) => {
                        full_needs.insert((0, *a));
                        full_needs.insert((1, *b));
                    }
                }
            }
        }
        for &i in &tp_layers {
            for (rank, worker) in self.mesh.workers.iter().enumerate() {
                let attn = w.attn_shard(i, rank, self.ranks)?;
                for (t, field) in attn.iter().zip(ATTN_FIELDS) {
                    worker.store(&crate::runtime::keys::weight(i, "tp", field), t.host())?;
                }
                let ffn = w.ffn_shard(i, rank, self.ranks)?;
                for (t, field) in ffn.iter().zip(FFN_FIELDS) {
                    worker.store(&crate::runtime::keys::weight(i, "tp", field), t.host())?;
                }
            }
        }
        for &(rank, layer) in &full_needs {
            let worker = &self.mesh.workers[rank];
            let attn = w.attn_full(layer)?;
            for (t, field) in attn.iter().zip(ATTN_FIELDS) {
                worker.store(&crate::runtime::keys::weight(layer, "full", field), t.host())?;
            }
            let ffn = w.ffn_full(layer)?;
            for (t, field) in ffn.iter().zip(FFN_FIELDS) {
                worker.store(&crate::runtime::keys::weight(layer, "full", field), t.host())?;
            }
        }
        Ok(())
    }

    fn cache_width(&self, stage: &ServeStage) -> usize {
        match stage {
            ServeStage::Tp(_) => self.entry.config.d_model / self.ranks,
            ServeStage::Lp(..) => self.entry.config.d_model,
        }
    }

    fn init_caches(&self) -> Result<()> {
        let cfg = &self.entry.config;
        for var in self.variants.values() {
            for (sidx, stage) in var.stages.iter().enumerate() {
                let w = self.cache_width(stage);
                let zeros = HostValue::f32(
                    vec![cfg.slots, cfg.ctx, w],
                    vec![0.0; cfg.slots * cfg.ctx * w],
                );
                for worker in &self.mesh.workers {
                    worker.store(&cache_name(&var.id, "k", sidx), zeros.clone())?;
                    worker.store(&cache_name(&var.id, "v", sidx), zeros.clone())?;
                }
            }
        }
        Ok(())
    }

    // ---- static verification hooks -----------------------------------------

    /// The abstract dispatch trace of one decode round under tier `vid`
    /// (`bucket` = `None` for the fixed-`[S]` path, `Some(B)` for a batch
    /// bucket) — what this model's [`ServingModel::decode_step_v`] /
    /// bucketed dispatch will issue, op for op
    /// ([`crate::verify::crosscheck_trace`] holds the two together).
    pub fn static_decode_trace(
        &self,
        vid: &VariantId,
        bucket: Option<usize>,
    ) -> Result<DispatchTrace> {
        let var = self.variant(vid)?;
        let d = self.entry.config.d_model;
        Ok(match bucket {
            None => decode_trace(
                vid,
                &var.stages,
                self.ranks,
                d,
                self.entry.config.slots,
                "",
                false,
            ),
            Some(b) => {
                decode_trace(vid, &var.stages, self.ranks, d, b, &format!("_b{b}"), true)
            }
        })
    }

    /// The abstract dispatch trace of one chunk-prefill step under tier
    /// `vid` (`None` on legacy manifests without the chunk family).
    pub fn static_chunk_trace(
        &self,
        vid: &VariantId,
        last: bool,
    ) -> Result<Option<DispatchTrace>> {
        let var = self.variant(vid)?;
        Ok(self.prefill_chunk.map(|k| {
            crate::model::prefill::chunk_step_trace(
                vid,
                &var.stages,
                self.ranks,
                self.entry.config.d_model,
                k,
                last,
            )
        }))
    }

    /// The per-rank resident-buffer sets this model's construction
    /// establishes — the initial abstract state the binding checker
    /// interprets against (and a testable claim: every name here must be
    /// fetchable on the live mesh).
    pub fn static_residents(&self) -> Vec<BTreeSet<String>> {
        let variants: Vec<(VariantId, Vec<ServeStage>)> = self
            .variants
            .values()
            .map(|v| (v.id.clone(), v.stages.clone()))
            .collect();
        initial_resident_names(&variants, self.ranks)
    }

    // ---- admission ---------------------------------------------------------

    /// Longest admissible prompt: bounded by the KV context (one position
    /// must stay free for decode) and — on the monolithic fixed-`T` path —
    /// by the largest compiled seq bucket. The chunked streaming path has
    /// no bucket bound: any prompt that fits the cache is admissible.
    /// Tier-independent: every variant shares ctx and the prefill path.
    pub fn max_prompt_len(&self) -> usize {
        let ctx_cap = self.entry.config.ctx.saturating_sub(1);
        match self.prefill_chunk {
            Some(_) => ctx_cap,
            None => self.buckets.iter().copied().max().unwrap_or(0).min(ctx_cap),
        }
    }

    /// Validate a request against BOTH admission bounds — the prefill
    /// path's maximum prompt length and the ctx generation budget — before
    /// any slot is claimed. Pre-refactor these checks disagreed
    /// (`SlotManager::alloc` validated against ctx while `prefill`
    /// validated against the largest seq bucket), so an over-long prompt
    /// was admitted, allocated a slot, and only then errored; the scheduler
    /// now calls this (after [`ServingModel::resolve_tier`]) before
    /// dequeueing a request into a slot and returns one clear rejection.
    pub fn check_admission(&self, prompt_len: usize, max_new: usize) -> Result<()> {
        let ctx = self.entry.config.ctx;
        if prompt_len == 0 {
            return Err(Error::BadRequest("empty prompt (nothing to prefill)".into()));
        }
        let max_prompt = self.max_prompt_len();
        if prompt_len > max_prompt {
            let bound = match self.prefill_chunk {
                Some(_) => "the KV context (ctx - 1)".to_string(),
                None => format!("the largest prefill bucket and ctx {ctx}"),
            };
            return Err(Error::BadRequest(format!(
                "prompt of {prompt_len} tokens exceeds the admission limit \
                 {max_prompt} ({bound}) — shorten the prompt"
            )));
        }
        let cap = crate::model::kvcache::generation_capacity(ctx, prompt_len);
        if max_new > cap {
            return Err(Error::BadRequest(format!(
                "request wants {max_new} new tokens but a {prompt_len}-token \
                 prompt leaves room for only {cap} within ctx {ctx} — lower \
                 max_new_tokens or shorten the prompt"
            )));
        }
        Ok(())
    }

    // ---- paged KV cache ----------------------------------------------------

    /// Switch this model to paged KV serving (opt-in, idempotent): validate
    /// the manifest's `kv_pages` geometry and the paged executable family,
    /// upload the two zero-filled shared pools (`kvpool.{half,full}.{k,v}`,
    /// `[P, page, w]`, resident on every rank — pool *contents* are
    /// rank-local, exactly like the dense caches), and build the host-side
    /// [`PagedKv`] over every registered variant's stage widths.
    ///
    /// After this, chunked prefill and bucketed decode dispatch the paged
    /// attention executables against the pools; the dense per-variant
    /// caches stay resident but are no longer written, so the fixed-`[S]`
    /// decode fallback (no covering batch bucket) becomes an error instead
    /// of silently reading stale rows.
    pub fn enable_paging(&mut self) -> Result<()> {
        if self.paged.is_some() {
            return Ok(());
        }
        let kvp = self.entry.kv_pages.ok_or_else(|| {
            Error::Serving(
                "manifest has no kv_pages section — regenerate artifacts \
                 with a paged-aware AOT"
                    .into(),
            )
        })?;
        let k = self.prefill_chunk.ok_or_else(|| {
            Error::Serving(
                "paged serving requires the chunked-prefill executable family".into(),
            )
        })?;
        if kvp.page_tokens != k {
            return Err(Error::Serving(format!(
                "paged chunk executables cover one page per chunk step, but \
                 page_tokens {} != prefill_chunk {k}",
                kvp.page_tokens
            )));
        }
        // every paged executable each variant can bind must exist up front
        // (same fail-at-build contract as validate_artifacts)
        for var in self.variants.values() {
            for key in paged_chunk_exec_keys(&var.stages) {
                self.entry.artifact(&key)?;
            }
            for &b in var.bucket_set.buckets() {
                for key in paged_decode_exec_keys(&var.stages, b) {
                    self.entry.artifact(&key)?;
                }
            }
        }
        let cfg = &self.entry.config;
        for (width, pages, w) in [
            ("half", kvp.pool_pages_half, cfg.d_model / self.ranks),
            ("full", kvp.pool_pages_full, cfg.d_model),
        ] {
            let zeros =
                HostValue::f32(vec![pages, kvp.page_tokens, w], vec![0.0; pages * kvp.page_tokens * w]);
            for kv in ["k", "v"] {
                let name = crate::runtime::keys::kv_pool(width, kv);
                for worker in &self.mesh.workers {
                    worker.store(&name, zeros.clone())?;
                }
            }
        }
        let widths: Vec<(VariantId, Vec<PageWidth>)> = self
            .variants
            .values()
            .map(|v| {
                let ws = v
                    .stages
                    .iter()
                    .map(|s| match s {
                        ServeStage::Tp(_) => PageWidth::Half,
                        ServeStage::Lp(..) => PageWidth::Full,
                    })
                    .collect();
                (v.id.clone(), ws)
            })
            .collect();
        self.paged = Some(Mutex::new(PagedKv::new(&kvp, &widths, cfg.slots)));
        Ok(())
    }

    pub fn paging_enabled(&self) -> bool {
        self.paged.is_some()
    }

    pub(crate) fn paged_kv(&self) -> std::sync::MutexGuard<'_, PagedKv> {
        self.paged.as_ref().expect("paged dispatch without enable_paging").lock().unwrap()
    }

    /// Tier-aware admission: the dense bounds of
    /// [`ServingModel::check_admission`], plus — under paging — a page-pool
    /// feasibility check. Optimistic, vLLM-style: a request is rejected
    /// only when the pages its full `prompt + max_new` span needs can
    /// *never* fit the logical pools, before any slot churn; transient
    /// pressure is left to eviction.
    pub fn check_admission_v(
        &self,
        vid: &VariantId,
        prompt_len: usize,
        max_new: usize,
    ) -> Result<()> {
        self.variant(vid)?;
        self.check_admission(prompt_len, max_new)?;
        if let Some(pg) = &self.paged {
            let pg = pg.lock().unwrap();
            let k = pg.page_tokens();
            let blocks = (prompt_len + max_new).div_ceil(k).min(pg.blocks_per_slot());
            if !pg.fits(vid, blocks) {
                return Err(Error::Overloaded(format!(
                    "request needs {blocks} KV pages per paged stage under \
                     tier `{vid}` but the page pool can never hold them — \
                     lower max_new_tokens or raise the pool capacity"
                )));
            }
        }
        Ok(())
    }

    /// Admission back-pressure probe: `true` when a request that already
    /// passed [`ServingModel::check_admission_v`] must PARK because the
    /// page pools are transiently full (free + LRU-evictable pages cannot
    /// cover its span right now). Always `false` when paging is off. See
    /// [`PagedKv::available_now`] for the exact accounting.
    pub fn admission_must_wait_v(
        &self,
        vid: &VariantId,
        prompt_len: usize,
        max_new: usize,
    ) -> bool {
        let Some(pg) = &self.paged else { return false };
        let pg = pg.lock().unwrap();
        let k = pg.page_tokens();
        let blocks = (prompt_len + max_new).div_ceil(k).min(pg.blocks_per_slot());
        !pg.available_now(vid, blocks)
    }

    /// Release every page `slot` maps (no-op when paging is off). The
    /// scheduler calls this wherever it frees a slot; pages held by the
    /// shared-prefix index stay resident for future reuse.
    pub fn release_pages(&self, slot: usize) {
        if let Some(pg) = &self.paged {
            pg.lock().unwrap().release_slot(slot);
        }
    }

    /// Paged-KV counters (`None` when paging is off) — mirrored into
    /// `ServerMetrics` by the scheduler and exported in the snapshot.
    pub fn kv_stats(&self) -> Option<KvStats> {
        self.paged.as_ref().map(|pg| pg.lock().unwrap().stats())
    }

    /// Shrink the logical page pools (memory-pressure knob; no-op when
    /// paging is off). See [`PagedKv::set_page_capacity`].
    pub fn set_page_capacity(&self, pages: usize) {
        if let Some(pg) = &self.paged {
            pg.lock().unwrap().set_page_capacity(pages);
        }
    }

    // ---- prefill (monolithic fixed-T path) ---------------------------------

    /// Monolithic fixed-`T` prefill into the default tier's caches (see
    /// [`ServingModel::prefill_v`]).
    pub fn prefill(&self, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        self.prefill_v(&self.default_id, slot, tokens)
    }

    /// Monolithic fixed-`T` prefill of `tokens` into `slot` under tier
    /// `vid`: the whole prompt is padded to the smallest covering seq
    /// bucket and runs in one pass. Returns the logits row for the last
    /// real token ([V]) — the distribution of the first generated token.
    ///
    /// This is the bit-exactness oracle for (and the legacy-manifest
    /// fallback of) the chunked streaming path in [`crate::model::prefill`];
    /// the serving hot path goes through
    /// [`ServingModel::begin_prefill`] / [`ServingModel::prefill_step`].
    ///
    /// Resident protocol: token ids and the slot index are the only
    /// host→device uploads; the logits row is the only device→host fetch
    /// besides the embed shadow. Stages chain the resident `act` buffer.
    pub fn prefill_v(&self, vid: &VariantId, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let var = self.variant(vid)?;
        let cfg = &self.entry.config;
        if tokens.is_empty() {
            // guards the `tokens.len() - 1` logits-row read below — an
            // empty prompt used to underflow-panic in the scheduler thread
            return Err(Error::Serving("empty prompt (nothing to prefill)".into()));
        }
        let t = crate::text::tokenizer::bucket_for(tokens.len(), &self.buckets)
            .ok_or_else(|| Error::Serving(format!("prompt too long: {}", tokens.len())))?;
        self.ensure_execs(&prefill_exec_keys(&var.stages, t))?;
        let padded = crate::text::tokenizer::pad_to(tokens, t)?;
        let d = cfg.d_model;
        // modelled device compute: T padded tokens + the [T, V] logits
        // head, priced on the roofline with the matching memory traffic
        self.mesh.charge_compute(
            crate::runtime::buckets::prefill_flops(cfg, var.layers_equiv, 0, t, t),
            crate::runtime::buckets::prefill_bytes(cfg, var.layers_equiv, 0, t, t),
        );

        // slot index is fresh host data, referenced by every cache insert
        self.mesh.upload_all("slot", HostValue::scalar_i32(slot as i32))?;

        // rank 0: embed (the host→device edge), then fan out as `act`
        let mut shadow = self
            .mesh
            .exec_rank(
                0,
                &format!("embed_t{t}"),
                vec![
                    ArgRef::Host(HostValue::i32(vec![t], padded)),
                    ArgRef::Resident("emb".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        self.mesh
            .broadcast_resident("act", &HostValue::f32(vec![t, d], shadow.clone()))?;

        for (sidx, stage) in var.stages.iter().enumerate() {
            let (attn_key, ffn_key, insert_key) = match stage {
                ServeStage::Tp(_) => (
                    format!("tpattn_prefill_t{t}"),
                    format!("tpffn_prefill_t{t}"),
                    format!("cache_insert_half_t{t}"),
                ),
                ServeStage::Lp(..) => (
                    format!("lpattn_prefill_t{t}"),
                    format!("ffn_t{t}"),
                    format!("cache_insert_full_t{t}"),
                ),
            };
            // --- attention partials (device-resident) + KV stripes
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &ATTN_FIELDS));
                    (
                        attn_key.clone(),
                        args,
                        vec![
                            Some("act.partial".to_string()),
                            Some("tmp.k".to_string()),
                            Some("tmp.v".to_string()),
                        ],
                        vec![false, false, false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;

            // --- insert KV stripes into the slot (both ranks, k then v)
            for (stripe, kv) in [("tmp.k", "k"), ("tmp.v", "v")] {
                let cache = cache_name(vid, kv, sidx);
                let calls = (0..self.ranks)
                    .map(|_| {
                        (
                            insert_key.clone(),
                            vec![
                                ArgRef::Resident(cache.clone()),
                                ArgRef::Resident(stripe.to_string()),
                                ArgRef::Resident("slot".into()),
                            ],
                            vec![Some(cache.clone())],
                            vec![false],
                        )
                    })
                    .collect();
                self.mesh.exec_all(calls)?;
            }

            // --- FFN partials (device-resident)
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &FFN_FIELDS));
                    (ffn_key.clone(), args, vec![Some("act.partial".to_string())], vec![false])
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;
        }

        // rank 0: logits of the last real token (the device→host edge)
        let logits = self
            .mesh
            .exec_rank(
                0,
                &format!("logits_t{t}"),
                vec![
                    ArgRef::Resident("act".into()),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        let v = cfg.vocab;
        let last = tokens.len() - 1;
        Ok(logits[last * v..(last + 1) * v].to_vec())
    }

    // ---- decode ------------------------------------------------------------

    fn check_step_inputs(&self, tokens: &[i32], pos: &[i32]) -> Result<usize> {
        let s = self.entry.config.slots;
        if tokens.len() != s || pos.len() != s {
            return Err(Error::Serving(format!(
                "decode_step wants {s} slot tokens/positions"
            )));
        }
        Ok(s)
    }

    /// One decode step over all S device lanes of the default tier (see
    /// [`ServingModel::decode_step_v`]).
    pub fn decode_step(&self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        self.decode_step_v(&self.default_id, tokens, pos)
    }

    /// One decode step over all S device lanes under tier `vid`
    /// (resident-activation path). `tokens[s]` / `pos[s]` from the slot
    /// manager. Returns `[S, V]` logits (row-major). Host↔device traffic
    /// is O(1) in the stage count: token ids + positions in, logits out.
    pub fn decode_step_v(
        &self,
        vid: &VariantId,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<f32>> {
        let var = self.variant(vid)?;
        let s = self.check_step_inputs(tokens, pos)?;
        self.decode_step_shaped(var, s, "", tokens, pos, None)
    }

    /// The resident-activation decode body shared by the fixed-`[S]` path
    /// (`suffix = ""`) and the bucketed path (`suffix = "_b{B}"`, `lanes`
    /// present): embed on rank 0 → per stage, attention + FFN partials
    /// reduced into the `act` shadow → logits on rank 0. One body keeps the
    /// two paths in lockstep — the bit-exactness contract between them —
    /// and serves every variant (the stage walk, cache names and cost
    /// charges are the variant's own).
    fn decode_step_shaped(
        &self,
        var: &PlanVariant,
        shape: usize,
        suffix: &str,
        tokens: &[i32],
        pos: &[i32],
        lanes: Option<&[i32]>,
    ) -> Result<Vec<f32>> {
        let d = self.entry.config.d_model;
        self.ensure_execs(&decode_exec_keys(&var.stages, suffix))?;
        self.mesh.charge_compute(
            shape as u64 * var.flops_per_lane,
            decode_bytes(&self.entry.config, var.layers_equiv, shape),
        );

        // positions (and the bucketed path's lane→slot mapping) are fresh
        // host data each token, resident for the stages
        self.mesh.upload_all("pos", HostValue::i32(vec![shape], pos.to_vec()))?;
        if let Some(l) = lanes {
            self.mesh.upload_all("lanes", HostValue::i32(vec![shape], l.to_vec()))?;
        }

        // rank 0: embed (host→device edge), fan out as `act`
        let mut shadow = self
            .mesh
            .exec_rank(
                0,
                &format!("embed_decode{suffix}"),
                vec![
                    ArgRef::Host(HostValue::i32(vec![shape], tokens.to_vec())),
                    ArgRef::Resident("emb".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        self.mesh
            .broadcast_resident("act", &HostValue::f32(vec![shape, d], shadow.clone()))?;

        for (sidx, stage) in var.stages.iter().enumerate() {
            let (attn_base, ffn_base) = match stage {
                ServeStage::Tp(_) => ("tpattn_decode", "tpffn_decode"),
                ServeStage::Lp(..) => ("lpattn_decode", "lpffn_decode"),
            };
            let attn_key = format!("{attn_base}{suffix}");
            let ffn_key = format!("{ffn_base}{suffix}");
            let kname = cache_name(&var.id, "k", sidx);
            let vname = cache_name(&var.id, "v", sidx);
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &ATTN_FIELDS));
                    args.push(ArgRef::Resident(kname.clone()));
                    args.push(ArgRef::Resident(vname.clone()));
                    args.push(ArgRef::Resident("pos".into()));
                    if lanes.is_some() {
                        args.push(ArgRef::Resident("lanes".into()));
                    }
                    (
                        attn_key.clone(),
                        args,
                        vec![
                            Some("act.partial".to_string()),
                            Some(kname.clone()),
                            Some(vname.clone()),
                        ],
                        vec![false, false, false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;

            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &FFN_FIELDS));
                    (
                        ffn_key.clone(),
                        args,
                        vec![Some("act.partial".to_string())],
                        vec![false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;
        }

        // rank 0: logits (the device→host edge)
        self.mesh
            .exec_rank(
                0,
                &format!("logits_decode{suffix}"),
                vec![
                    ArgRef::Resident("act".into()),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()
    }

    /// [`ServingModel::decode_active_v`] on the default tier.
    pub fn decode_active(&self, active: &[ActiveSlot]) -> Result<Vec<(usize, Vec<f32>)>> {
        self.decode_active_v(&self.default_id, active)
    }

    /// One decode step over a *compacted* batch of active slots of tier
    /// `vid`, dispatched at bucket granularity: the smallest batch bucket B
    /// covering the live count is selected from the variant's
    /// [`BucketSet`] and the per-bucket executables run B compute lanes
    /// against the tier's full-`[S]` resident KV caches (lane i
    /// gathers/scatters slot `lanes[i]`'s row). Device compute, all-reduce
    /// payload and the `[B, V]` logits download are occupancy-proportional;
    /// rounds with no covering bucket fall back to the fixed-`[S]`
    /// [`ServingModel::decode_step_v`]. Both paths produce bit-identical
    /// rows (same per-lane HLO on the AOT side).
    ///
    /// Returns one `(slot, logits_row)` per input, in input order.
    pub fn decode_active_v(
        &self,
        vid: &VariantId,
        active: &[ActiveSlot],
    ) -> Result<Vec<(usize, Vec<f32>)>> {
        let var = self.variant(vid)?;
        let cfg = &self.entry.config;
        let s = cfg.slots;
        let v = cfg.vocab;
        for &(slot, _, _) in active {
            if slot >= s {
                return Err(Error::Serving(format!("decode_active: slot {slot} >= {s}")));
            }
        }
        if self.paged.is_some() {
            return self.decode_active_paged(var, active);
        }
        match var.bucket_set.select(active.len()) {
            BucketChoice::Skip => Ok(vec![]),
            BucketChoice::Full => {
                // Fixed-[S] executables: inactive lanes padded with benign
                // zeros; only the active rows are materialized for sampling.
                let mut tokens = vec![0i32; s];
                let mut pos = vec![0i32; s];
                for &(slot, tok, p) in active {
                    tokens[slot] = tok;
                    pos[slot] = p;
                }
                let logits = self.decode_step_shaped(var, s, "", &tokens, &pos, None)?;
                var.bucket_set.record(s, active.len());
                Ok(active
                    .iter()
                    .map(|&(slot, _, _)| (slot, logits[slot * v..(slot + 1) * v].to_vec()))
                    .collect())
            }
            BucketChoice::Bucket(b) => {
                let mut tokens = Vec::with_capacity(b);
                let mut pos = Vec::with_capacity(b);
                let mut lanes = Vec::with_capacity(b);
                for &(slot, tok, p) in active {
                    lanes.push(slot as i32);
                    tokens.push(tok);
                    pos.push(p);
                }
                // Pad lanes *duplicate* the first live lane: a duplicate
                // recomputes the identical per-lane step and rewrites the
                // same cache row with identical bits (sequential scatter,
                // same inputs), so padding is benign regardless of which
                // other slots are live — no liveness knowledge needed.
                let (slot0, tok0, pos0) = active[0];
                for _ in active.len()..b {
                    lanes.push(slot0 as i32);
                    tokens.push(tok0);
                    pos.push(pos0);
                }
                let logits = self.decode_step_shaped(
                    var,
                    b,
                    &format!("_b{b}"),
                    &tokens,
                    &pos,
                    Some(&lanes),
                )?;
                var.bucket_set.record(b, active.len());
                Ok(active
                    .iter()
                    .enumerate()
                    .map(|(i, &(slot, _, _))| (slot, logits[i * v..(i + 1) * v].to_vec()))
                    .collect())
            }
        }
    }

    /// Paged decode dispatch: like the bucketed arm of
    /// [`ServingModel::decode_active_v`], but the write position of every
    /// live lane is mapped (with copy-on-write forking of shared blocks)
    /// and the per-stage `[B, nb]` page-table operands are frozen under one
    /// lock before dispatch. Paged decode *requires* a covering batch
    /// bucket: the fixed-`[S]` fallback would read the dense caches paging
    /// no longer writes, so it errors instead of silently diverging.
    fn decode_active_paged(
        &self,
        var: &PlanVariant,
        active: &[ActiveSlot],
    ) -> Result<Vec<(usize, Vec<f32>)>> {
        let v = self.entry.config.vocab;
        match var.bucket_set.select(active.len()) {
            BucketChoice::Skip => Ok(vec![]),
            BucketChoice::Full => Err(Error::Serving(
                "paged decode needs a covering batch bucket — the fixed-[S] \
                 fallback reads the dense caches paging no longer writes"
                    .into(),
            )),
            BucketChoice::Bucket(b) => {
                let mut tokens = Vec::with_capacity(b);
                let mut pos = Vec::with_capacity(b);
                let mut lane_slots = Vec::with_capacity(b);
                for &(slot, tok, p) in active {
                    lane_slots.push(slot);
                    tokens.push(tok);
                    pos.push(p);
                }
                // pad lanes duplicate the first live lane, same as the dense
                // bucketed path: the duplicate scatters identical bits into
                // the same page, so padding stays benign
                let (slot0, tok0, pos0) = active[0];
                for _ in active.len()..b {
                    lane_slots.push(slot0);
                    tokens.push(tok0);
                    pos.push(pos0);
                }
                let pts: Vec<Vec<i32>> = {
                    let mut pg = self.paged_kv();
                    let k = pg.page_tokens();
                    for &(slot, _, p) in active {
                        pg.ensure_block(&var.id, slot, p as usize / k)?;
                    }
                    (0..var.stages.len())
                        .map(|sidx| {
                            lane_slots
                                .iter()
                                .flat_map(|&slot| {
                                    pg.page_table(&var.id, sidx, slot).to_vec()
                                })
                                .collect()
                        })
                        .collect()
                };
                let logits = self.decode_step_paged(var, b, &tokens, &pos, &pts)?;
                var.bucket_set.record(b, active.len());
                Ok(active
                    .iter()
                    .enumerate()
                    .map(|(i, &(slot, _, _))| (slot, logits[i * v..(i + 1) * v].to_vec()))
                    .collect())
            }
        }
    }

    /// The paged counterpart of [`ServingModel::decode_step_shaped`]
    /// (bucketed shape only): per stage the attention executable binds the
    /// width-matched pools plus `pos`/`pt` — the page table does the
    /// slot indirection, so there is no `lanes` operand. The page tables
    /// differ per stage, so `pt` is uploaded *inside* the stage loop:
    /// paged decode host traffic is O(stages), a cost the dense resident
    /// path doesn't pay (the price of pool indirection). Compute/bytes are
    /// charged exactly like the dense bucketed path — paging changes where
    /// KV rows live, not what a token costs.
    fn decode_step_paged(
        &self,
        var: &PlanVariant,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        pts: &[Vec<i32>],
    ) -> Result<Vec<f32>> {
        let d = self.entry.config.d_model;
        self.ensure_execs(&paged_decode_exec_keys(&var.stages, b))?;
        self.mesh.charge_compute(
            b as u64 * var.flops_per_lane,
            decode_bytes(&self.entry.config, var.layers_equiv, b),
        );
        self.mesh.upload_all("pos", HostValue::i32(vec![b], pos.to_vec()))?;

        let mut shadow = self
            .mesh
            .exec_rank(
                0,
                &format!("embed_decode_b{b}"),
                vec![
                    ArgRef::Host(HostValue::i32(vec![b], tokens.to_vec())),
                    ArgRef::Resident("emb".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;
        self.mesh
            .broadcast_resident("act", &HostValue::f32(vec![b, d], shadow.clone()))?;

        for (sidx, stage) in var.stages.iter().enumerate() {
            let (attn_base, ffn_base, width) = match stage {
                ServeStage::Tp(_) => ("tpattn_decode_paged", "tpffn_decode", "half"),
                ServeStage::Lp(..) => ("lpattn_decode_paged", "lpffn_decode", "full"),
            };
            let attn_key = format!("{attn_base}_b{b}");
            let ffn_key = format!("{ffn_base}_b{b}");
            let poolk = crate::runtime::keys::kv_pool(width, "k");
            let poolv = crate::runtime::keys::kv_pool(width, "v");
            let nb = pts[sidx].len() / b;
            self.mesh.upload_all("pt", HostValue::i32(vec![b, nb], pts[sidx].clone()))?;
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &ATTN_FIELDS));
                    args.push(ArgRef::Resident(poolk.clone()));
                    args.push(ArgRef::Resident(poolv.clone()));
                    args.push(ArgRef::Resident("pos".into()));
                    args.push(ArgRef::Resident("pt".into()));
                    (
                        attn_key.clone(),
                        args,
                        vec![
                            Some("act.partial".to_string()),
                            Some(poolk.clone()),
                            Some(poolv.clone()),
                        ],
                        vec![false, false, false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;

            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args = vec![ArgRef::Resident("act".into())];
                    args.extend(stage_weight_args(stage, rank, &FFN_FIELDS));
                    (
                        ffn_key.clone(),
                        args,
                        vec![Some("act.partial".to_string())],
                        vec![false],
                    )
                })
                .collect();
            self.mesh.exec_all(calls)?;
            self.mesh.reduce_into("act.partial", &mut shadow, "act")?;
        }

        self.mesh
            .exec_rank(
                0,
                &format!("logits_decode_b{b}"),
                vec![
                    ArgRef::Resident("act".into()),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()
    }

    /// Pre-refactor decode step over the default tier: uploads the
    /// activation to every rank as a fresh host value at each stage and
    /// pulls the partials back for a host-side sum — 2 host↔device
    /// round-trips per rank per stage.
    ///
    /// Kept as the bit-exactness oracle for [`ServingModel::decode_step`]
    /// (same executables, same reduction order ⇒ identical floats) and as
    /// the baseline `bench_decode` compares host-transfer counts against.
    pub fn decode_step_host_reference(&self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let var = self.default_variant();
        let cfg = &self.entry.config;
        let s = self.check_step_inputs(tokens, pos)?;
        let d = cfg.d_model;
        self.ensure_execs(&decode_exec_keys(&var.stages, ""))?;
        self.mesh.charge_compute(
            s as u64 * var.flops_per_lane,
            decode_bytes(cfg, var.layers_equiv, s),
        );
        let mut x = self
            .mesh
            .exec_rank(
                0,
                "embed_decode",
                vec![
                    ArgRef::Host(HostValue::i32(vec![s], tokens.to_vec())),
                    ArgRef::Resident("emb".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()?;

        for (sidx, stage) in var.stages.iter().enumerate() {
            let (attn_key, ffn_key) = match stage {
                ServeStage::Tp(_) => ("tpattn_decode", "tpffn_decode"),
                ServeStage::Lp(..) => ("lpattn_decode", "lpffn_decode"),
            };
            let kname = cache_name(&var.id, "k", sidx);
            let vname = cache_name(&var.id, "v", sidx);
            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args =
                        vec![ArgRef::Host(HostValue::f32(vec![s, d], x.clone()))];
                    args.extend(stage_weight_args(stage, rank, &ATTN_FIELDS));
                    args.push(ArgRef::Resident(kname.clone()));
                    args.push(ArgRef::Resident(vname.clone()));
                    args.push(ArgRef::Host(HostValue::i32(vec![s], pos.to_vec())));
                    (
                        attn_key.to_string(),
                        args,
                        vec![None, Some(kname.clone()), Some(vname.clone())],
                        vec![true, false, false],
                    )
                })
                .collect();
            let mut outs = self.mesh.exec_all(calls)?;
            let parts: Vec<HostValue> = outs.iter_mut().map(|o| o.remove(0)).collect();
            let reduced = self.mesh.all_reduce(parts)?;
            add_slices(&mut x, reduced.as_f32()?);

            let calls = (0..self.ranks)
                .map(|rank| {
                    let mut args =
                        vec![ArgRef::Host(HostValue::f32(vec![s, d], x.clone()))];
                    args.extend(stage_weight_args(stage, rank, &FFN_FIELDS));
                    (ffn_key.to_string(), args, vec![], vec![true])
                })
                .collect();
            let mut outs = self.mesh.exec_all(calls)?;
            let parts: Vec<HostValue> = outs.iter_mut().map(|o| o.remove(0)).collect();
            let reduced = self.mesh.all_reduce(parts)?;
            add_slices(&mut x, reduced.as_f32()?);
        }

        self.mesh
            .exec_rank(
                0,
                "logits_decode",
                vec![
                    ArgRef::Host(HostValue::f32(vec![s, d], x)),
                    ArgRef::Resident("lnf".into()),
                    ArgRef::Resident("wout".into()),
                ],
                vec![],
                vec![],
            )?
            .remove(0)
            .into_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transform;
    use crate::runtime::Manifest;

    fn quiet() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    fn build(plan_fn: impl Fn(usize) -> GraphPlan) -> Option<ServingModel> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        let weights = Weights::random(&cfg, 7);
        let plan = plan_fn(cfg.n_layers);
        ServingModel::new(&manifest, "td-small", &weights, &plan, quiet()).ok()
    }

    #[test]
    fn rejects_unservable_plans() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 7);
        let plan = transform::merge(cfg.n_layers, 2, 5);
        let r = ServingModel::new(&manifest, "td-small", &weights, &plan, quiet());
        assert!(r.is_err());
    }

    #[test]
    fn lp_plan_halves_all_reduces_in_window() {
        let Some(m) = build(|n| transform::pair_parallel(n, 0, 12, true)) else { return };
        assert_eq!(m.effective_depth(), 6);
        assert_eq!(m.all_reduces_per_token(), 12); // vs 24 sequential
    }

    #[test]
    fn prefill_then_decode_produces_finite_logits_and_counts_syncs() {
        let Some(m) = build(|n| transform::pair_parallel(n, 4, 10, true)) else { return };
        let prompt: Vec<i32> = "the red fox".bytes().map(|b| b as i32).collect();
        let logits = m.prefill(0, &prompt).unwrap();
        assert_eq!(logits.len(), m.entry.config.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));

        m.mesh.metrics.reset();
        let s = m.entry.config.slots;
        let mut tokens = vec![0i32; s];
        let mut pos = vec![0i32; s];
        tokens[0] = crate::tensor::argmax(&logits) as i32;
        pos[0] = prompt.len() as i32;
        let out = m.decode_step(&tokens, &pos).unwrap();
        assert_eq!(out.len(), s * m.entry.config.vocab);
        assert!(out.iter().all(|x| x.is_finite()));
        let (sync_ops, _, _, _) = m.mesh.metrics.snapshot();
        assert_eq!(sync_ops as usize, m.all_reduces_per_token());
    }

    /// The acceptance criterion in numbers: a decode token costs a constant
    /// number of host↔device transfers — token ids + positions in, the
    /// embed shadow and logits out — independent of the stage count.
    #[test]
    fn decode_host_transfers_are_constant_in_depth() {
        let mut per_plan = Vec::new();
        for (stages, planner) in [
            (12, Box::new(|n| transform::sequential(n)) as Box<dyn Fn(usize) -> GraphPlan>),
            (6, Box::new(|n| transform::pair_parallel(n, 0, 12, true))),
        ] {
            let Some(m) = build(&*planner) else { return };
            assert_eq!(m.effective_depth(), stages);
            let s = m.entry.config.slots;
            let prompt: Vec<i32> = "warm".bytes().map(|b| b as i32).collect();
            m.prefill(0, &prompt).unwrap();
            // warm once so lazy compiles are done before metering
            let mut tokens = vec![0i32; s];
            let mut pos = vec![0i32; s];
            tokens[0] = 65;
            pos[0] = prompt.len() as i32;
            m.decode_step(&tokens, &pos).unwrap();
            m.mesh.metrics.reset();
            m.decode_step(&tokens, &pos).unwrap();
            let h = m.mesh.metrics.host_transfers();
            // tokens upload + pos upload per rank; embed shadow + logits out
            assert_eq!(h.in_ops, 1 + m.mesh.ranks() as u64);
            assert_eq!(h.out_ops, 2);
            per_plan.push(h.ops());
        }
        assert_eq!(per_plan[0], per_plan[1], "host traffic must not scale with depth");
    }

    /// Acceptance criterion of the shape-bucket subsystem: a bucketed
    /// decode round on a mixed Tp/Lp plan is bit-identical to the
    /// full-batch path, and the modelled device compute + logits download
    /// scale with the dispatched bucket, not the slot count.
    #[test]
    fn bucketed_decode_bit_identical_and_occupancy_proportional() {
        let Some(m) = build(|n| transform::pair_parallel(n, 4, 10, true)) else { return };
        let cfg = m.entry.config.clone();
        if m.bucket_set().buckets().is_empty() {
            return; // legacy artifacts without batch buckets
        }
        let (s, v, d) = (cfg.slots, cfg.vocab, cfg.d_model);
        let pa: Vec<i32> = "the red fox".bytes().map(|b| b as i32).collect();
        let pb: Vec<i32> = "9 - 4 = ".bytes().map(|b| b as i32).collect();
        m.prefill(0, &pa).unwrap();
        m.prefill(2, &pb).unwrap();

        // 2 live slots on a 4-slot model → the B=2 bucket, non-contiguous lanes
        let active = vec![(0usize, 65i32, pa.len() as i32), (2usize, 66i32, pb.len() as i32)];
        m.mesh.metrics.reset();
        let rows = m.decode_active(&active).unwrap();
        let bucket_flops = m.mesh.metrics.modelled_flops();
        let bucket_out = m.mesh.metrics.host_transfers().out_bytes;
        let (bucket_sync, _, _, _) = m.mesh.metrics.snapshot();

        // same lanes through the fixed-[S] executables (idempotent KV writes:
        // same tokens at the same positions)
        let mut tok = vec![0i32; s];
        let mut pos = vec![0i32; s];
        tok[0] = 65;
        pos[0] = pa.len() as i32;
        tok[2] = 66;
        pos[2] = pb.len() as i32;
        m.mesh.metrics.reset();
        let full = m.decode_step(&tok, &pos).unwrap();
        let full_flops = m.mesh.metrics.modelled_flops();
        let full_out = m.mesh.metrics.host_transfers().out_bytes;

        assert_eq!(rows[0].0, 0);
        assert_eq!(rows[1].0, 2);
        assert_eq!(rows[0].1, full[..v].to_vec(), "slot 0 row diverged");
        assert_eq!(rows[1].1, full[2 * v..3 * v].to_vec(), "slot 2 row diverged");

        // device compute and downloads (embed shadow [B,D] + logits [B,V])
        // are billed at the bucket shape
        assert_eq!(bucket_flops, 2 * m.decode_flops_per_lane());
        assert_eq!(full_flops, s as u64 * m.decode_flops_per_lane());
        assert_eq!(bucket_out, (2 * (d + v) * 4) as u64);
        assert_eq!(full_out, (s * (d + v) * 4) as u64);
        // all-reduce accounting is unchanged: 2 per stage
        assert_eq!(bucket_sync as usize, m.all_reduces_per_token());

        let stats = m.bucket_set().stats();
        assert_eq!(
            stats,
            vec![(
                2,
                crate::runtime::BucketStats { rounds: 1, live_lanes: 2, padded_lanes: 0 }
            )]
        );
    }

    /// live < B: the pad lane (a duplicate of the first live lane) must
    /// not perturb any slot's output (bit-compared against the full-[S]
    /// path) nor any other slot's cache row.
    #[test]
    fn bucketed_decode_pad_lane_is_benign() {
        let Some(m) = build(|n| transform::pair_parallel(n, 2, 10, true)) else { return };
        let cfg = m.entry.config.clone();
        if m.bucket_set().buckets().is_empty() {
            return;
        }
        let (s, v) = (cfg.slots, cfg.vocab);
        let prompt: Vec<i32> = "abcd".bytes().map(|b| b as i32).collect();
        for slot in 0..3 {
            m.prefill(slot, &prompt).unwrap();
        }
        // 3 live slots → bucket 4 with one pad lane duplicating slot 0
        let active: Vec<_> =
            (0..3).map(|slot| (slot, 70 + slot as i32, prompt.len() as i32)).collect();
        let rows = m.decode_active(&active).unwrap();

        let mut tok = vec![0i32; s];
        let mut pos = vec![0i32; s];
        for &(slot, t, p) in &active {
            tok[slot] = t;
            pos[slot] = p;
        }
        let full = m.decode_step(&tok, &pos).unwrap();
        for (i, (slot, row)) in rows.iter().enumerate() {
            assert_eq!(*slot, i);
            assert_eq!(row, &full[slot * v..(slot + 1) * v], "slot {slot} diverged");
        }
        assert_eq!(
            m.bucket_set().stats(),
            vec![(
                4,
                crate::runtime::BucketStats { rounds: 1, live_lanes: 3, padded_lanes: 1 }
            )]
        );

        // untouched slot 3 admits a new sequence as usual
        m.prefill(3, &prompt).unwrap();
        let one = m.decode_active(&[(3, 70, prompt.len() as i32)]).unwrap();
        assert_eq!(one.len(), 1);
        assert!(one[0].1.iter().all(|x| x.is_finite()));
    }

    /// Both admission bounds live in one check: the prefill path's prompt
    /// limit and the ctx token budget. Anything `check_admission` admits,
    /// `SlotManager::alloc` must admit too (no admit-then-fail churn).
    #[test]
    fn admission_bounds_are_unified() {
        let Some(m) = build(|n| transform::sequential(n)) else { return };
        let ctx = m.entry.config.ctx;
        assert!(m.check_admission(0, 1).is_err(), "empty prompt");
        assert!(m.check_admission(m.max_prompt_len(), 1).is_ok());
        assert!(m.check_admission(m.max_prompt_len() + 1, 1).is_err());
        assert!(m.check_admission(10, ctx).is_err(), "impossible budget");
        let mut slots =
            crate::model::kvcache::SlotManager::new(m.entry.config.slots, ctx);
        for (pl, mn) in [(1usize, 1usize), (m.max_prompt_len(), 1), (10, ctx - 11)] {
            if m.check_admission(pl, mn).is_ok() {
                assert!(
                    slots.alloc(1, pl, mn, 0).is_ok(),
                    "alloc disagreed with check_admission for ({pl}, {mn})"
                );
            }
        }
    }

    #[test]
    fn decode_active_gathers_rows_of_full_step() {
        let Some(m) = build(|n| transform::pair_parallel(n, 2, 10, true)) else { return };
        let cfg = m.entry.config.clone();
        let prompt: Vec<i32> = "ab".bytes().map(|b| b as i32).collect();
        m.prefill(0, &prompt).unwrap();
        m.prefill(1, &prompt).unwrap();

        let active = vec![(1usize, 66i32, prompt.len() as i32)];
        let rows = m.decode_active(&active).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, 1);
        assert_eq!(rows[0].1.len(), cfg.vocab);

        // same device lanes, full-step view: row 1 must match
        let mut tokens = vec![0i32; cfg.slots];
        let mut pos = vec![0i32; cfg.slots];
        tokens[1] = 66;
        pos[1] = prompt.len() as i32;
        let full = m.decode_step(&tokens, &pos).unwrap();
        assert_eq!(rows[0].1, full[cfg.vocab..2 * cfg.vocab].to_vec());

        assert!(m.decode_active(&[(cfg.slots, 1, 0)]).is_err(), "slot bounds checked");
        assert!(m.decode_active(&[]).unwrap().is_empty());
    }

    // ---- plan-variant registry ---------------------------------------------

    /// Unknown tiers are rejected with the list of served tiers — the
    /// admission-time half of the registry contract.
    #[test]
    fn unknown_tier_is_rejected_with_available_list() {
        let Some(m) = build(transform::sequential) else { return };
        assert_eq!(m.resolve_tier(None).unwrap(), VariantId::new("plan"));
        let err = m.resolve_tier(Some("turbo")).unwrap_err().to_string();
        assert!(err.contains("turbo") && err.contains("plan"), "{err}");
        assert!(m.decode_active_v(&VariantId::new("turbo"), &[]).is_err());
        assert!(m.prefill_v(&VariantId::new("turbo"), 0, &[1]).is_err());
    }

    /// The tentpole acceptance criterion, model half: every manifest tier
    /// served by one multi-variant build produces logits bit-identical to
    /// a dedicated single-plan build of the same graph — prefill AND the
    /// decode continuation.
    #[test]
    fn tiers_bit_identical_to_dedicated_single_plan_builds() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let entry = manifest.model("td-small").unwrap().clone();
        let cfg = entry.config.clone();
        let weights = Weights::random(&cfg, 7);
        let Ok(multi) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        if multi.variant_ids().len() < 3 {
            return; // legacy artifacts without the variants section
        }
        assert_eq!(multi.default_tier(), &VariantId::dense());
        let prompt: Vec<i32> = "the red fox".bytes().map(|b| b as i32).collect();
        for vid in multi.variant_ids() {
            let spec = entry.variants.get(&vid).unwrap();
            let plan = GraphPlan::from_stage_lists(cfg.n_layers, &spec.stages).unwrap();
            let solo =
                ServingModel::new(&manifest, "td-small", &weights, &plan, quiet()).unwrap();
            let a = multi.prefill_v(&vid, 0, &prompt).unwrap();
            let b = solo.prefill(0, &prompt).unwrap();
            assert_eq!(a, b, "tier {vid}: prefill logits diverged from dedicated build");
            let next = crate::tensor::argmax(&a) as i32;
            let ra =
                multi.decode_active_v(&vid, &[(0, next, prompt.len() as i32)]).unwrap();
            let rb = solo.decode_active(&[(0, next, prompt.len() as i32)]).unwrap();
            assert_eq!(ra[0].1, rb[0].1, "tier {vid}: decode row diverged");
            assert_eq!(
                multi.variant(&vid).unwrap().effective_depth(),
                solo.effective_depth()
            );
        }
    }

    /// The speed half of the tradeoff: per-variant cost charging must
    /// strictly order the tiers' modelled round time by effective depth
    /// (dense > lp > lp_aggr), i.e. modelled tokens/sec the other way.
    #[test]
    fn modelled_tier_round_cost_orders_by_depth() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let cfg = manifest.model("td-small").unwrap().config.clone();
        let weights = Weights::random(&cfg, 11);
        // small but live α so the sync term differentiates tiers without
        // slowing the test down (block_for sleeps for real)
        let net = InterconnectConfig { alpha_s: 30e-6, beta_bytes_per_s: 25e9, enabled: true };
        let Ok(multi) = ServingModel::from_manifest(&manifest, "td-small", &weights, net)
        else {
            return;
        };
        if multi.variant_ids().len() < 3 {
            return;
        }
        let s = cfg.slots;
        let prompt: Vec<i32> = (0..16).map(|i| 97 + (i % 26)).collect();
        let mut costs: Vec<(usize, u64)> = Vec::new(); // (depth, modelled ns/round)
        for vid in multi.variant_ids() {
            for slot in 0..s {
                multi.prefill_v(&vid, slot, &prompt).unwrap();
            }
            let active: Vec<ActiveSlot> =
                (0..s).map(|slot| (slot, 65i32, prompt.len() as i32)).collect();
            multi.decode_active_v(&vid, &active).unwrap(); // warm (lazy compile)
            multi.mesh.metrics.reset();
            multi.decode_active_v(&vid, &active).unwrap();
            let var = multi.variant(&vid).unwrap();
            assert_eq!(
                m_sync_ops(&multi) as usize,
                var.all_reduces_per_token(),
                "tier {vid}: sync count must reflect ITS stage walk"
            );
            costs.push((var.effective_depth(), multi.mesh.metrics.modelled_total_ns()));
        }
        // VariantId order is dense, lp, lp_aggr — strictly shallower
        assert!(costs[0].0 > costs[1].0 && costs[1].0 > costs[2].0, "{costs:?}");
        assert!(
            costs[0].1 > costs[1].1 && costs[1].1 > costs[2].1,
            "modelled round cost must strictly order the tiers: {costs:?}"
        );
    }

    fn m_sync_ops(m: &ServingModel) -> u64 {
        let (sync_ops, _, _, _) = m.mesh.metrics.snapshot();
        sync_ops
    }

    /// The tentpole acceptance criterion: for EVERY manifest tier, paged
    /// chunked prefill + paged bucketed decode are bit-identical to the
    /// dense oracle (same weights, paging off) — gathered dense math over
    /// scattered pages changes where KV rows live, never a single bit.
    #[test]
    fn paged_serving_bit_identical_to_dense() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let entry = manifest.model("td-small").unwrap().clone();
        if entry.kv_pages.is_none() {
            return; // artifacts predate the paged family
        }
        let cfg = entry.config.clone();
        let weights = Weights::random(&cfg, 7);
        let Ok(dense) = ServingModel::from_manifest(&manifest, "td-small", &weights, quiet())
        else {
            return;
        };
        let mut paged =
            ServingModel::from_manifest(&manifest, "td-small", &weights, quiet()).unwrap();
        paged.enable_paging().unwrap();
        assert!(paged.paging_enabled());
        assert!(paged.kv_stats().is_some());
        // multi-chunk prompt (3 chunks of 32) exercising gather + scatter
        let prompt: Vec<i32> = (0..77).map(|i| 40 + (i % 50)).collect();
        for vid in dense.variant_ids() {
            let a = dense.prefill_chunked_v(&vid, 0, &prompt).unwrap();
            let b = paged.prefill_chunked_v(&vid, 0, &prompt).unwrap();
            assert_eq!(a, b, "tier {vid}: paged prefill diverged from the dense oracle");
            let mut next = crate::tensor::argmax(&a) as i32;
            let mut p = prompt.len() as i32;
            for round in 0..3 {
                let ra = dense.decode_active_v(&vid, &[(0, next, p)]).unwrap();
                let rb = paged.decode_active_v(&vid, &[(0, next, p)]).unwrap();
                assert_eq!(
                    ra[0].1, rb[0].1,
                    "tier {vid} round {round}: paged decode diverged"
                );
                next = crate::tensor::argmax(&ra[0].1) as i32;
                p += 1;
            }
            paged.release_pages(0);
        }
        // pages freed on release; only index-held prefix blocks survive
        let ks = paged.kv_stats().unwrap();
        assert!(ks.pages_in_use > 0, "the prefix index keeps shared blocks resident");
    }

    /// Paged admission prices pages: a request whose block span can never
    /// fit the (shrunken) logical pool is rejected up front; the dense
    /// bounds still apply; releasing restores nothing it shouldn't.
    #[test]
    fn paged_admission_rejects_over_pool_requests() {
        let Ok(manifest) = Manifest::load_default() else { return };
        let entry = manifest.model("td-small").unwrap().clone();
        if entry.kv_pages.is_none() {
            return;
        }
        let cfg = entry.config.clone();
        let weights = Weights::random(&cfg, 7);
        let mut m =
            ServingModel::from_manifest(&manifest, "td-small", &weights, quiet()).unwrap();
        let vid = m.resolve_tier(None).unwrap();
        // dense admission unchanged before paging
        assert!(m.check_admission_v(&vid, 40, 8).is_ok());
        m.enable_paging().unwrap();
        assert!(m.check_admission_v(&vid, 40, 8).is_ok(), "well-sized request admitted");
        assert!(m.check_admission_v(&VariantId::new("nope"), 4, 1).is_err());
        // shrink the logical pools so 2 blocks can never fit a dense-tier
        // slot (the dense stage walk has n_layers half-width stages)
        let k = entry.kv_pages.unwrap().page_tokens;
        let stages = m.variant(&vid).unwrap().stages.len();
        m.set_page_capacity(stages + 1); // 1 block fits, 2 never
        assert!(m.check_admission_v(&vid, 1, k - 1).is_ok(), "one-block span admitted");
        let err = m.check_admission_v(&vid, k, k).unwrap_err().to_string();
        assert!(err.contains("page"), "{err}");
    }

    /// Satellite: the exec-cache cap evicts LRU executables and the next
    /// round transparently recompiles them — same bits, eviction metric
    /// visible.
    #[test]
    fn exec_cache_cap_evicts_and_recompiles_transparently() {
        let Some(m) = build(|n| transform::pair_parallel(n, 4, 10, true)) else { return };
        if m.bucket_set().buckets().len() < 2 {
            return;
        }
        let prompt: Vec<i32> = "abcd".bytes().map(|b| b as i32).collect();
        m.prefill(0, &prompt).unwrap();
        m.prefill(1, &prompt).unwrap();
        let l = prompt.len() as i32;
        let r1 = m.decode_active(&[(0, 65, l)]).unwrap(); // compiles the B=1 set
        m.set_exec_cache_cap(Some(4));
        m.decode_active(&[(0, 65, l), (1, 66, l)]).unwrap(); // B=2 set evicts
        let st = m.exec_cache().stats();
        assert!(st.evictions > 0, "cap must evict: {st:?}");
        assert!(st.cached <= 6, "only the working set may survive a tiny cap: {st:?}");
        let r2 = m.decode_active(&[(0, 65, l)]).unwrap(); // recompiles B=1
        assert_eq!(r1, r2, "eviction must not change a single bit");
        let st2 = m.exec_cache().stats();
        assert!(st2.compiles > st.compiles, "evicted keys must recompile on reuse");
        assert!(st2.evictions > st.evictions, "the B=1 re-ensure evicts B=2 keys");
    }
}
