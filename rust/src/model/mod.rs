//! Model execution: weights, the computational-graph transform engine, the
//! scoring executor (perplexity under arbitrary §3 transforms) and the
//! TP/LP serving executor (§4's deployed form over the simulated mesh).

pub mod kvcache;
pub mod plan;
pub mod prefill;
pub mod scoring;
pub mod serving;
pub mod transform;
pub mod weights;

pub use plan::{GraphPlan, Stage};
pub use prefill::ChunkedPrefill;
pub use scoring::Scorer;
pub use serving::{ActiveSlot, PlanVariant, ServeStage, ServingModel};
pub use weights::Weights;
