//! §3 transform builders: produce a [`GraphPlan`] from a base model and a
//! contiguous window `[s, e)` of layers (the paper's search space).

use crate::model::plan::{GraphPlan, Stage};
use crate::util::rng::SplitMix64;

/// The untransformed sequential model.
pub fn sequential(n_layers: usize) -> GraphPlan {
    GraphPlan { n_layers, stages: (0..n_layers).map(Stage::Seq).collect() }
}

/// Fig 3a: random re-ordering of the layers in `[s, e)`.
pub fn shuffle(n_layers: usize, s: usize, e: usize, rng: &mut SplitMix64) -> GraphPlan {
    let mut window: Vec<usize> = (s..e).collect();
    rng.shuffle(&mut window);
    let mut stages = Vec::with_capacity(n_layers);
    stages.extend((0..s).map(Stage::Seq));
    stages.extend(window.into_iter().map(Stage::Seq));
    stages.extend((e..n_layers).map(Stage::Seq));
    GraphPlan { n_layers, stages }
}

/// Fig 3b: remove the layers in `[s, e)` entirely.
pub fn prune(n_layers: usize, s: usize, e: usize) -> GraphPlan {
    let stages = (0..n_layers).filter(|i| !(s..e).contains(i)).map(Stage::Seq).collect();
    GraphPlan { n_layers, stages }
}

/// Fig 3c: weight-average the layers in `[s, e)` into one layer.
pub fn merge(n_layers: usize, s: usize, e: usize) -> GraphPlan {
    let mut stages: Vec<Stage> = (0..s).map(Stage::Seq).collect();
    stages.push(Stage::Merged((s..e).collect()));
    stages.extend((e..n_layers).map(Stage::Seq));
    GraphPlan { n_layers, stages }
}

/// Fig 3d: run the whole stretch `[s, e)` in parallel (PAR approximation).
pub fn parallel(n_layers: usize, s: usize, e: usize) -> GraphPlan {
    let mut stages: Vec<Stage> = (0..s).map(Stage::Seq).collect();
    stages.push(Stage::ParBlock((s..e).collect()));
    stages.extend((e..n_layers).map(Stage::Seq));
    GraphPlan { n_layers, stages }
}

/// Fig 3e + §4: contiguous 2-parallel — consecutive disjoint pairs over
/// `[s, e)`; an odd trailing layer stays sequential. `lp_numerics` selects
/// the deployed LP-TP form (true) or the PAR approximation (false) for the
/// abl3 comparison.
pub fn pair_parallel(n_layers: usize, s: usize, e: usize, lp_numerics: bool) -> GraphPlan {
    let mut stages: Vec<Stage> = (0..s).map(Stage::Seq).collect();
    let mut i = s;
    while i + 1 < e {
        if lp_numerics {
            stages.push(Stage::PairLp(i, i + 1));
        } else {
            stages.push(Stage::ParBlock(vec![i, i + 1]));
        }
        i += 2;
    }
    if i < e {
        stages.push(Stage::Seq(i));
    }
    stages.extend((e..n_layers).map(Stage::Seq));
    GraphPlan { n_layers, stages }
}

/// §3 "triplets perform worse" ablation: 3-wide parallel groups over [s,e).
pub fn triplet_parallel(n_layers: usize, s: usize, e: usize) -> GraphPlan {
    let mut stages: Vec<Stage> = (0..s).map(Stage::Seq).collect();
    let mut i = s;
    while i + 2 < e {
        stages.push(Stage::ParBlock(vec![i, i + 1, i + 2]));
        i += 3;
    }
    while i < e {
        stages.push(Stage::Seq(i));
        i += 1;
    }
    stages.extend((e..n_layers).map(Stage::Seq));
    GraphPlan { n_layers, stages }
}

/// Experiment-protocol helper: the LP plan for a target effective depth,
/// using the window-end convention of Fig. 6 (pairs packed so the window
/// ends at `end`, the PPL-optimal end index per model).
pub fn lp_for_depth(n_layers: usize, target_depth: usize, end: usize) -> Option<GraphPlan> {
    if target_depth > n_layers || end > n_layers {
        return None;
    }
    let n_pairs = n_layers - target_depth;
    let s = end.checked_sub(2 * n_pairs)?;
    let plan = pair_parallel(n_layers, s, end, true);
    (plan.effective_depth() == target_depth).then_some(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_is_identity_plan() {
        let p = sequential(5);
        p.validate().unwrap();
        assert_eq!(p.effective_depth(), 5);
        assert_eq!(p.delta(), 0);
    }

    #[test]
    fn shuffle_permutes_only_the_window() {
        let mut rng = SplitMix64::new(9);
        let p = shuffle(8, 2, 6, &mut rng);
        p.validate().unwrap();
        assert_eq!(p.effective_depth(), 8);
        let order: Vec<usize> = p.stages.iter().flat_map(|s| s.layers()).collect();
        assert_eq!(&order[..2], &[0, 1]);
        assert_eq!(&order[6..], &[6, 7]);
        let mut win = order[2..6].to_vec();
        win.sort_unstable();
        assert_eq!(win, vec![2, 3, 4, 5]);
    }

    #[test]
    fn prune_drops_the_window() {
        let p = prune(6, 2, 4);
        p.validate().unwrap();
        assert_eq!(p.effective_depth(), 4);
        let order: Vec<usize> = p.stages.iter().flat_map(|s| s.layers()).collect();
        assert_eq!(order, vec![0, 1, 4, 5]);
    }

    #[test]
    fn merge_collapses_to_one_stage() {
        let p = merge(6, 1, 4);
        p.validate().unwrap();
        assert_eq!(p.effective_depth(), 4);
        assert!(matches!(&p.stages[1], Stage::Merged(v) if v == &vec![1, 2, 3]));
    }

    #[test]
    fn pair_parallel_matches_paper_example() {
        // layers {15..19}: pairs (15,16), (17,18), then 19 sequential
        let p = pair_parallel(32, 15, 20, true);
        p.validate().unwrap();
        assert!(matches!(p.stages[15], Stage::PairLp(15, 16)));
        assert!(matches!(p.stages[16], Stage::PairLp(17, 18)));
        assert!(matches!(p.stages[17], Stage::Seq(19)));
        // paper: LP from layer 4 to 29 on a 32-layer model → depth 19
        let p = pair_parallel(32, 4, 29, true);
        assert_eq!(p.effective_depth(), 32 - 12); // 12 pairs of the 25-window
        assert_eq!(p.delta(), 24);
    }

    #[test]
    fn triplets_group_by_three() {
        let p = triplet_parallel(9, 0, 9);
        p.validate().unwrap();
        assert_eq!(p.effective_depth(), 3);
    }

    #[test]
    fn lp_for_depth_hits_target() {
        for depth in [10, 9, 8, 7] {
            let p = lp_for_depth(12, depth, 11).unwrap();
            p.validate().unwrap();
            assert_eq!(p.effective_depth(), depth, "depth {depth}");
        }
        assert!(lp_for_depth(12, 3, 11).is_none()); // window would underflow
    }

    #[test]
    fn par_numerics_flag_switches_stage_kind() {
        let a = pair_parallel(6, 0, 4, true);
        let b = pair_parallel(6, 0, 4, false);
        assert!(matches!(a.stages[0], Stage::PairLp(0, 1)));
        assert!(matches!(&b.stages[0], Stage::ParBlock(v) if v == &vec![0, 1]));
    }
}
