//! KV-cache slot management for continuous batching.
//!
//! The device-side caches are statically shaped `[S, C, w]` tensors owned
//! by the workers (one per stage per rank); this module is the host-side
//! bookkeeping: which slot belongs to which request, how far each sequence
//! has decoded, and when a slot can be recycled.

use crate::error::{Error, Result};

#[derive(Clone, Debug)]
pub struct SlotInfo {
    pub request_id: u64,
    /// Next token position to be written/attended (== current seq length).
    pub pos: usize,
    pub generated: usize,
    pub max_new: usize,
    /// The token to feed at the next decode step.
    pub next_token: i32,
    /// Slot is mid-prefill (chunked streaming prefill): it holds its KV
    /// reservation but must not join decode rounds until the prompt is
    /// fully consumed — [`SlotManager::active_inputs`] skips it.
    pub prefilling: bool,
}

#[derive(Debug)]
pub struct SlotManager {
    slots: Vec<Option<SlotInfo>>,
    ctx: usize,
}

impl SlotManager {
    pub fn new(n_slots: usize, ctx: usize) -> SlotManager {
        SlotManager { slots: vec![None; n_slots], ctx }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn free_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.free_count() == self.n_slots()
    }

    /// Generation headroom for a prompt of `prompt_len` tokens. The ctx
    /// stop in [`SlotManager::advance`] fires once `pos + 1 == ctx`, which
    /// caps generation at `ctx - 1 - prompt_len` tokens — but it is checked
    /// *after* a token is produced, so any admissible prompt (< ctx) always
    /// gets at least one decode round (at pos ≤ ctx - 1).
    pub fn capacity_for(&self, prompt_len: usize) -> usize {
        generation_capacity(self.ctx, prompt_len)
    }

    /// Claim a free slot for a request whose prompt is `prompt_len` tokens.
    ///
    /// Admission is checked against the KV budget up front: a request whose
    /// `prompt_len + max_new` can never fit in `ctx` is rejected here with
    /// an actionable error instead of occupying a slot for decode rounds
    /// that are guaranteed to end at the ctx stop.
    pub fn alloc(
        &mut self,
        request_id: u64,
        prompt_len: usize,
        max_new: usize,
        first_token: i32,
    ) -> Result<usize> {
        if prompt_len >= self.ctx {
            return Err(Error::Serving(format!(
                "prompt of {prompt_len} tokens exceeds ctx {}",
                self.ctx
            )));
        }
        let cap = self.capacity_for(prompt_len);
        if max_new > cap {
            return Err(Error::Serving(format!(
                "request wants {max_new} new tokens but a {prompt_len}-token \
                 prompt leaves room for only {cap} within ctx {} — lower \
                 max_new_tokens or shorten the prompt",
                self.ctx
            )));
        }
        let idx = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| Error::Serving("no free slot".into()))?;
        self.slots[idx] = Some(SlotInfo {
            request_id,
            pos: prompt_len,
            generated: 0,
            max_new,
            next_token: first_token,
            prefilling: false,
        });
        Ok(idx)
    }

    /// Mark/unmark a slot as mid-prefill (see [`SlotInfo::prefilling`]).
    pub fn set_prefilling(&mut self, slot: usize, prefilling: bool) {
        if let Some(info) = self.get_mut(slot) {
            info.prefilling = prefilling;
        }
    }

    pub fn free(&mut self, slot: usize) {
        self.slots[slot] = None;
    }

    pub fn get(&self, slot: usize) -> Option<&SlotInfo> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut SlotInfo> {
        self.slots.get_mut(slot).and_then(|s| s.as_mut())
    }

    pub fn active(&self) -> impl Iterator<Item = (usize, &SlotInfo)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|x| (i, x)))
    }

    /// Decode-step inputs for all S slots: token + position vectors
    /// (inactive slots get benign zeros; their outputs are ignored).
    pub fn step_inputs(&self) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = vec![0i32; self.n_slots()];
        let mut pos = vec![0i32; self.n_slots()];
        for (i, info) in self.active() {
            tokens[i] = info.next_token;
            pos[i] = info.pos as i32;
        }
        (tokens, pos)
    }

    /// Compacted decode-step inputs: one `(slot, next_token, pos)` triple
    /// per *active, fully prefilled* slot, in slot order — the batch the
    /// scheduler hands to `ServingModel::decode_active` so the logits edge
    /// only materializes rows that will actually be sampled. Slots still
    /// mid-prefill (chunked admission) hold their reservation but are
    /// skipped until their prompt is fully consumed.
    pub fn active_inputs(&self) -> Vec<(usize, i32, i32)> {
        self.active()
            .filter(|(_, info)| !info.prefilling)
            .map(|(i, info)| (i, info.next_token, info.pos as i32))
            .collect()
    }

    /// Advance a slot after a decode step produced `token`. Returns true if
    /// the sequence is finished (budget exhausted or ctx full).
    pub fn advance(&mut self, slot: usize, token: i32, eos: i32) -> bool {
        let ctx = self.ctx;
        let info = self.get_mut(slot).expect("advance on empty slot");
        info.pos += 1;
        info.generated += 1;
        info.next_token = token;
        token == eos || info.generated >= info.max_new || info.pos + 1 >= ctx
    }
}

/// Generation headroom within a `ctx`-position KV budget for a prompt of
/// `prompt_len` tokens (the formula behind [`SlotManager::capacity_for`],
/// shared with `ServingModel::check_admission` so the pre-dequeue admission
/// check and the slot allocator can never disagree).
pub fn generation_capacity(ctx: usize, prompt_len: usize) -> usize {
    if prompt_len >= ctx {
        return 0;
    }
    ctx.saturating_sub(prompt_len + 1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut m = SlotManager::new(2, 64);
        assert!(m.is_idle());
        let a = m.alloc(1, 10, 5, 42).unwrap();
        let b = m.alloc(2, 3, 5, 43).unwrap();
        assert_ne!(a, b);
        assert!(m.alloc(3, 1, 1, 0).is_err()); // full
        m.free(a);
        assert_eq!(m.free_count(), 1);
        let c = m.alloc(3, 1, 1, 0).unwrap();
        assert_eq!(c, a); // recycled
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut m = SlotManager::new(1, 16);
        assert!(m.alloc(1, 16, 1, 0).is_err());
        assert!(m.alloc(1, 15, 1, 0).is_ok());
    }

    #[test]
    fn rejects_budget_that_can_never_fit_ctx() {
        let mut m = SlotManager::new(2, 16);
        assert_eq!(m.capacity_for(10), 5);
        // 10 prompt + 6 new tokens needs pos 16 — past the ctx stop
        let err = m.alloc(1, 10, 6, 0).unwrap_err();
        assert!(err.to_string().contains("max_new"), "{err}");
        assert_eq!(m.free_count(), 2, "rejected request must not hold a slot");
        // exactly at capacity is admitted
        assert!(m.alloc(1, 10, 5, 0).is_ok());
        // a prompt filling ctx-1 still gets one decode round (at pos ctx-1,
        // the last valid KV index), so max_new == 1 stays admissible
        assert_eq!(m.capacity_for(15), 1);
        assert!(m.alloc(2, 15, 2, 0).is_err());
        assert!(m.alloc(2, 15, 1, 0).is_ok());
    }

    #[test]
    fn step_inputs_mask_inactive() {
        let mut m = SlotManager::new(3, 64);
        m.alloc(7, 5, 10, 99).unwrap();
        let (tokens, pos) = m.step_inputs();
        assert_eq!(tokens, vec![99, 0, 0]);
        assert_eq!(pos, vec![5, 0, 0]);
    }

    #[test]
    fn active_inputs_compact_to_live_slots() {
        let mut m = SlotManager::new(4, 64);
        let a = m.alloc(7, 5, 10, 99).unwrap();
        let b = m.alloc(8, 3, 10, 41).unwrap();
        m.free(a);
        assert_eq!(m.active_inputs(), vec![(b, 41, 3)]);
        let c = m.alloc(9, 2, 10, 17).unwrap();
        assert_eq!(m.active_inputs(), vec![(c, 17, 2), (b, 41, 3)]);
    }

    #[test]
    fn prefilling_slots_hold_reservation_but_skip_decode() {
        let mut m = SlotManager::new(3, 64);
        let a = m.alloc(7, 5, 10, 99).unwrap();
        let b = m.alloc(8, 3, 10, 41).unwrap();
        m.set_prefilling(b, true);
        assert_eq!(m.active_inputs(), vec![(a, 99, 5)], "prefilling slot joined decode");
        assert_eq!(m.free_count(), 1, "prefilling slot must keep its reservation");
        m.set_prefilling(b, false);
        assert_eq!(m.active_inputs(), vec![(a, 99, 5), (b, 41, 3)]);
    }

    #[test]
    fn advance_terminates_on_budget_eos_and_ctx() {
        let mut m = SlotManager::new(1, 8);
        let s = m.alloc(1, 2, 2, 10).unwrap();
        assert!(!m.advance(s, 11, 999)); // 1 generated
        assert!(m.advance(s, 12, 999)); // budget of 2 reached
        m.free(s);
        let s = m.alloc(2, 2, 5, 10).unwrap();
        assert!(m.advance(s, 999, 999)); // eos
        m.free(s);
        // budget == capacity: the last admissible token lands on pos ctx-1,
        // where the ctx stop and the budget stop coincide
        let s = m.alloc(3, 5, 2, 10).unwrap();
        assert!(!m.advance(s, 1, 999)); // pos 6
        assert!(m.advance(s, 1, 999)); // pos 7 == ctx-1 → stop
    }
}
