//! KV-cache slot management for continuous batching, plus the paged-KV
//! page allocator and shared-prefix index.
//!
//! The dense device-side caches are statically shaped `[S, C, w]` tensors
//! owned by the workers (one per stage per rank); [`SlotManager`] is the
//! host-side bookkeeping: which slot belongs to which request, how far
//! each sequence has decoded, and when a slot can be recycled.
//!
//! Under paged serving (`ServingModel::enable_paging`) the per-variant
//! caches are replaced by two shared `[P, page, w]` pools — one per cache
//! width — and this module additionally owns the host-side paging state:
//!
//! * [`PageAllocator`] — a deterministic smallest-id-first free list over
//!   one pool's logical pages (page 0 is reserved scratch: unmapped page-
//!   table entries point at it and the kernels' causal mask discards
//!   whatever it holds), with per-page reference counts so a physical page
//!   can back several logical blocks (shared prefixes).
//! * [`PagedKv`] — the per-`(variant, stage, slot)` page tables the
//!   dispatch paths upload as the `pt` operand, a content-hash index of
//!   completed prefix blocks (identical prefixes prefill once: followers
//!   map the leader's pages and skip those chunks entirely), refcounted
//!   copy-on-write forking when a reused slot diverges from a shared
//!   block, and LRU eviction of index-only blocks under pool pressure.

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{Error, Result};
use crate::runtime::{KvPages, VariantId};

#[derive(Clone, Debug)]
pub struct SlotInfo {
    pub request_id: u64,
    /// Next token position to be written/attended (== current seq length).
    pub pos: usize,
    pub generated: usize,
    pub max_new: usize,
    /// The token to feed at the next decode step.
    pub next_token: i32,
    /// Slot is mid-prefill (chunked streaming prefill): it holds its KV
    /// reservation but must not join decode rounds until the prompt is
    /// fully consumed — [`SlotManager::active_inputs`] skips it.
    pub prefilling: bool,
}

#[derive(Debug)]
pub struct SlotManager {
    slots: Vec<Option<SlotInfo>>,
    ctx: usize,
}

impl SlotManager {
    pub fn new(n_slots: usize, ctx: usize) -> SlotManager {
        SlotManager { slots: vec![None; n_slots], ctx }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn free_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.free_count() == self.n_slots()
    }

    /// Generation headroom for a prompt of `prompt_len` tokens. The ctx
    /// stop in [`SlotManager::advance`] fires once `pos + 1 == ctx`, which
    /// caps generation at `ctx - 1 - prompt_len` tokens — but it is checked
    /// *after* a token is produced, so any admissible prompt (< ctx) always
    /// gets at least one decode round (at pos ≤ ctx - 1).
    pub fn capacity_for(&self, prompt_len: usize) -> usize {
        generation_capacity(self.ctx, prompt_len)
    }

    /// Claim a free slot for a request whose prompt is `prompt_len` tokens.
    ///
    /// Admission is checked against the KV budget up front: a request whose
    /// `prompt_len + max_new` can never fit in `ctx` is rejected here with
    /// an actionable error instead of occupying a slot for decode rounds
    /// that are guaranteed to end at the ctx stop.
    pub fn alloc(
        &mut self,
        request_id: u64,
        prompt_len: usize,
        max_new: usize,
        first_token: i32,
    ) -> Result<usize> {
        if prompt_len >= self.ctx {
            return Err(Error::Serving(format!(
                "prompt of {prompt_len} tokens exceeds ctx {}",
                self.ctx
            )));
        }
        let cap = self.capacity_for(prompt_len);
        if max_new > cap {
            return Err(Error::Serving(format!(
                "request wants {max_new} new tokens but a {prompt_len}-token \
                 prompt leaves room for only {cap} within ctx {} — lower \
                 max_new_tokens or shorten the prompt",
                self.ctx
            )));
        }
        let idx = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| Error::Serving("no free slot".into()))?;
        self.slots[idx] = Some(SlotInfo {
            request_id,
            pos: prompt_len,
            generated: 0,
            max_new,
            next_token: first_token,
            prefilling: false,
        });
        Ok(idx)
    }

    /// Mark/unmark a slot as mid-prefill (see [`SlotInfo::prefilling`]).
    pub fn set_prefilling(&mut self, slot: usize, prefilling: bool) {
        if let Some(info) = self.get_mut(slot) {
            info.prefilling = prefilling;
        }
    }

    pub fn free(&mut self, slot: usize) {
        self.slots[slot] = None;
    }

    pub fn get(&self, slot: usize) -> Option<&SlotInfo> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut SlotInfo> {
        self.slots.get_mut(slot).and_then(|s| s.as_mut())
    }

    pub fn active(&self) -> impl Iterator<Item = (usize, &SlotInfo)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|x| (i, x)))
    }

    /// Decode-step inputs for all S slots: token + position vectors
    /// (inactive slots get benign zeros; their outputs are ignored).
    pub fn step_inputs(&self) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = vec![0i32; self.n_slots()];
        let mut pos = vec![0i32; self.n_slots()];
        for (i, info) in self.active() {
            tokens[i] = info.next_token;
            pos[i] = info.pos as i32;
        }
        (tokens, pos)
    }

    /// Compacted decode-step inputs: one `(slot, next_token, pos)` triple
    /// per *active, fully prefilled* slot, in slot order — the batch the
    /// scheduler hands to `ServingModel::decode_active` so the logits edge
    /// only materializes rows that will actually be sampled. Slots still
    /// mid-prefill (chunked admission) hold their reservation but are
    /// skipped until their prompt is fully consumed.
    pub fn active_inputs(&self) -> Vec<(usize, i32, i32)> {
        self.active()
            .filter(|(_, info)| !info.prefilling)
            .map(|(i, info)| (i, info.next_token, info.pos as i32))
            .collect()
    }

    /// Advance a slot after a decode step produced `token`. Returns true if
    /// the sequence is finished (budget exhausted or ctx full).
    pub fn advance(&mut self, slot: usize, token: i32, eos: i32) -> bool {
        let ctx = self.ctx;
        let info = self.get_mut(slot).expect("advance on empty slot");
        info.pos += 1;
        info.generated += 1;
        info.next_token = token;
        token == eos || info.generated >= info.max_new || info.pos + 1 >= ctx
    }
}

/// Generation headroom within a `ctx`-position KV budget for a prompt of
/// `prompt_len` tokens (the formula behind [`SlotManager::capacity_for`],
/// shared with `ServingModel::check_admission` so the pre-dequeue admission
/// check and the slot allocator can never disagree).
pub fn generation_capacity(ctx: usize, prompt_len: usize) -> usize {
    if prompt_len >= ctx {
        return 0;
    }
    ctx.saturating_sub(prompt_len + 1).max(1)
}

// ---- paged KV cache --------------------------------------------------------

/// Cache width of a paged stage: a Tp stage writes each rank's `d/2`-wide
/// K/V shard into the `half` pool, an Lp stage its full-width layer into
/// the `full` pool — mirroring the dense `[S, C, w]` cache widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageWidth {
    Half,
    Full,
}

/// Deterministic page free list over one pool, with per-page reference
/// counts. Page 0 is the reserved scratch page (never allocated); the
/// allocator always hands out the smallest free id, so allocation order —
/// and therefore every page table, device scatter and bench metric — is
/// reproducible run to run.
#[derive(Debug)]
pub struct PageAllocator {
    /// Free logical pages (ids in `1..capacity` with zero references).
    free: BTreeSet<usize>,
    /// Logical pool size including the scratch page (≤ `physical`;
    /// shrinkable for pressure tests via [`PageAllocator::set_capacity`]).
    capacity: usize,
    /// Physical pool size — the device tensor's page dimension.
    physical: usize,
    /// Per-page reference counts (slot mappings + prefix-index holds).
    refs: Vec<usize>,
}

impl PageAllocator {
    pub fn new(pages: usize) -> PageAllocator {
        PageAllocator {
            free: (1..pages).collect(),
            capacity: pages,
            physical: pages,
            refs: vec![0; pages.max(1)],
        }
    }

    /// Logical pool size (including the scratch page).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Shrink (or re-grow, up to the physical tensor) the logical pool —
    /// the memory-pressure knob. Pages at or above the new capacity leave
    /// the free list; already-mapped high pages stay valid until released.
    pub fn set_capacity(&mut self, pages: usize) {
        self.capacity = pages.clamp(1, self.physical);
        self.free = (1..self.capacity).filter(|&p| self.refs[p] == 0).collect();
    }

    /// Claim the smallest free page, or `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<usize> {
        let p = self.free.pop_first()?;
        self.refs[p] = 1;
        Some(p)
    }

    /// Add one reference to an already-claimed page (prefix sharing).
    pub fn retain(&mut self, page: usize) {
        debug_assert!(self.refs[page] > 0, "retain on a free page");
        self.refs[page] += 1;
    }

    /// Drop one reference; the page returns to the free list once nothing
    /// holds it.
    pub fn release(&mut self, page: usize) {
        debug_assert!(self.refs[page] > 0, "release on a free page");
        self.refs[page] -= 1;
        if self.refs[page] == 0 && page != 0 && page < self.capacity {
            self.free.insert(page);
        }
    }

    /// References currently held on `page`.
    pub fn refs(&self, page: usize) -> usize {
        self.refs[page]
    }

    /// Pages currently claimed (the scratch page is not counted).
    pub fn in_use(&self) -> usize {
        self.refs.iter().skip(1).filter(|&&r| r > 0).count()
    }

    /// Pages allocatable right now, without any eviction.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }
}

/// One published prefix block: the pages holding block `j` of some prompt
/// prefix, one per stage of the owning variant, in stage order. The index
/// itself holds one reference per page, so the block outlives the slot
/// that prefilled it.
#[derive(Debug)]
struct SharedBlocks {
    pages: Vec<usize>,
    /// LRU stamp — bumped on every successful prefix match.
    last_used: u64,
}

/// Paged-KV counters surfaced through `ServingModel::kv_stats` into the
/// server metrics/snapshot (all deterministic under a fixed request
/// sequence — the bench baselines gate on them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Pages currently claimed across both pools.
    pub pages_in_use: u64,
    /// Prefix-index probes (one per paged prefill begun).
    pub prefix_lookups: u64,
    /// Probes that matched at least one block.
    pub prefix_hits: u64,
    /// Prompt tokens covered by matched blocks — prefill chunks never run.
    pub prefix_shared_tokens: u64,
    /// Prefix blocks evicted from the index under pool pressure.
    pub evictions: u64,
}

/// FNV-1a over one page-sized token chunk, chained on the previous block's
/// hash — a cumulative content hash, so equal chains mean equal full
/// prefixes (block j's chain commits to every token of blocks 0..=j).
pub fn chain_hash(prev: u64, chunk: &[i32]) -> u64 {
    let mut h = prev ^ 0xcbf2_9ce4_8422_2325;
    for &t in chunk {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Host-side paging state of one serving model: per-width allocators, the
/// `(variant, stage, slot)` page tables the dispatch paths upload as `pt`,
/// and the shared-prefix index. Geometry comes from the manifest's
/// `kv_pages` section ([`KvPages`]).
#[derive(Debug)]
pub struct PagedKv {
    half: PageAllocator,
    full: PageAllocator,
    page_tokens: usize,
    blocks_per_slot: usize,
    slots: usize,
    /// Stage widths per variant, in stage-walk order.
    widths: BTreeMap<VariantId, Vec<PageWidth>>,
    /// `tables[vid][sidx][slot * blocks_per_slot + block]` = page id
    /// (0 = unmapped → the kernels read the masked scratch page).
    tables: BTreeMap<VariantId, Vec<Vec<i32>>>,
    /// `(variant, chain hash over blocks 0..=j)` → the pages of block j.
    index: BTreeMap<(VariantId, u64), SharedBlocks>,
    clock: u64,
    prefix_lookups: u64,
    prefix_hits: u64,
    prefix_shared_tokens: u64,
    evictions: u64,
}

impl PagedKv {
    pub fn new(
        kvp: &KvPages,
        variants: &[(VariantId, Vec<PageWidth>)],
        slots: usize,
    ) -> PagedKv {
        let mut widths = BTreeMap::new();
        let mut tables = BTreeMap::new();
        for (vid, ws) in variants {
            tables.insert(
                vid.clone(),
                vec![vec![0i32; slots * kvp.blocks_per_slot]; ws.len()],
            );
            widths.insert(vid.clone(), ws.clone());
        }
        PagedKv {
            half: PageAllocator::new(kvp.pool_pages_half),
            full: PageAllocator::new(kvp.pool_pages_full),
            page_tokens: kvp.page_tokens,
            blocks_per_slot: kvp.blocks_per_slot,
            slots,
            widths,
            tables,
            index: BTreeMap::new(),
            clock: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            prefix_shared_tokens: 0,
            evictions: 0,
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn blocks_per_slot(&self) -> usize {
        self.blocks_per_slot
    }

    /// Apply a logical page-pool cap to BOTH pools — the memory-pressure
    /// knob behind `serve_batch --page-pool` and the eviction tests.
    pub fn set_page_capacity(&mut self, pages: usize) {
        self.half.set_capacity(pages);
        self.full.set_capacity(pages);
    }

    pub fn stats(&self) -> KvStats {
        KvStats {
            pages_in_use: (self.half.in_use() + self.full.in_use()) as u64,
            prefix_lookups: self.prefix_lookups,
            prefix_hits: self.prefix_hits,
            prefix_shared_tokens: self.prefix_shared_tokens,
            evictions: self.evictions,
        }
    }

    /// Admission: can a request needing `blocks` KV blocks under `vid`
    /// EVER fit the logical pools? Optimistic, vLLM-style — a request
    /// within total capacity is admitted even if pages are currently
    /// claimed (eviction under pressure is policy); only an impossible
    /// request is rejected, before any slot churn.
    pub fn fits(&self, vid: &VariantId, blocks: usize) -> bool {
        let Some(ws) = self.widths.get(vid) else { return false };
        let half_stages = ws.iter().filter(|w| matches!(w, PageWidth::Half)).count();
        let full_stages = ws.len() - half_stages;
        blocks * half_stages <= self.half.capacity().saturating_sub(1)
            && blocks * full_stages <= self.full.capacity().saturating_sub(1)
    }

    /// Admission back-pressure probe: can a request needing `blocks` KV
    /// blocks under `vid` be mapped RIGHT NOW, counting free pages plus
    /// pages reclaimable by LRU eviction (prefix blocks only the index
    /// holds)? Conservative: it ignores prefix attaches the request might
    /// score, so `true` guarantees admission succeeds while `false` only
    /// means "park and retry after a sibling retires". A request that
    /// passes [`PagedKv::fits`] always becomes admissible once every slot
    /// has retired (retired pages are either free or index-only).
    pub fn available_now(&self, vid: &VariantId, blocks: usize) -> bool {
        let Some(ws) = self.widths.get(vid) else { return false };
        let half_stages = ws.iter().filter(|w| matches!(w, PageWidth::Half)).count();
        let full_stages = ws.len() - half_stages;
        // Reclaimable = pages of index blocks where EVERY page has refs==1
        // (exactly what evict_lru can free); dedup in case of aliasing.
        let mut half_reclaim = BTreeSet::new();
        let mut full_reclaim = BTreeSet::new();
        for (key, e) in &self.index {
            let ews = &self.widths[&key.0];
            let index_only = e.pages.iter().zip(ews.iter()).all(|(&p, w)| match w {
                PageWidth::Half => self.half.refs(p) == 1,
                PageWidth::Full => self.full.refs(p) == 1,
            });
            if index_only {
                // pages at/above a shrunken logical capacity never return
                // to the free list on eviction — don't count them
                for (&p, w) in e.pages.iter().zip(ews.iter()) {
                    match w {
                        PageWidth::Half if p < self.half.capacity() => {
                            half_reclaim.insert(p);
                        }
                        PageWidth::Full if p < self.full.capacity() => {
                            full_reclaim.insert(p);
                        }
                        _ => {}
                    }
                }
            }
        }
        blocks * half_stages <= self.half.free_count() + half_reclaim.len()
            && blocks * full_stages <= self.full.free_count() + full_reclaim.len()
    }

    /// The `[blocks_per_slot]` page table of one stage of one slot — the
    /// `pt` operand of the paged chunk/decode executables.
    pub fn page_table(&self, vid: &VariantId, sidx: usize, slot: usize) -> &[i32] {
        let nb = self.blocks_per_slot;
        &self.tables[vid][sidx][slot * nb..(slot + 1) * nb]
    }

    fn alloc_page(&mut self, width: PageWidth) -> Result<usize> {
        loop {
            let got = match width {
                PageWidth::Half => self.half.alloc(),
                PageWidth::Full => self.full.alloc(),
            };
            if let Some(p) = got {
                return Ok(p);
            }
            if !self.evict_lru() {
                return Err(Error::Serving(
                    "kv page pool exhausted (no evictable prefix blocks left)".into(),
                ));
            }
        }
    }

    /// Evict the least-recently-used prefix block whose pages nothing but
    /// the index holds. Returns false when no block is evictable.
    fn evict_lru(&mut self) -> bool {
        let mut best: Option<(u64, (VariantId, u64))> = None;
        for (key, e) in &self.index {
            let ws = &self.widths[&key.0];
            let index_only = e.pages.iter().zip(ws.iter()).all(|(&p, w)| match w {
                PageWidth::Half => self.half.refs(p) == 1,
                PageWidth::Full => self.full.refs(p) == 1,
            });
            if index_only && best.as_ref().map_or(true, |(lu, _)| e.last_used < *lu) {
                best = Some((e.last_used, key.clone()));
            }
        }
        let Some((_, key)) = best else { return false };
        let e = self.index.remove(&key).unwrap();
        let ws = self.widths[&key.0].clone();
        for (&p, w) in e.pages.iter().zip(ws.iter()) {
            match w {
                PageWidth::Half => self.half.release(p),
                PageWidth::Full => self.full.release(p),
            }
        }
        self.evictions += 1;
        true
    }

    /// Lazily map `block` of `slot` for every stage of `vid`, allocating
    /// pages as needed. A stale mapping left by a previous request on the
    /// same slot is kept when private (its content is simply overwritten),
    /// but **forked** when shared (reference count > 1): the slot drops
    /// its reference and takes a fresh private page, so a diverging write
    /// can never corrupt a block other holders still read — copy-on-write.
    pub fn ensure_block(&mut self, vid: &VariantId, slot: usize, block: usize) -> Result<()> {
        debug_assert!(slot < self.slots && block < self.blocks_per_slot);
        let ws = self
            .widths
            .get(vid)
            .cloned()
            .ok_or_else(|| Error::Serving(format!("paged kv: unknown tier `{vid}`")))?;
        let idx = slot * self.blocks_per_slot + block;
        for (sidx, w) in ws.iter().enumerate() {
            let cur = self.tables[vid][sidx][idx] as usize;
            if cur != 0 {
                let shared = match w {
                    PageWidth::Half => self.half.refs(cur) > 1,
                    PageWidth::Full => self.full.refs(cur) > 1,
                };
                if !shared {
                    continue;
                }
                match w {
                    PageWidth::Half => self.half.release(cur),
                    PageWidth::Full => self.full.release(cur),
                }
            }
            let p = self.alloc_page(*w)?;
            self.tables.get_mut(vid).unwrap()[sidx][idx] = p as i32;
        }
        Ok(())
    }

    /// Hash chain of every *shareable* block of a prompt: full chunks
    /// strictly below the prompt length (`(j+1)·K < L`). The final chunk —
    /// partial or not — is never shared, so it always runs (producing the
    /// first-token logits) and decode writes land in private blocks:
    /// copy-on-write by construction on the hot path.
    fn shareable_chains(&self, tokens: &[i32]) -> Vec<u64> {
        let k = self.page_tokens;
        let mut chains = Vec::new();
        let mut h = 0u64;
        let mut j = 0;
        while (j + 1) * k < tokens.len() {
            h = chain_hash(h, &tokens[j * k..(j + 1) * k]);
            chains.push(h);
            j += 1;
        }
        chains
    }

    /// Follower half of prefix reuse: map every already-indexed leading
    /// block of `tokens` into `slot`'s page tables (bumping page refs) and
    /// return the number of prompt tokens covered — the prefill cursor
    /// starts there, and the skipped chunks charge zero modelled compute.
    pub fn attach_prefix(&mut self, vid: &VariantId, slot: usize, tokens: &[i32]) -> usize {
        self.prefix_lookups += 1;
        let Some(ws) = self.widths.get(vid).cloned() else { return 0 };
        let chains = self.shareable_chains(tokens);
        self.clock += 1;
        let clock = self.clock;
        let nb = self.blocks_per_slot;
        let mut matched = 0;
        for (j, h) in chains.iter().enumerate() {
            let Some(e) = self.index.get_mut(&(vid.clone(), *h)) else { break };
            e.last_used = clock;
            let pages = e.pages.clone();
            for (sidx, (&p, w)) in pages.iter().zip(ws.iter()).enumerate() {
                let idx = slot * nb + j;
                let old = self.tables[vid][sidx][idx] as usize;
                if old == p {
                    continue; // same prompt re-prefilled into the same slot
                }
                match w {
                    PageWidth::Half => self.half.retain(p),
                    PageWidth::Full => self.full.retain(p),
                }
                if old != 0 {
                    match w {
                        PageWidth::Half => self.half.release(old),
                        PageWidth::Full => self.full.release(old),
                    }
                }
                self.tables.get_mut(vid).unwrap()[sidx][idx] = p as i32;
            }
            matched = j + 1;
        }
        if matched > 0 {
            self.prefix_hits += 1;
            self.prefix_shared_tokens += (matched * self.page_tokens) as u64;
        }
        matched * self.page_tokens
    }

    /// Leader half: after the chunk covering block `block` of `slot`
    /// completes, publish its pages under the prefix chain hash. The index
    /// holds one reference per page, keeping the block alive for followers
    /// after the slot retires. Non-shareable blocks (the final chunk) and
    /// already-published chains are no-ops.
    pub fn register_block(&mut self, vid: &VariantId, slot: usize, tokens: &[i32], block: usize) {
        let Some(ws) = self.widths.get(vid).cloned() else { return };
        let chains = self.shareable_chains(tokens);
        let Some(&h) = chains.get(block) else { return };
        let key = (vid.clone(), h);
        if self.index.contains_key(&key) {
            return;
        }
        let idx = slot * self.blocks_per_slot + block;
        let pages: Vec<usize> = self.tables[&key.0].iter().map(|t| t[idx] as usize).collect();
        if pages.iter().any(|&p| p == 0) {
            return; // block not fully mapped: nothing to publish
        }
        for (&p, w) in pages.iter().zip(ws.iter()) {
            match w {
                PageWidth::Half => self.half.retain(p),
                PageWidth::Full => self.full.retain(p),
            }
        }
        self.clock += 1;
        self.index.insert(key, SharedBlocks { pages, last_used: self.clock });
    }

    /// Reference count of one pool page (test observability).
    #[cfg(test)]
    fn pool_refs(&self, width: PageWidth, page: usize) -> usize {
        match width {
            PageWidth::Half => self.half.refs(page),
            PageWidth::Full => self.full.refs(page),
        }
    }

    /// Return every page `slot` maps (across all variants) to the pools.
    /// Pages also held by the prefix index stay resident for future reuse;
    /// everything else becomes allocatable again.
    pub fn release_slot(&mut self, slot: usize) {
        let nb = self.blocks_per_slot;
        let vids: Vec<VariantId> = self.tables.keys().cloned().collect();
        for vid in vids {
            let ws = self.widths[&vid].clone();
            for (sidx, w) in ws.iter().enumerate() {
                for b in 0..nb {
                    let idx = slot * nb + b;
                    let p = self.tables[&vid][sidx][idx] as usize;
                    if p == 0 {
                        continue;
                    }
                    match w {
                        PageWidth::Half => self.half.release(p),
                        PageWidth::Full => self.full.release(p),
                    }
                    self.tables.get_mut(&vid).unwrap()[sidx][idx] = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut m = SlotManager::new(2, 64);
        assert!(m.is_idle());
        let a = m.alloc(1, 10, 5, 42).unwrap();
        let b = m.alloc(2, 3, 5, 43).unwrap();
        assert_ne!(a, b);
        assert!(m.alloc(3, 1, 1, 0).is_err()); // full
        m.free(a);
        assert_eq!(m.free_count(), 1);
        let c = m.alloc(3, 1, 1, 0).unwrap();
        assert_eq!(c, a); // recycled
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut m = SlotManager::new(1, 16);
        assert!(m.alloc(1, 16, 1, 0).is_err());
        assert!(m.alloc(1, 15, 1, 0).is_ok());
    }

    #[test]
    fn rejects_budget_that_can_never_fit_ctx() {
        let mut m = SlotManager::new(2, 16);
        assert_eq!(m.capacity_for(10), 5);
        // 10 prompt + 6 new tokens needs pos 16 — past the ctx stop
        let err = m.alloc(1, 10, 6, 0).unwrap_err();
        assert!(err.to_string().contains("max_new"), "{err}");
        assert_eq!(m.free_count(), 2, "rejected request must not hold a slot");
        // exactly at capacity is admitted
        assert!(m.alloc(1, 10, 5, 0).is_ok());
        // a prompt filling ctx-1 still gets one decode round (at pos ctx-1,
        // the last valid KV index), so max_new == 1 stays admissible
        assert_eq!(m.capacity_for(15), 1);
        assert!(m.alloc(2, 15, 2, 0).is_err());
        assert!(m.alloc(2, 15, 1, 0).is_ok());
    }

    #[test]
    fn step_inputs_mask_inactive() {
        let mut m = SlotManager::new(3, 64);
        m.alloc(7, 5, 10, 99).unwrap();
        let (tokens, pos) = m.step_inputs();
        assert_eq!(tokens, vec![99, 0, 0]);
        assert_eq!(pos, vec![5, 0, 0]);
    }

    #[test]
    fn active_inputs_compact_to_live_slots() {
        let mut m = SlotManager::new(4, 64);
        let a = m.alloc(7, 5, 10, 99).unwrap();
        let b = m.alloc(8, 3, 10, 41).unwrap();
        m.free(a);
        assert_eq!(m.active_inputs(), vec![(b, 41, 3)]);
        let c = m.alloc(9, 2, 10, 17).unwrap();
        assert_eq!(m.active_inputs(), vec![(c, 17, 2), (b, 41, 3)]);
    }

    #[test]
    fn prefilling_slots_hold_reservation_but_skip_decode() {
        let mut m = SlotManager::new(3, 64);
        let a = m.alloc(7, 5, 10, 99).unwrap();
        let b = m.alloc(8, 3, 10, 41).unwrap();
        m.set_prefilling(b, true);
        assert_eq!(m.active_inputs(), vec![(a, 99, 5)], "prefilling slot joined decode");
        assert_eq!(m.free_count(), 1, "prefilling slot must keep its reservation");
        m.set_prefilling(b, false);
        assert_eq!(m.active_inputs(), vec![(a, 99, 5), (b, 41, 3)]);
    }

    fn kvp(page_tokens: usize, blocks: usize, pool: usize) -> KvPages {
        KvPages {
            page_tokens,
            blocks_per_slot: blocks,
            pool_pages_half: pool,
            pool_pages_full: pool,
        }
    }

    fn lp() -> VariantId {
        VariantId::new("lp")
    }

    #[test]
    fn page_allocator_hands_out_smallest_free_id() {
        let mut a = PageAllocator::new(5);
        assert_eq!(a.alloc(), Some(1));
        assert_eq!(a.alloc(), Some(2));
        assert_eq!(a.alloc(), Some(3));
        a.release(2);
        assert_eq!(a.alloc(), Some(2), "freed page must be reissued first");
        assert_eq!(a.alloc(), Some(4));
        assert_eq!(a.alloc(), None, "pool of 5 holds 4 allocatable pages");
        assert_eq!(a.in_use(), 4);
    }

    #[test]
    fn slot_release_returns_private_pages_to_the_pool() {
        let mut kv = PagedKv::new(
            &kvp(4, 4, 9),
            &[(lp(), vec![PageWidth::Full, PageWidth::Full])],
            2,
        );
        for b in 0..2 {
            kv.ensure_block(&lp(), 0, b).unwrap();
        }
        assert_eq!(kv.stats().pages_in_use, 4, "2 stages × 2 blocks");
        // allocation walks stages within a block: stage 0 holds pages 1, 3
        assert_eq!(kv.page_table(&lp(), 0, 0)[..2], [1, 3]);
        kv.release_slot(0);
        assert_eq!(kv.stats().pages_in_use, 0);
        assert!(kv.page_table(&lp(), 0, 0).iter().all(|&p| p == 0));
        // re-ensure after release reuses the smallest ids — deterministic
        kv.ensure_block(&lp(), 1, 0).unwrap();
        assert_eq!(kv.page_table(&lp(), 0, 1)[0], 1);
    }

    #[test]
    fn fits_rejects_over_pool_requests_without_churn() {
        let kv = PagedKv::new(&kvp(4, 4, 9), &[(lp(), vec![PageWidth::Full])], 2);
        // 8 allocatable full pages, one stage: 8 blocks fit, 9 never can
        assert!(kv.fits(&lp(), 8));
        assert!(!kv.fits(&lp(), 9));
        assert!(!kv.fits(&VariantId::new("nope"), 1));
        assert_eq!(kv.stats().pages_in_use, 0, "admission probing claims nothing");
    }

    #[test]
    fn shared_prefix_attach_and_cow_fork() {
        let mut kv = PagedKv::new(&kvp(4, 4, 9), &[(lp(), vec![PageWidth::Full])], 2);
        // 12-token prompt: blocks 0 and 1 shareable, block 2 (final) never
        let tokens: Vec<i32> = (0..12).collect();
        for b in 0..3 {
            kv.ensure_block(&lp(), 0, b).unwrap();
            kv.register_block(&lp(), 0, &tokens, b);
        }
        let leader: Vec<i32> = kv.page_table(&lp(), 0, 0).to_vec();

        let shared = kv.attach_prefix(&lp(), 1, &tokens);
        assert_eq!(shared, 8, "two 4-token blocks reused");
        let st = kv.stats();
        assert_eq!((st.prefix_lookups, st.prefix_hits, st.prefix_shared_tokens), (1, 1, 8));
        let follower: Vec<i32> = kv.page_table(&lp(), 0, 1).to_vec();
        assert_eq!(follower[..2], leader[..2], "shared blocks map the same pages");
        assert_eq!(follower[2], 0, "the final block is never shared");
        // leader slot + index + follower slot all hold block 0's page
        assert_eq!(kv.pool_refs(PageWidth::Full, leader[0] as usize), 3);

        // divergence: the follower rewrites block 0 → fork to a private page
        kv.ensure_block(&lp(), 1, 0).unwrap();
        let forked = kv.page_table(&lp(), 0, 1)[0];
        assert_ne!(forked, leader[0], "copy-on-write must not reuse the shared page");
        assert_eq!(kv.pool_refs(PageWidth::Full, leader[0] as usize), 2);

        // a private block is NOT forked on re-ensure (content is overwritten)
        kv.ensure_block(&lp(), 1, 0).unwrap();
        assert_eq!(kv.page_table(&lp(), 0, 1)[0], forked);

        // a different prompt shares nothing
        let other: Vec<i32> = (100..112).collect();
        assert_eq!(kv.attach_prefix(&lp(), 1, &other), 0);
        assert_eq!(kv.stats().prefix_hits, 1);
    }

    #[test]
    fn eviction_reclaims_index_only_blocks_in_lru_order() {
        let mut kv = PagedKv::new(&kvp(4, 2, 5), &[(lp(), vec![PageWidth::Full])], 2);
        let tokens: Vec<i32> = (0..8).collect();
        kv.ensure_block(&lp(), 0, 0).unwrap();
        kv.ensure_block(&lp(), 0, 1).unwrap();
        kv.register_block(&lp(), 0, &tokens, 0);
        kv.release_slot(0);
        assert_eq!(kv.stats().pages_in_use, 1, "the index keeps the prefix block");

        // shrink to 2 logical pages: only page 1 exists and the index holds it
        kv.set_page_capacity(2);
        kv.ensure_block(&lp(), 1, 0).unwrap(); // evicts the idle prefix block
        assert_eq!(kv.stats().evictions, 1);
        assert_eq!(kv.page_table(&lp(), 0, 1)[0], 1);
        assert_eq!(kv.attach_prefix(&lp(), 1, &tokens), 0, "evicted chain is gone");

        // nothing evictable left (the only page is a live slot mapping)
        let err = kv.ensure_block(&lp(), 1, 1).unwrap_err();
        assert!(err.to_string().contains("page pool exhausted"), "{err}");
    }

    #[test]
    fn chain_hash_commits_to_the_whole_prefix() {
        let a = chain_hash(0, &[1, 2, 3, 4]);
        let b = chain_hash(a, &[5, 6, 7, 8]);
        assert_ne!(a, b);
        assert_eq!(chain_hash(0, &[1, 2, 3, 4]), a, "deterministic");
        assert_ne!(chain_hash(0, &[1, 2, 3, 5]), a, "content-sensitive");
        assert_ne!(chain_hash(a, &[5, 6, 7, 8]), chain_hash(b, &[5, 6, 7, 8]), "chain-sensitive");
    }

    #[test]
    fn advance_terminates_on_budget_eos_and_ctx() {
        let mut m = SlotManager::new(1, 8);
        let s = m.alloc(1, 2, 2, 10).unwrap();
        assert!(!m.advance(s, 11, 999)); // 1 generated
        assert!(m.advance(s, 12, 999)); // budget of 2 reached
        m.free(s);
        let s = m.alloc(2, 2, 5, 10).unwrap();
        assert!(m.advance(s, 999, 999)); // eos
        m.free(s);
        // budget == capacity: the last admissible token lands on pos ctx-1,
        // where the ctx stop and the budget stop coincide
        let s = m.alloc(3, 5, 2, 10).unwrap();
        assert!(!m.advance(s, 1, 999)); // pos 6
        assert!(m.advance(s, 1, 999)); // pos 7 == ctx-1 → stop
    }
}
