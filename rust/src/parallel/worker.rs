//! Worker thread = one simulated accelerator.
//!
//! Owns a private PJRT CPU client, compiled executables and resident weight
//! buffers (uploaded once at init — weights never cross the channel on the
//! hot path). Commands arrive over an mpsc channel; results return over a
//! per-call reply channel. The PJRT wrapper types are not `Send`, so
//! everything device-related is constructed inside the thread.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use xla::PjRtBuffer;

use crate::error::{Error, Result};
use crate::runtime::pjrt::{Engine, HostValue};

/// Argument to a worker execution.
#[derive(Clone, Debug)]
pub enum ArgRef {
    /// Fresh host data, uploaded for this call (activations, positions).
    Host(HostValue),
    /// A named buffer resident on the worker (weights, persisted states).
    Resident(String),
}

type Reply = Result<Vec<HostValue>>;

pub enum Cmd {
    /// Upload a named resident buffer (weight shard / activation / cache).
    Store { name: String, value: HostValue, done: Sender<std::result::Result<(), String>> },
    /// Download a named resident buffer to the host (collective gather /
    /// debugging; the serving hot path fetches only at the logits edge).
    Fetch { name: String, reply: Sender<std::result::Result<HostValue, String>> },
    /// Drop a named resident buffer.
    Evict { name: String },
    /// Drop a compiled executable (the exec-cache LRU eviction path; a
    /// later `Compile` of the same key re-registers it).
    Release { key: String },
    /// Pre-compile an executable.
    Compile { key: String, path: PathBuf, done: Sender<std::result::Result<(), String>> },
    /// Execute `key` with args; optionally persist outputs under names
    /// (`persist[i] = Some(name)` keeps output i on the device and returns
    /// it to the caller only if `fetch[i]`).
    Exec {
        key: String,
        args: Vec<ArgRef>,
        persist: Vec<Option<String>>,
        fetch: Vec<bool>,
        reply: Sender<std::result::Result<Vec<HostValue>, String>>,
    },
    Shutdown,
}

pub struct WorkerHandle {
    pub rank: usize,
    tx: Sender<Cmd>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn a worker. Executables are compiled lazily on first use or
    /// eagerly via [`WorkerHandle::compile`].
    pub fn spawn(rank: usize) -> WorkerHandle {
        let (tx, rx) = channel::<Cmd>();
        let join = std::thread::Builder::new()
            .name(format!("accel{rank}"))
            .spawn(move || worker_main(rx))
            .expect("spawn worker");
        WorkerHandle { rank, tx, join: Some(join) }
    }

    pub fn store(&self, name: &str, value: HostValue) -> Result<()> {
        self.store_async(name, value)?
            .recv()
            .map_err(|_| Error::msg("worker died"))?
            .map_err(Error::Msg)
    }

    /// Fire a store; returns the completion receiver so the caller can
    /// scatter to every rank before joining.
    pub fn store_async(
        &self,
        name: &str,
        value: HostValue,
    ) -> Result<Receiver<std::result::Result<(), String>>> {
        let (dtx, drx) = channel();
        self.tx
            .send(Cmd::Store { name: name.to_string(), value, done: dtx })
            .map_err(|_| Error::msg("worker gone"))?;
        Ok(drx)
    }

    /// Download a named resident buffer.
    pub fn fetch(&self, name: &str) -> Result<HostValue> {
        self.fetch_async(name)?
            .recv()
            .map_err(|_| Error::msg("worker died"))?
            .map_err(Error::Msg)
    }

    /// Fire a fetch; returns the reply receiver so the caller can gather
    /// from every rank before joining (the collective's gather half).
    pub fn fetch_async(
        &self,
        name: &str,
    ) -> Result<Receiver<std::result::Result<HostValue, String>>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Cmd::Fetch { name: name.to_string(), reply: rtx })
            .map_err(|_| Error::msg("worker gone"))?;
        Ok(rrx)
    }

    pub fn evict(&self, name: &str) {
        let _ = self.tx.send(Cmd::Evict { name: name.to_string() });
    }

    /// Drop a compiled executable (fire-and-forget; the per-worker channel
    /// keeps it ordered before any later `compile` of the same key).
    pub fn release(&self, key: &str) {
        let _ = self.tx.send(Cmd::Release { key: key.to_string() });
    }

    pub fn compile(&self, key: &str, path: PathBuf) -> Result<()> {
        let (dtx, drx) = channel();
        self.tx
            .send(Cmd::Compile { key: key.to_string(), path, done: dtx })
            .map_err(|_| Error::msg("worker gone"))?;
        drx.recv().map_err(|_| Error::msg("worker died"))?.map_err(Error::Msg)
    }

    /// Fire an execution; returns the reply receiver immediately so the
    /// coordinator can dispatch to all ranks before joining (true overlap).
    pub fn exec_async(
        &self,
        key: &str,
        args: Vec<ArgRef>,
        persist: Vec<Option<String>>,
        fetch: Vec<bool>,
    ) -> Result<Receiver<std::result::Result<Vec<HostValue>, String>>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Cmd::Exec { key: key.to_string(), args, persist, fetch, reply: rtx })
            .map_err(|_| Error::msg("worker gone"))?;
        Ok(rrx)
    }

    /// Synchronous execute-and-fetch-everything.
    pub fn exec(&self, key: &str, args: Vec<ArgRef>) -> Reply {
        let rx = self.exec_async(key, args, vec![], vec![])?;
        rx.recv().map_err(|_| Error::msg("worker died"))?.map_err(Error::Msg)
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_main(rx: Receiver<Cmd>) {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            // Fail every request with the boot error.
            for cmd in rx {
                match cmd {
                    Cmd::Store { done, .. } => {
                        let _ = done.send(Err(format!("engine boot failed: {e}")));
                    }
                    Cmd::Compile { done, .. } => {
                        let _ = done.send(Err(format!("engine boot failed: {e}")));
                    }
                    Cmd::Exec { reply, .. } => {
                        let _ = reply.send(Err(format!("engine boot failed: {e}")));
                    }
                    Cmd::Fetch { reply, .. } => {
                        let _ = reply.send(Err(format!("engine boot failed: {e}")));
                    }
                    Cmd::Evict { .. } | Cmd::Release { .. } => {}
                    Cmd::Shutdown => return,
                }
            }
            return;
        }
    };
    let mut resident: HashMap<String, PjRtBuffer> = HashMap::new();
    let mut exes: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>> = HashMap::new();

    for cmd in rx {
        match cmd {
            Cmd::Store { name, value, done } => {
                let r = engine
                    .upload(&value)
                    .map(|b| {
                        resident.insert(name, b);
                    })
                    .map_err(|e| e.to_string());
                let _ = done.send(r);
            }
            Cmd::Fetch { name, reply } => {
                let r = match resident.get(&name) {
                    Some(buf) => buf
                        .to_literal_sync()
                        .map_err(crate::error::Error::from)
                        .and_then(|l| crate::runtime::pjrt::literal_to_host(&l))
                        .map_err(|e| e.to_string()),
                    None => Err(format!("resident buffer `{name}` missing")),
                };
                let _ = reply.send(r);
            }
            Cmd::Evict { name } => {
                resident.remove(&name);
            }
            Cmd::Release { key } => {
                exes.remove(&key);
            }
            Cmd::Compile { key, path, done } => {
                let r = engine
                    .load(&path)
                    .map(|e| {
                        exes.insert(key, e);
                    })
                    .map_err(|e| e.to_string());
                let _ = done.send(r);
            }
            Cmd::Exec { key, args, persist, fetch, reply } => {
                let r = exec_one(&engine, &mut resident, &exes, &key, args, &persist, &fetch);
                let _ = reply.send(r.map_err(|e| e.to_string()));
            }
            Cmd::Shutdown => return,
        }
    }
}

fn exec_one(
    engine: &Engine,
    resident: &mut HashMap<String, PjRtBuffer>,
    exes: &HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    key: &str,
    args: Vec<ArgRef>,
    persist: &[Option<String>],
    fetch: &[bool],
) -> Result<Vec<HostValue>> {
    let exe = exes
        .get(key)
        .ok_or_else(|| Error::msg(format!("executable `{key}` not compiled on this worker")))?
        .clone();
    // Build the arg buffer list: fresh uploads own their buffer; resident
    // args borrow from the map.
    let mut owned: Vec<PjRtBuffer> = Vec::new();
    let mut order: Vec<(bool, usize, &str)> = Vec::new(); // (is_owned, idx, name)
    for a in &args {
        match a {
            ArgRef::Host(v) => {
                owned.push(engine.upload(v)?);
                order.push((true, owned.len() - 1, ""));
            }
            ArgRef::Resident(name) => {
                if !resident.contains_key(name.as_str()) {
                    return Err(Error::msg(format!("resident buffer `{name}` missing")));
                }
                order.push((false, 0, name.as_str()));
            }
        }
    }
    let refs: Vec<&PjRtBuffer> = order
        .iter()
        .map(|(is_owned, idx, name)| {
            if *is_owned {
                &owned[*idx]
            } else {
                resident.get(*name).unwrap()
            }
        })
        .collect();

    // §Perf fast path: the patched xla crate returns each output as its own
    // device buffer (untuple_result), so persisted outputs (KV caches) stay
    // device-resident and fetched outputs download only their own bytes.
    let bufs = engine.run_raw(&exe, &refs)?;
    drop(owned);
    let mut out = Vec::new();
    for (i, buf) in bufs.into_iter().enumerate() {
        let want_fetch = fetch.get(i).copied().unwrap_or(fetch.is_empty());
        let want_persist = persist.get(i).and_then(|p| p.clone());
        if want_fetch {
            out.push(crate::runtime::pjrt::literal_to_host(&buf.to_literal_sync()?)?);
        }
        if let Some(name) = want_persist {
            resident.insert(name, buf);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<crate::runtime::Manifest> {
        crate::runtime::Manifest::load_default().ok()
    }

    #[test]
    fn worker_boots_and_shuts_down() {
        let w = WorkerHandle::spawn(0);
        drop(w); // must not hang
    }

    #[test]
    fn exec_unknown_key_errors() {
        let w = WorkerHandle::spawn(0);
        let r = w.exec("nope", vec![]);
        assert!(r.is_err());
    }

    #[test]
    fn store_compile_exec_roundtrip() {
        let Some(m) = manifest() else { return };
        let entry = m.model("td-small").unwrap();
        let cfg = entry.config.clone();
        let art = entry.artifact("embed_t32").unwrap();
        let w = WorkerHandle::spawn(0);
        w.compile("embed", art.file.clone()).unwrap();
        let emb: Vec<f32> =
            (0..cfg.vocab * cfg.d_model).map(|i| (i % 31) as f32 * 0.1).collect();
        w.store("emb", HostValue::f32(vec![cfg.vocab, cfg.d_model], emb.clone())).unwrap();
        let tokens: Vec<i32> = (0..32).collect();
        let outs = w
            .exec(
                "embed",
                vec![ArgRef::Host(HostValue::i32(vec![32], tokens)), ArgRef::Resident("emb".into())],
            )
            .unwrap();
        assert_eq!(outs[0].shape(), &[32, cfg.d_model]);
        assert_eq!(outs[0].as_f32().unwrap()[..cfg.d_model], emb[..cfg.d_model]);
    }

    #[test]
    fn release_drops_executable_until_recompiled() {
        let Some(m) = manifest() else { return };
        let entry = m.model("td-small").unwrap();
        let cfg = entry.config.clone();
        let art = entry.artifact("embed_t32").unwrap();
        let w = WorkerHandle::spawn(0);
        w.compile("embed", art.file.clone()).unwrap();
        w.store(
            "emb",
            HostValue::f32(vec![cfg.vocab, cfg.d_model], vec![0.0; cfg.vocab * cfg.d_model]),
        )
        .unwrap();
        let ids = || HostValue::i32(vec![32], (0..32).collect());
        let args = || vec![ArgRef::Host(ids()), ArgRef::Resident("emb".into())];
        assert!(w.exec("embed", args()).is_ok());
        w.release("embed");
        assert!(w.exec("embed", args()).is_err(), "released executable must be gone");
        w.compile("embed", art.file.clone()).unwrap();
        assert!(w.exec("embed", args()).is_ok(), "recompile must restore it");
    }

    #[test]
    fn missing_resident_arg_errors() {
        let Some(m) = manifest() else { return };
        let art = m.model("td-small").unwrap().artifact("embed_t32").unwrap();
        let w = WorkerHandle::spawn(0);
        w.compile("embed", art.file.clone()).unwrap();
        let r = w.exec(
            "embed",
            vec![
                ArgRef::Host(HostValue::i32(vec![32], (0..32).collect())),
                ArgRef::Resident("absent".into()),
            ],
        );
        assert!(r.is_err());
    }
}
