//! Host-side collective combinators (the data plane of the simulated
//! all-reduce; the control plane — barriers and cost — lives in the mesh).

use crate::runtime::pjrt::HostValue;
use crate::tensor::add_slices;
use crate::error::{Error, Result};

/// Element-wise sum of per-rank f32 partials: the all-reduce combinator for
/// tensor parallelism (partial output projections sum to the full-rank
/// output — Megatron §3 / paper Fig. 5).
pub fn all_reduce_sum(parts: Vec<HostValue>) -> Result<HostValue> {
    let mut it = parts.into_iter();
    let first = it.next().ok_or_else(|| Error::msg("all_reduce of zero ranks"))?;
    let (shape, mut acc) = match first {
        HostValue::F32 { shape, data } => (shape, data),
        _ => return Err(Error::msg("all_reduce expects f32")),
    };
    for p in it {
        let d = p.as_f32()?;
        if p.shape() != shape.as_slice() {
            return Err(Error::msg(format!(
                "all_reduce shape mismatch: {:?} vs {:?}",
                p.shape(),
                shape
            )));
        }
        add_slices(&mut acc, d);
    }
    Ok(HostValue::F32 { shape, data: acc })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_ranks() {
        let a = HostValue::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostValue::f32(vec![2, 2], vec![10.0, 20.0, 30.0, 40.0]);
        let r = all_reduce_sum(vec![a, b]).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn single_rank_is_identity() {
        let a = HostValue::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let r = all_reduce_sum(vec![a.clone()]).unwrap();
        assert_eq!(r.as_f32().unwrap(), a.as_f32().unwrap());
    }

    #[test]
    fn rejects_mismatch_and_empty() {
        let a = HostValue::f32(vec![2], vec![1.0, 2.0]);
        let b = HostValue::f32(vec![3], vec![1.0, 2.0, 3.0]);
        assert!(all_reduce_sum(vec![a, b]).is_err());
        assert!(all_reduce_sum(vec![]).is_err());
    }
}
