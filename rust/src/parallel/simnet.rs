//! SimNet: the unified device-time cost model of the simulated mesh.
//!
//! PR 1–3 made every *work* quantity deterministic and shape-accurate
//! (`MeshMetrics::modelled_flops`, the α–β payload, `host_transfers`), but
//! nothing translated work into **time** — so no test or CI gate could say
//! whether a change made decode or chunked prefill *slower*. [`CostModel`]
//! closes that gap: it combines the α–β interconnect model ([`SimNet`])
//! with a roofline compute term and a host-link term, all parameterized by
//! a [`DeviceProfile`]. Every modelled duration is a pure function of
//! shapes and constants — deterministic by construction, so two identical
//! runs produce bit-identical modelled timelines and CI can gate on a >2%
//! regression without touching flaky wall-clock (see `bin/perf_gate.rs`).
//!
//! ## Cost equations
//!
//! * **Collective** (ring all-reduce of `n` bytes over `g` accelerators):
//!
//!   ```text
//!   T_sync(n, g) = α + 2·(g−1)/g · n / β            (0 when g ≤ 1)
//!   ```
//!
//!   latency term + two passes over the payload at link bandwidth β.
//!
//! * **Compute** (roofline over one dispatch of `f` flops touching `b`
//!   bytes of device memory):
//!
//!   ```text
//!   T_comp(f, b) = max(f / peak_flops_per_s, b / hbm_bytes_per_s)
//!   ```
//!
//!   the kernel is limited by whichever of the flop pipe or the memory
//!   system it saturates first. Small-batch decode sits on the memory
//!   side on GPU-like profiles; the testbed default profile (CPU-backed
//!   PJRT devices, low peak) is flop-bound — see `DeviceProfile::default`.
//!
//! * **Kernel launch**: each executable dispatch pays a fixed
//!   `launch_s` of driver/launch overhead ([`CostModel::launch_cost`];
//!   charged by `Mesh::exec_all` / `Mesh::exec_rank` per dispatch event).
//!
//! * **Host transfer** (PCIe-like host↔device link):
//!
//!   ```text
//!   T_host(b) = b / host_bytes_per_s
//!   ```
//!
//!   charged by the mesh for exactly the traffic
//!   `MeshMetrics::host_transfers` meters — `ArgRef::Host` uploads,
//!   fetched outputs, and `upload_all` pushes.
//!
//! The α–β defaults are calibrated in EXPERIMENTS.md so the sync:compute
//! ratio of two TP decoder layers lands near the paper's Table 3;
//! `DeviceProfile::default` is calibrated against the same table (see its
//! docs). Sweeping α/β in `benches/bench_allreduce.rs` maps out when LP's
//! halved sync count pays; `bin/fig7_modelled.rs` runs the same equations
//! analytically over Llama-2-7B-scale shapes to reproduce the paper's
//! headline 1.19× throughput claim without a GPU.
//!
//! Only the interconnect term is ever *applied* as real blocking time
//! ([`SimNet::block_for`], used when `InterconnectConfig::enabled`); the
//! compute/launch/host terms are accounting-only and never sleep.

use std::time::{Duration, Instant};

use crate::config::{DeviceProfile, InterconnectConfig};

#[derive(Clone, Debug)]
pub struct SimNet {
    pub cfg: InterconnectConfig,
}

impl SimNet {
    pub fn new(cfg: InterconnectConfig) -> SimNet {
        SimNet { cfg }
    }

    pub fn disabled() -> SimNet {
        SimNet { cfg: InterconnectConfig { enabled: false, ..Default::default() } }
    }

    /// Modelled wall-clock cost of one all-reduce of `bytes` over `g` ranks.
    pub fn all_reduce_cost(&self, bytes: usize, g: usize) -> Duration {
        if !self.cfg.enabled || g <= 1 {
            return Duration::ZERO;
        }
        let ring = 2.0 * (g as f64 - 1.0) / g as f64;
        let secs = self.cfg.alpha_s + ring * bytes as f64 / self.cfg.beta_bytes_per_s;
        Duration::from_secs_f64(secs)
    }

    /// Block the caller for `d` with sub-sleep-granularity precision:
    /// coarse sleep for the bulk, spin for the tail (Linux nanosleep
    /// overshoots by ~50µs which would swamp a 30µs α).
    pub fn block_for(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        let start = Instant::now();
        if d > Duration::from_millis(2) {
            // coarse sleep for the bulk; Linux nanosleep can overshoot by
            // ~100µs+ under load, so leave a 1ms spin tail.
            std::thread::sleep(d - Duration::from_millis(1));
        }
        while start.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    /// Convenience: model + apply the cost; returns the modelled duration.
    pub fn charge_all_reduce(&self, bytes: usize, g: usize) -> Duration {
        let d = self.all_reduce_cost(bytes, g);
        self.block_for(d);
        d
    }
}

/// The full device-time cost model: α–β interconnect + roofline compute +
/// kernel-launch overhead + host-link transfers (equations in the module
/// docs). Owned by `parallel::Mesh`, which charges every term into
/// `MeshMetrics` as the executor dispatches work; the sum
/// (`MeshMetrics::modelled_total_ns`) is the mesh's simulated clock.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub net: SimNet,
    pub dev: DeviceProfile,
}

impl CostModel {
    pub fn new(net: InterconnectConfig, dev: DeviceProfile) -> CostModel {
        CostModel { net: SimNet::new(net), dev }
    }

    /// Interconnect-only construction with the default device profile.
    pub fn from_net(net: InterconnectConfig) -> CostModel {
        CostModel::new(net, DeviceProfile::default())
    }

    /// Interconnect disabled, default device profile (compute/launch/host
    /// terms stay live — they are accounting-only and never block).
    pub fn quiet() -> CostModel {
        CostModel { net: SimNet::disabled(), dev: DeviceProfile::default() }
    }

    /// Roofline device time of one dispatch: `flops` of arithmetic over
    /// `bytes` of memory traffic (weights + KV + activations).
    pub fn compute_cost(&self, flops: u64, bytes: u64) -> Duration {
        let flop_s = flops as f64 / self.dev.peak_flops_per_s;
        let mem_s = bytes as f64 / self.dev.hbm_bytes_per_s;
        Duration::from_secs_f64(flop_s.max(mem_s))
    }

    /// Fixed launch/driver overhead of `launches` executable dispatches.
    pub fn launch_cost(&self, launches: u64) -> Duration {
        Duration::from_secs_f64(launches as f64 * self.dev.launch_s)
    }

    /// Host↔device link time for `bytes` of protocol-level traffic.
    pub fn host_transfer_cost(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.dev.host_bytes_per_s)
    }

    /// Modelled cost of one all-reduce of `bytes` over `g` ranks (α–β).
    pub fn all_reduce_cost(&self, bytes: usize, g: usize) -> Duration {
        self.net.all_reduce_cost(bytes, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(alpha_us: f64, beta_gbs: f64) -> SimNet {
        SimNet::new(InterconnectConfig {
            alpha_s: alpha_us * 1e-6,
            beta_bytes_per_s: beta_gbs * 1e9,
            enabled: true,
        })
    }

    fn cost(alpha_us: f64, beta_gbs: f64) -> CostModel {
        CostModel { net: net(alpha_us, beta_gbs), dev: DeviceProfile::default() }
    }

    #[test]
    fn cost_model_formula() {
        let n = net(10.0, 100.0);
        // 1 MB over 2 ranks: 10µs + (2·1/2)·1e6/1e11 s = 10µs + 10µs
        let d = n.all_reduce_cost(1_000_000, 2);
        assert!((d.as_secs_f64() - 20e-6).abs() < 1e-9, "{d:?}");
    }

    #[test]
    fn single_rank_and_disabled_are_free() {
        // g = 1: no collective happens, so the cost is exactly zero even
        // with the model enabled...
        assert_eq!(net(10.0, 1.0).all_reduce_cost(1 << 20, 1), Duration::ZERO);
        // ...including at g = 0 (degenerate empty reduce)
        assert_eq!(net(10.0, 1.0).all_reduce_cost(1 << 20, 0), Duration::ZERO);
        assert_eq!(SimNet::disabled().all_reduce_cost(1 << 20, 2), Duration::ZERO);
    }

    #[test]
    fn zero_byte_reduce_still_pays_alpha() {
        // bytes = 0: the latency term α is per-collective, not per-byte
        let d = net(25.0, 1.0).all_reduce_cost(0, 2);
        assert!((d.as_secs_f64() - 25e-6).abs() < 1e-12, "{d:?}");
        // and with g = 1 even the α is waived
        assert_eq!(net(25.0, 1.0).all_reduce_cost(0, 1), Duration::ZERO);
    }

    #[test]
    fn cost_scales_with_bytes() {
        let n = net(5.0, 10.0);
        assert!(n.all_reduce_cost(1 << 22, 2) > n.all_reduce_cost(1 << 12, 2));
    }

    #[test]
    fn roofline_takes_the_binding_term() {
        let c = CostModel {
            net: SimNet::disabled(),
            dev: DeviceProfile {
                peak_flops_per_s: 1e9,
                hbm_bytes_per_s: 1e9,
                launch_s: 5e-6,
                host_bytes_per_s: 1e9,
            },
        };
        // flop-bound: 1e6 flops vs 1e3 bytes -> 1 ms
        assert!((c.compute_cost(1_000_000, 1_000).as_secs_f64() - 1e-3).abs() < 1e-12);
        // memory-bound: 1e3 flops vs 1e6 bytes -> 1 ms
        assert!((c.compute_cost(1_000, 1_000_000).as_secs_f64() - 1e-3).abs() < 1e-12);
        // launch overhead is linear in dispatches
        assert_eq!(c.launch_cost(3), Duration::from_secs_f64(15e-6));
        assert_eq!(c.launch_cost(0), Duration::ZERO);
        // host link is pure bandwidth
        assert!((c.host_transfer_cost(500_000).as_secs_f64() - 0.5e-3).abs() < 1e-12);
    }

    /// More work never models faster: every cost term is monotone
    /// non-decreasing in its inputs (flops, bytes, launches, ranks·bytes).
    #[test]
    fn cost_model_is_monotone() {
        let c = cost(20.0, 50.0);
        let grid: [u64; 5] = [0, 1, 1_000, 1_000_000, 1_000_000_000];
        for (i, &a) in grid.iter().enumerate() {
            for &b in &grid[i..] {
                // b >= a in every pairing below
                assert!(
                    c.compute_cost(b, 0) >= c.compute_cost(a, 0),
                    "flops term not monotone at {a} vs {b}"
                );
                assert!(
                    c.compute_cost(0, b) >= c.compute_cost(0, a),
                    "bytes term not monotone at {a} vs {b}"
                );
                assert!(
                    c.compute_cost(b, b) >= c.compute_cost(a, a),
                    "joint roofline not monotone at {a} vs {b}"
                );
                assert!(c.launch_cost(b) >= c.launch_cost(a));
                assert!(c.host_transfer_cost(b) >= c.host_transfer_cost(a));
                assert!(
                    c.all_reduce_cost(b as usize, 2) >= c.all_reduce_cost(a as usize, 2)
                );
            }
        }
    }

    /// The modelled timeline is a pure function of the op sequence: two
    /// identical sequences cost bit-identical totals.
    #[test]
    fn modelled_costs_are_deterministic() {
        let run = || {
            let c = cost(17.0, 33.0);
            let mut total = 0u128;
            for i in 0..64u64 {
                total += c.compute_cost(i * 12_345, i * 678).as_nanos();
                total += c.all_reduce_cost((i * 91) as usize, 2).as_nanos();
                total += c.host_transfer_cost(i * 4_321).as_nanos();
                total += c.launch_cost(i % 7).as_nanos();
            }
            total
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn block_for_never_undershoots() {
        // Only the lower bound is guaranteed by the spin tail; an upper
        // bound on wall-clock is inherently flaky under load (the scheduler
        // can preempt us arbitrarily long), so we don't assert one.
        let n = net(0.0, 1.0);
        for target_us in [30u64, 150, 600] {
            let d = Duration::from_micros(target_us);
            let t = Instant::now();
            n.block_for(d);
            let el = t.elapsed();
            assert!(el >= d, "undershoot: {el:?} < {d:?}");
        }
    }
}
