//! α–β interconnect cost model.
//!
//! A ring all-reduce of `n` bytes over `g` accelerators costs
//!     α + 2·(g−1)/g · n / β
//! (latency term + two passes over the payload at link bandwidth). The
//! defaults are calibrated in EXPERIMENTS.md so that the sync:compute ratio
//! of two TP decoder layers lands near the paper's Table 3; sweeping α/β in
//! `benches/bench_allreduce.rs` maps out when LP's halved sync count pays.

use std::time::{Duration, Instant};

use crate::config::InterconnectConfig;

#[derive(Clone, Debug)]
pub struct SimNet {
    pub cfg: InterconnectConfig,
}

impl SimNet {
    pub fn new(cfg: InterconnectConfig) -> SimNet {
        SimNet { cfg }
    }

    pub fn disabled() -> SimNet {
        SimNet { cfg: InterconnectConfig { enabled: false, ..Default::default() } }
    }

    /// Modelled wall-clock cost of one all-reduce of `bytes` over `g` ranks.
    pub fn all_reduce_cost(&self, bytes: usize, g: usize) -> Duration {
        if !self.cfg.enabled || g <= 1 {
            return Duration::ZERO;
        }
        let ring = 2.0 * (g as f64 - 1.0) / g as f64;
        let secs = self.cfg.alpha_s + ring * bytes as f64 / self.cfg.beta_bytes_per_s;
        Duration::from_secs_f64(secs)
    }

    /// Block the caller for `d` with sub-sleep-granularity precision:
    /// coarse sleep for the bulk, spin for the tail (Linux nanosleep
    /// overshoots by ~50µs which would swamp a 30µs α).
    pub fn block_for(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        let start = Instant::now();
        if d > Duration::from_millis(2) {
            // coarse sleep for the bulk; Linux nanosleep can overshoot by
            // ~100µs+ under load, so leave a 1ms spin tail.
            std::thread::sleep(d - Duration::from_millis(1));
        }
        while start.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    /// Convenience: model + apply the cost; returns the modelled duration.
    pub fn charge_all_reduce(&self, bytes: usize, g: usize) -> Duration {
        let d = self.all_reduce_cost(bytes, g);
        self.block_for(d);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(alpha_us: f64, beta_gbs: f64) -> SimNet {
        SimNet::new(InterconnectConfig {
            alpha_s: alpha_us * 1e-6,
            beta_bytes_per_s: beta_gbs * 1e9,
            enabled: true,
        })
    }

    #[test]
    fn cost_model_formula() {
        let n = net(10.0, 100.0);
        // 1 MB over 2 ranks: 10µs + (2·1/2)·1e6/1e11 s = 10µs + 10µs
        let d = n.all_reduce_cost(1_000_000, 2);
        assert!((d.as_secs_f64() - 20e-6).abs() < 1e-9, "{d:?}");
    }

    #[test]
    fn single_rank_and_disabled_are_free() {
        assert_eq!(net(10.0, 1.0).all_reduce_cost(1 << 20, 1), Duration::ZERO);
        assert_eq!(SimNet::disabled().all_reduce_cost(1 << 20, 2), Duration::ZERO);
    }

    #[test]
    fn cost_scales_with_bytes() {
        let n = net(5.0, 10.0);
        assert!(n.all_reduce_cost(1 << 22, 2) > n.all_reduce_cost(1 << 12, 2));
    }

    #[test]
    fn block_for_never_undershoots() {
        // Only the lower bound is guaranteed by the spin tail; an upper
        // bound on wall-clock is inherently flaky under load (the scheduler
        // can preempt us arbitrarily long), so we don't assert one.
        let n = net(0.0, 1.0);
        for target_us in [30u64, 150, 600] {
            let d = Duration::from_micros(target_us);
            let t = Instant::now();
            n.block_for(d);
            let el = t.elapsed();
            assert!(el >= d, "undershoot: {el:?} < {d:?}");
        }
    }
}
