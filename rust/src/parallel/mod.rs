//! Simulated multi-accelerator tensor-parallel runtime.
//!
//! The paper's testbed is 2×A100 over NVLink with NCCL all-reduce; this
//! environment has neither, so we build the closest substrate that
//! exercises the same code path (DESIGN.md §Substitutions):
//!
//! * each *worker* is an OS thread owning its own PJRT CPU client, its own
//!   compiled executables and its own resident weight shards — the strict
//!   isolation a real device would impose;
//! * collectives are real synchronization points (both workers must finish
//!   their shard before the sum is formed) plus an α–β interconnect cost
//!   model ([`simnet`]) standing in for NVLink/NCCL latency+bandwidth;
//! * the mesh counts every collective and its simulated cost — the
//!   quantity the paper's Table 3 attributes the LP speedup to.

pub mod collective;
pub mod mesh;
pub mod simnet;
pub mod worker;

pub use mesh::{HostTransfers, Mesh, MeshEvent, MeshMetrics};
pub use simnet::{CostModel, SimNet};
pub use worker::{ArgRef, WorkerHandle};
