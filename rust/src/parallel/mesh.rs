//! The mesh: a group of simulated accelerators plus the collective layer.
//!
//! `exec_all` dispatches one executable call per rank and joins — the ranks
//! run concurrently on their own threads (the real parallelism in this
//! testbed). `all_reduce` is the synchronization point the paper counts:
//! it joins the ranks' partial outputs, charges the α–β interconnect cost,
//! sums, and bumps the sync metrics that `table3_profile` reports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::config::InterconnectConfig;
use crate::error::{Error, Result};
use crate::parallel::collective::all_reduce_sum;
use crate::parallel::simnet::SimNet;
use crate::parallel::worker::{ArgRef, WorkerHandle};
use crate::runtime::pjrt::HostValue;

#[derive(Default, Debug)]
pub struct MeshMetrics {
    /// Number of all-reduce operations performed.
    pub sync_ops: AtomicU64,
    /// Wall time spent in all-reduce (modelled interconnect + host sum), ns.
    pub sync_ns: AtomicU64,
    /// Wall time spent in `exec_all` (shard compute, incl. host<->device), ns.
    pub compute_ns: AtomicU64,
    /// Number of exec_all dispatches.
    pub exec_ops: AtomicU64,
}

impl MeshMetrics {
    pub fn reset(&self) {
        self.sync_ops.store(0, Ordering::Relaxed);
        self.sync_ns.store(0, Ordering::Relaxed);
        self.compute_ns.store(0, Ordering::Relaxed);
        self.exec_ops.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, f64, f64, u64) {
        (
            self.sync_ops.load(Ordering::Relaxed),
            self.sync_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.compute_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.exec_ops.load(Ordering::Relaxed),
        )
    }
}

pub struct Mesh {
    pub workers: Vec<WorkerHandle>,
    pub net: SimNet,
    pub metrics: MeshMetrics,
}

impl Mesh {
    pub fn new(n_ranks: usize, net_cfg: InterconnectConfig) -> Mesh {
        let workers = (0..n_ranks).map(WorkerHandle::spawn).collect();
        Mesh { workers, net: SimNet::new(net_cfg), metrics: MeshMetrics::default() }
    }

    pub fn ranks(&self) -> usize {
        self.workers.len()
    }

    /// Compile `key` from `path` on every rank.
    pub fn compile_all(&self, key: &str, path: &std::path::Path) -> Result<()> {
        for w in &self.workers {
            w.compile(key, path.to_path_buf())?;
        }
        Ok(())
    }

    /// Run one call per rank concurrently; returns per-rank outputs.
    /// `calls[r]` = (executable key, args, persist, fetch) for rank r.
    #[allow(clippy::type_complexity)]
    pub fn exec_all(
        &self,
        calls: Vec<(String, Vec<ArgRef>, Vec<Option<String>>, Vec<bool>)>,
    ) -> Result<Vec<Vec<HostValue>>> {
        if calls.len() != self.workers.len() {
            return Err(Error::msg(format!(
                "exec_all: {} calls for {} ranks",
                calls.len(),
                self.workers.len()
            )));
        }
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(calls.len());
        for (w, (key, args, persist, fetch)) in self.workers.iter().zip(calls) {
            rxs.push(w.exec_async(&key, args, persist, fetch)?);
        }
        let mut outs = Vec::with_capacity(rxs.len());
        for rx in rxs {
            outs.push(
                rx.recv()
                    .map_err(|_| Error::msg("worker died"))?
                    .map_err(Error::Msg)?,
            );
        }
        self.metrics
            .compute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.metrics.exec_ops.fetch_add(1, Ordering::Relaxed);
        Ok(outs)
    }

    /// All-reduce (sum) of per-rank partials: charges the interconnect cost
    /// model and the metrics, returns the combined tensor.
    pub fn all_reduce(&self, parts: Vec<HostValue>) -> Result<HostValue> {
        let t0 = Instant::now();
        let bytes = parts.first().map(|p| p.num_bytes()).unwrap_or(0);
        let g = parts.len();
        let out = all_reduce_sum(parts)?;
        self.net.charge_all_reduce(bytes, g);
        self.metrics.sync_ops.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .sync_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_net() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    #[test]
    fn mesh_spawns_and_counts_reduces() {
        let mesh = Mesh::new(2, quiet_net());
        assert_eq!(mesh.ranks(), 2);
        let a = HostValue::f32(vec![4], vec![1.0; 4]);
        let b = HostValue::f32(vec![4], vec![2.0; 4]);
        let r = mesh.all_reduce(vec![a, b]).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[3.0; 4]);
        let (ops, _, _, _) = mesh.metrics.snapshot();
        assert_eq!(ops, 1);
    }

    #[test]
    fn exec_all_arity_checked() {
        let mesh = Mesh::new(2, quiet_net());
        assert!(mesh.exec_all(vec![]).is_err());
    }

    #[test]
    fn simnet_cost_is_charged() {
        let mesh = Mesh::new(
            1,
            InterconnectConfig { alpha_s: 500e-6, beta_bytes_per_s: 1e12, enabled: true },
        );
        // g=1 in all_reduce parts => free even though enabled
        let t = Instant::now();
        mesh.all_reduce(vec![HostValue::f32(vec![1], vec![0.0])]).unwrap();
        assert!(t.elapsed() < std::time::Duration::from_micros(400));
        // two parts => alpha charged
        let t = Instant::now();
        mesh.all_reduce(vec![
            HostValue::f32(vec![1], vec![0.0]),
            HostValue::f32(vec![1], vec![0.0]),
        ])
        .unwrap();
        assert!(t.elapsed() >= std::time::Duration::from_micros(500));
    }

    #[test]
    fn metrics_reset() {
        let mesh = Mesh::new(1, quiet_net());
        mesh.all_reduce(vec![HostValue::f32(vec![1], vec![1.0])]).unwrap();
        mesh.metrics.reset();
        let (ops, sync_ms, comp_ms, execs) = mesh.metrics.snapshot();
        assert_eq!((ops, execs), (0, 0));
        assert_eq!(sync_ms, 0.0);
        assert_eq!(comp_ms, 0.0);
    }
}
