//! The mesh: a group of simulated accelerators plus the collective layer.
//!
//! `exec_all` dispatches one executable call per rank and joins — the ranks
//! run concurrently on their own threads (the real parallelism in this
//! testbed). Two collectives synchronize them:
//!
//! * `all_reduce` — legacy value-level sum of per-rank partials (scoring,
//!   benches, the serving executor's host-round-trip reference path);
//! * `reduce_into` — the resident-buffer all-reduce of the serving hot
//!   path: gathers each rank's named partial buffer (standing in for the
//!   NVLink ring), sums it into the host shadow of the activation, and
//!   scatters the combined activation back into a named resident buffer on
//!   every rank. One `sync_ops` tick and one α–β charge per call — exactly
//!   the accounting of the all-reduce it replaces, so `table3_profile` and
//!   `all_reduces_per_token` stay honest.
//!
//! ## Host-transfer accounting
//!
//! `MeshMetrics` separately meters *protocol-level* host↔device activation
//! traffic: every `ArgRef::Host` upload and every fetched output that goes
//! through `exec_all` / `exec_rank`, plus explicit `upload_all` pushes of
//! fresh host data (tokens, positions). Byte movement *inside* a collective
//! (`reduce_into`'s gather/scatter, `broadcast_resident`'s fan-out) is
//! simulation mechanics for the device-to-device interconnect and is
//! charged to the α–β model, not to the host counters. Under the resident
//! protocol a decode token costs O(1) host transfers (token ids + positions
//! in, logits out) instead of O(stages).
//!
//! ## Modelled time (the simulated clock)
//!
//! Besides metering *work*, the mesh prices it in deterministic modelled
//! *time* via its [`CostModel`] (equations in `parallel::simnet`):
//! collectives charge the α–β term (`modelled_sync_ns` + payload
//! `sync_bytes`), every `exec_all`/`exec_rank` dispatch charges one kernel
//! launch and the executor adds the roofline term through
//! [`Mesh::charge_compute`] (`modelled_compute_ns`), and the metered
//! host↔device traffic is priced on the host link (`modelled_host_ns`).
//! The sum, [`MeshMetrics::modelled_total_ns`], is the mesh's simulated
//! clock — the scheduler turns deltas of it into per-request modelled
//! TTFT/latency and CI gates on it (`bin/perf_gate.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::InterconnectConfig;
use crate::error::{Error, Result};
use crate::parallel::collective::all_reduce_sum;
use crate::parallel::simnet::CostModel;
use crate::parallel::worker::{ArgRef, WorkerHandle};
use crate::runtime::pjrt::HostValue;
use crate::tensor::add_slices;

#[derive(Default, Debug)]
pub struct MeshMetrics {
    /// Number of all-reduce operations performed (value or resident form).
    pub sync_ops: AtomicU64,
    /// Wall time spent in collectives (modelled interconnect + sum), ns.
    pub sync_ns: AtomicU64,
    /// Modelled (α–β) interconnect cost of those collectives, ns. Unlike
    /// `sync_ns` this is deterministic — tests assert on it.
    pub modelled_sync_ns: AtomicU64,
    /// Total α–β payload bytes those collectives carried (per-collective
    /// `n` of the cost equation; deterministic, gated in CI).
    pub sync_bytes: AtomicU64,
    /// Modelled device time, ns: roofline compute charged via
    /// [`Mesh::charge_compute`] plus per-dispatch kernel-launch overhead
    /// charged by `exec_all`/`exec_rank`. Deterministic.
    pub modelled_compute_ns: AtomicU64,
    /// Modelled host↔device link time, ns, for exactly the traffic the
    /// `host_*` counters meter. Deterministic.
    pub modelled_host_ns: AtomicU64,
    /// Wall time spent in `exec_all` (shard compute, incl. host<->device), ns.
    pub compute_ns: AtomicU64,
    /// Modelled device compute (flops) charged by the executor. Unlike
    /// `compute_ns` this is deterministic and shape-accurate: the serving
    /// model charges `runtime::buckets::decode_flops_per_lane` per
    /// *dispatched* lane, so a bucketed decode round is billed for the
    /// bucket shape, not the full slot count.
    pub modelled_flops: AtomicU64,
    /// Number of exec_all dispatches.
    pub exec_ops: AtomicU64,
    /// Host→device activation/input uploads initiated by the executor.
    pub host_in_ops: AtomicU64,
    pub host_in_bytes: AtomicU64,
    /// Device→host downloads of fetched outputs.
    pub host_out_ops: AtomicU64,
    pub host_out_bytes: AtomicU64,
}

/// Snapshot of the executor-level host↔device traffic counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostTransfers {
    pub in_ops: u64,
    pub in_bytes: u64,
    pub out_ops: u64,
    pub out_bytes: u64,
}

impl HostTransfers {
    pub fn ops(&self) -> u64 {
        self.in_ops + self.out_ops
    }

    pub fn bytes(&self) -> u64 {
        self.in_bytes + self.out_bytes
    }
}

impl MeshMetrics {
    pub fn reset(&self) {
        self.sync_ops.store(0, Ordering::Relaxed);
        self.sync_ns.store(0, Ordering::Relaxed);
        self.modelled_sync_ns.store(0, Ordering::Relaxed);
        self.sync_bytes.store(0, Ordering::Relaxed);
        self.modelled_compute_ns.store(0, Ordering::Relaxed);
        self.modelled_host_ns.store(0, Ordering::Relaxed);
        self.compute_ns.store(0, Ordering::Relaxed);
        self.modelled_flops.store(0, Ordering::Relaxed);
        self.exec_ops.store(0, Ordering::Relaxed);
        self.host_in_ops.store(0, Ordering::Relaxed);
        self.host_in_bytes.store(0, Ordering::Relaxed);
        self.host_out_ops.store(0, Ordering::Relaxed);
        self.host_out_bytes.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, f64, f64, u64) {
        (
            self.sync_ops.load(Ordering::Relaxed),
            self.sync_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.compute_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.exec_ops.load(Ordering::Relaxed),
        )
    }

    /// Modelled interconnect cost so far, in milliseconds (deterministic).
    pub fn modelled_sync_ms(&self) -> f64 {
        self.modelled_sync_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Modelled device time (roofline + launches), ms (deterministic).
    pub fn modelled_compute_ms(&self) -> f64 {
        self.modelled_compute_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Modelled host-link time, ms (deterministic).
    pub fn modelled_host_ms(&self) -> f64 {
        self.modelled_host_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// The mesh's simulated clock: total modelled time across all three
    /// cost terms (sync + compute + host), in nanoseconds. Monotone over a
    /// run; the scheduler reads deltas of this clock to attribute modelled
    /// latency to requests and decode rounds. Deterministic — two identical
    /// runs tick the clock identically.
    pub fn modelled_total_ns(&self) -> u64 {
        self.modelled_sync_ns.load(Ordering::Relaxed)
            + self.modelled_compute_ns.load(Ordering::Relaxed)
            + self.modelled_host_ns.load(Ordering::Relaxed)
    }

    /// Simulated clock in milliseconds (see [`MeshMetrics::modelled_total_ns`]).
    pub fn modelled_total_ms(&self) -> f64 {
        self.modelled_total_ns() as f64 / 1e6
    }

    /// Total α–β payload bytes carried by collectives so far.
    pub fn sync_bytes(&self) -> u64 {
        self.sync_bytes.load(Ordering::Relaxed)
    }

    /// Charge modelled device compute (see `modelled_flops`).
    pub fn charge_flops(&self, flops: u64) {
        self.modelled_flops.fetch_add(flops, Ordering::Relaxed);
    }

    fn charge_compute_time(&self, d: Duration) {
        self.modelled_compute_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn charge_host_time(&self, d: Duration) {
        self.modelled_host_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Modelled device compute charged so far, in flops (deterministic).
    pub fn modelled_flops(&self) -> u64 {
        self.modelled_flops.load(Ordering::Relaxed)
    }

    pub fn host_transfers(&self) -> HostTransfers {
        HostTransfers {
            in_ops: self.host_in_ops.load(Ordering::Relaxed),
            in_bytes: self.host_in_bytes.load(Ordering::Relaxed),
            out_ops: self.host_out_ops.load(Ordering::Relaxed),
            out_bytes: self.host_out_bytes.load(Ordering::Relaxed),
        }
    }

    /// Count `ArgRef::Host` uploads; returns the bytes so the mesh can
    /// price them on the modelled host link.
    fn count_host_in(&self, args: &[ArgRef]) -> u64 {
        let mut bytes = 0u64;
        for a in args {
            if let ArgRef::Host(v) = a {
                self.host_in_ops.fetch_add(1, Ordering::Relaxed);
                bytes += v.num_bytes() as u64;
            }
        }
        self.host_in_bytes.fetch_add(bytes, Ordering::Relaxed);
        bytes
    }

    /// Count fetched outputs; returns the bytes (same contract as
    /// [`MeshMetrics::count_host_in`]).
    fn count_host_out(&self, outs: &[HostValue]) -> u64 {
        let mut bytes = 0u64;
        for o in outs {
            self.host_out_ops.fetch_add(1, Ordering::Relaxed);
            bytes += o.num_bytes() as u64;
        }
        self.host_out_bytes.fetch_add(bytes, Ordering::Relaxed);
        bytes
    }
}

/// One dispatch-layer event, as the mesh actually performed it. Recorded
/// only while a trace is armed ([`Mesh::begin_trace`]) — the verifier's
/// `crosscheck_trace` replays a protocol step with recording on and diffs
/// the result against the *static* [`crate::verify::DispatchTrace`] the
/// plan predicts, proving the abstract interpretation models the real
/// dispatch sequence rather than a parallel fiction. The same recorder
/// doubles as the mesh half of the observability layer: every event is
/// stored with simulated-clock stamps ([`TimedMeshEvent`]) which
/// `crate::obs::Tracer` turns into Chrome-trace spans.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MeshEvent {
    /// `exec_all`: the same executable dispatched on every rank.
    Exec { key: String, ranks: usize },
    /// `exec_rank`: a single-rank dispatch (embed/logits edges).
    ExecRank { key: String, rank: usize },
    /// `upload_all`: fresh host data pushed to every rank.
    Upload { name: String, ranks: usize },
    /// `broadcast_resident`: device-to-device fan-out of an activation.
    Broadcast { name: String },
    /// `all_reduce` / `reduce_into`: a payload-bearing collective.
    Collective { kind: &'static str, bytes: u64, ranks: usize },
}

/// A [`MeshEvent`] stamped with the simulated clock: `at_ns` is the
/// mesh's modelled clock ([`MeshMetrics::modelled_total_ns`]) when the
/// event was dispatched, `dur_ns` the modelled cost the event itself
/// charges (the α–β term for collectives, the host-link term for
/// uploads, one kernel launch for dispatches; 0 for events whose cost is
/// charged elsewhere). One recorder serves both consumers: the static
/// verifier reads the bare events via [`Mesh::take_trace`], the
/// observability exporters (`crate::obs`) read the timed form via
/// [`Mesh::take_timed_trace`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TimedMeshEvent {
    pub at_ns: u64,
    pub dur_ns: u64,
    pub event: MeshEvent,
}

pub struct Mesh {
    pub workers: Vec<WorkerHandle>,
    /// Device-time cost model (α–β interconnect + roofline + host link).
    pub cost: CostModel,
    pub metrics: MeshMetrics,
    /// Armed event recorder (None = off, the default). Verification and
    /// observability hook — the hot path pays one uncontended lock +
    /// `is_some()` while disarmed.
    trace: Mutex<Option<Vec<TimedMeshEvent>>>,
}

impl Mesh {
    pub fn new(n_ranks: usize, net_cfg: InterconnectConfig) -> Mesh {
        Mesh::with_cost(n_ranks, CostModel::from_net(net_cfg))
    }

    /// Build with an explicit cost model (custom [`crate::config::DeviceProfile`]).
    pub fn with_cost(n_ranks: usize, cost: CostModel) -> Mesh {
        let workers = (0..n_ranks).map(WorkerHandle::spawn).collect();
        Mesh { workers, cost, metrics: MeshMetrics::default(), trace: Mutex::new(None) }
    }

    pub fn ranks(&self) -> usize {
        self.workers.len()
    }

    /// Arm the event recorder: subsequent dispatches/collectives append to
    /// an in-order [`MeshEvent`] log until [`Mesh::take_trace`] drains it.
    pub fn begin_trace(&self) {
        *self.trace.lock().unwrap() = Some(Vec::new());
    }

    /// Drain the recorded events and disarm the recorder. Returns an empty
    /// log if [`Mesh::begin_trace`] was never called.
    pub fn take_trace(&self) -> Vec<MeshEvent> {
        self.take_timed_trace().into_iter().map(|t| t.event).collect()
    }

    /// Drain the recorded events with their simulated-clock stamps and
    /// disarm the recorder (the exporter-facing form of
    /// [`Mesh::take_trace`]).
    pub fn take_timed_trace(&self) -> Vec<TimedMeshEvent> {
        self.trace.lock().unwrap().take().unwrap_or_default()
    }

    fn record(&self, ev: MeshEvent) {
        self.record_timed(ev, Duration::ZERO);
    }

    /// Append `ev` stamped with the current simulated-clock reading plus
    /// the modelled duration the event is about to charge. The clock is
    /// read only while the recorder is armed.
    fn record_timed(&self, ev: MeshEvent, dur: Duration) {
        if let Some(log) = self.trace.lock().unwrap().as_mut() {
            log.push(TimedMeshEvent {
                at_ns: self.metrics.modelled_total_ns(),
                dur_ns: dur.as_nanos() as u64,
                event: ev,
            });
        }
    }

    /// Charge one dispatch's modelled device work: `flops` of arithmetic
    /// over `bytes` of device-memory traffic, priced by the roofline term
    /// of the cost model. The executor calls this once per protocol step
    /// (decode round, prefill pass/chunk) with shape-accurate totals from
    /// `runtime::buckets`; kernel-launch overhead is charged separately by
    /// `exec_all`/`exec_rank` per dispatch event.
    pub fn charge_compute(&self, flops: u64, bytes: u64) {
        self.metrics.charge_flops(flops);
        self.metrics.charge_compute_time(self.cost.compute_cost(flops, bytes));
    }

    /// Compile `key` from `path` on every rank.
    pub fn compile_all(&self, key: &str, path: &std::path::Path) -> Result<()> {
        for w in &self.workers {
            w.compile(key, path.to_path_buf())?;
        }
        Ok(())
    }

    /// Drop a compiled executable on every rank (the exec-cache eviction
    /// path — see `runtime::buckets::ExecCache`).
    pub fn release_all(&self, key: &str) {
        for w in &self.workers {
            w.release(key);
        }
    }

    /// Run one call per rank concurrently; returns per-rank outputs.
    /// `calls[r]` = (executable key, args, persist, fetch) for rank r.
    #[allow(clippy::type_complexity)]
    pub fn exec_all(
        &self,
        calls: Vec<(String, Vec<ArgRef>, Vec<Option<String>>, Vec<bool>)>,
    ) -> Result<Vec<Vec<HostValue>>> {
        if calls.len() != self.workers.len() {
            return Err(Error::msg(format!(
                "exec_all: {} calls for {} ranks",
                calls.len(),
                self.workers.len()
            )));
        }
        if let Some((key, ..)) = calls.first() {
            self.record_timed(
                MeshEvent::Exec { key: key.clone(), ranks: calls.len() },
                self.cost.launch_cost(1),
            );
        }
        let t0 = Instant::now();
        // One modelled kernel launch per dispatch event (the ranks run the
        // same kernel concurrently — device time, not rank-count time).
        // Launch and host-link time are charged at metering time, so the
        // modelled clock can never diverge from the host_* counters even
        // when a worker dies mid-round and we bail with Err.
        self.metrics.charge_compute_time(self.cost.launch_cost(1));
        let mut rxs = Vec::with_capacity(calls.len());
        for (w, (key, args, persist, fetch)) in self.workers.iter().zip(calls) {
            let bytes = self.metrics.count_host_in(&args);
            self.metrics.charge_host_time(self.cost.host_transfer_cost(bytes));
            rxs.push(w.exec_async(&key, args, persist, fetch)?);
        }
        let mut outs = Vec::with_capacity(rxs.len());
        for rx in rxs {
            let o = rx
                .recv()
                .map_err(|_| Error::msg("worker died"))?
                .map_err(Error::Msg)?;
            let bytes = self.metrics.count_host_out(&o);
            self.metrics.charge_host_time(self.cost.host_transfer_cost(bytes));
            outs.push(o);
        }
        self.metrics
            .compute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.metrics.exec_ops.fetch_add(1, Ordering::Relaxed);
        Ok(outs)
    }

    /// Run one call on a single rank, metering its host↔device traffic
    /// (the executor's embed/logits edges go through here).
    pub fn exec_rank(
        &self,
        rank: usize,
        key: &str,
        args: Vec<ArgRef>,
        persist: Vec<Option<String>>,
        fetch: Vec<bool>,
    ) -> Result<Vec<HostValue>> {
        let w = self
            .workers
            .get(rank)
            .ok_or_else(|| Error::msg(format!("exec_rank: no rank {rank}")))?;
        self.record_timed(
            MeshEvent::ExecRank { key: key.to_string(), rank },
            self.cost.launch_cost(1),
        );
        // charge at metering time — see the invariant note in `exec_all`
        self.metrics.charge_compute_time(self.cost.launch_cost(1));
        let bytes = self.metrics.count_host_in(&args);
        self.metrics.charge_host_time(self.cost.host_transfer_cost(bytes));
        let rx = w.exec_async(key, args, persist, fetch)?;
        let o = rx
            .recv()
            .map_err(|_| Error::msg("worker died"))?
            .map_err(Error::Msg)?;
        let bytes = self.metrics.count_host_out(&o);
        self.metrics.charge_host_time(self.cost.host_transfer_cost(bytes));
        Ok(o)
    }

    /// Scatter a value into a named resident buffer on every rank (fire
    /// all stores, then join). Unmetered — callers decide whether the
    /// movement counts as host traffic or simulated interconnect.
    fn store_all(&self, name: &str, value: &HostValue) -> Result<()> {
        let rxs: Vec<_> = self
            .workers
            .iter()
            .map(|w| w.store_async(name, value.clone()))
            .collect::<Result<_>>()?;
        for rx in rxs {
            rx.recv().map_err(|_| Error::msg("worker died"))?.map_err(Error::Msg)?;
        }
        Ok(())
    }

    /// Push fresh host data (token ids, positions) into a named resident
    /// buffer on every rank. Counted as host→device transfers — this is
    /// real host traffic in any deployment.
    pub fn upload_all(&self, name: &str, value: HostValue) -> Result<()> {
        let bytes = value.num_bytes() as u64;
        let total = bytes * self.workers.len() as u64;
        self.record_timed(
            MeshEvent::Upload { name: name.to_string(), ranks: self.workers.len() },
            self.cost.host_transfer_cost(total),
        );
        self.store_all(name, &value)?;
        self.metrics
            .host_in_ops
            .fetch_add(self.workers.len() as u64, Ordering::Relaxed);
        self.metrics.host_in_bytes.fetch_add(total, Ordering::Relaxed);
        self.metrics.charge_host_time(self.cost.host_transfer_cost(total));
        Ok(())
    }

    /// Fan a value out into a named resident buffer on every rank. Models
    /// the device-to-device broadcast of an activation already on the mesh
    /// (e.g. rank 0's embedding output), so it is *not* counted as host
    /// traffic; the simulation merely routes the bytes through the
    /// coordinator because the PJRT CPU devices share no interconnect.
    pub fn broadcast_resident(&self, name: &str, value: &HostValue) -> Result<()> {
        self.record(MeshEvent::Broadcast { name: name.to_string() });
        self.store_all(name, value)
    }

    /// All-reduce (sum) of per-rank partials: charges the interconnect cost
    /// model and the metrics, returns the combined tensor. (Value-level
    /// form — the serving hot path uses [`Mesh::reduce_into`].)
    pub fn all_reduce(&self, parts: Vec<HostValue>) -> Result<HostValue> {
        let t0 = Instant::now();
        let bytes = parts.first().map(|p| p.num_bytes()).unwrap_or(0);
        let g = parts.len();
        self.record_timed(
            MeshEvent::Collective { kind: "all_reduce", bytes: bytes as u64, ranks: g },
            self.cost.all_reduce_cost(bytes, g),
        );
        let out = all_reduce_sum(parts)?;
        let modelled = self.cost.net.charge_all_reduce(bytes, g);
        self.metrics.sync_ops.fetch_add(1, Ordering::Relaxed);
        self.metrics.sync_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.metrics
            .modelled_sync_ns
            .fetch_add(modelled.as_nanos() as u64, Ordering::Relaxed);
        self.metrics
            .sync_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Resident-buffer all-reduce: gather the named `partial` buffer from
    /// every rank, sum the partials (rank order, same combinator as
    /// [`Mesh::all_reduce`]), accumulate the sum into the host `shadow` of
    /// the activation, and scatter the combined activation back to every
    /// rank as resident buffer `dest`.
    ///
    /// One `sync_ops` tick and one α–β charge — identical accounting to the
    /// value-level all-reduce it replaces. The gather/scatter legs stand in
    /// for the on-device ring and are not counted as host transfers.
    pub fn reduce_into(&self, partial: &str, shadow: &mut [f32], dest: &str) -> Result<()> {
        let t0 = Instant::now();
        let rxs: Vec<_> = self
            .workers
            .iter()
            .map(|w| w.fetch_async(partial))
            .collect::<Result<_>>()?;
        let mut parts = Vec::with_capacity(rxs.len());
        for rx in rxs {
            parts.push(rx.recv().map_err(|_| Error::msg("worker died"))?.map_err(Error::Msg)?);
        }
        let bytes = parts.first().map(|p| p.num_bytes()).unwrap_or(0);
        let g = parts.len();
        self.record_timed(
            MeshEvent::Collective { kind: "reduce_into", bytes: bytes as u64, ranks: g },
            self.cost.all_reduce_cost(bytes, g),
        );
        let reduced = all_reduce_sum(parts)?;
        let shape = reduced.shape().to_vec();
        let rdata = reduced.as_f32()?;
        if rdata.len() != shadow.len() {
            return Err(Error::msg(format!(
                "reduce_into: partial `{partial}` has {} elements, shadow {}",
                rdata.len(),
                shadow.len()
            )));
        }
        add_slices(shadow, rdata);
        let modelled = self.cost.net.charge_all_reduce(bytes, g);
        self.metrics.sync_ops.fetch_add(1, Ordering::Relaxed);
        self.metrics.sync_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.metrics
            .modelled_sync_ns
            .fetch_add(modelled.as_nanos() as u64, Ordering::Relaxed);

        let scattered = HostValue::f32(shape, shadow.to_vec());
        self.store_all(dest, &scattered)?;
        self.metrics
            .sync_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use crate::parallel::simnet::SimNet;

    fn quiet_net() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    #[test]
    fn mesh_spawns_and_counts_reduces() {
        let mesh = Mesh::new(2, quiet_net());
        assert_eq!(mesh.ranks(), 2);
        let a = HostValue::f32(vec![4], vec![1.0; 4]);
        let b = HostValue::f32(vec![4], vec![2.0; 4]);
        let r = mesh.all_reduce(vec![a, b]).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[3.0; 4]);
        let (ops, _, _, _) = mesh.metrics.snapshot();
        assert_eq!(ops, 1);
    }

    #[test]
    fn exec_all_arity_checked() {
        let mesh = Mesh::new(2, quiet_net());
        assert!(mesh.exec_all(vec![]).is_err());
    }

    #[test]
    fn simnet_cost_is_charged() {
        // Deterministic: assert on the *charged* α–β cost the SimNet
        // modelled, not on wall-clock (flaky under load).
        let net = InterconnectConfig {
            alpha_s: 500e-6,
            beta_bytes_per_s: 1e12,
            enabled: true,
        };
        let mesh = Mesh::new(1, net.clone());
        // g=1 in all_reduce parts => free even though enabled
        mesh.all_reduce(vec![HostValue::f32(vec![1], vec![0.0])]).unwrap();
        assert_eq!(mesh.metrics.modelled_sync_ns.load(Ordering::Relaxed), 0);
        // two parts => alpha charged, exactly as the cost model says
        mesh.all_reduce(vec![
            HostValue::f32(vec![1], vec![0.0]),
            HostValue::f32(vec![1], vec![0.0]),
        ])
        .unwrap();
        let expect = SimNet::new(net).all_reduce_cost(4, 2).as_nanos() as u64;
        assert!(expect >= 500_000, "alpha term missing from the model");
        assert_eq!(mesh.metrics.modelled_sync_ns.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn metrics_reset() {
        let mesh = Mesh::new(1, quiet_net());
        mesh.all_reduce(vec![HostValue::f32(vec![1], vec![1.0])]).unwrap();
        mesh.metrics.charge_flops(1234);
        assert_eq!(mesh.metrics.modelled_flops(), 1234);
        mesh.charge_compute(1000, 1000);
        mesh.upload_all("x", HostValue::i32(vec![2], vec![1, 2])).unwrap();
        assert!(mesh.metrics.modelled_compute_ms() > 0.0);
        assert!(mesh.metrics.modelled_host_ms() > 0.0);
        mesh.metrics.reset();
        assert_eq!(mesh.metrics.modelled_flops(), 0);
        let (ops, sync_ms, comp_ms, execs) = mesh.metrics.snapshot();
        assert_eq!((ops, execs), (0, 0));
        assert_eq!(sync_ms, 0.0);
        assert_eq!(comp_ms, 0.0);
        assert_eq!(mesh.metrics.host_transfers().ops(), 0);
        assert_eq!(mesh.metrics.modelled_sync_ms(), 0.0);
        assert_eq!(mesh.metrics.sync_bytes(), 0);
        assert_eq!(mesh.metrics.modelled_total_ns(), 0);
        assert_eq!(mesh.metrics.modelled_total_ms(), 0.0);
    }

    /// The simulated clock ticks by exactly the cost model's sum of terms,
    /// and two identical op sequences tick it bit-identically.
    #[test]
    fn modelled_clock_sums_terms_and_is_deterministic() {
        let run = || {
            let net = InterconnectConfig {
                alpha_s: 100e-6,
                beta_bytes_per_s: 1e10,
                enabled: true,
            };
            let dev = DeviceProfile {
                peak_flops_per_s: 1e9,
                hbm_bytes_per_s: 1e9,
                launch_s: 10e-6,
                host_bytes_per_s: 1e9,
            };
            let mesh = Mesh::with_cost(2, CostModel::new(net, dev));
            mesh.charge_compute(2_000_000, 500); // 2 ms, flop-bound
            mesh.upload_all("pos", HostValue::i32(vec![4], vec![0; 4])).unwrap(); // 2×16 B
            mesh.all_reduce(vec![
                HostValue::f32(vec![8], vec![0.0; 8]),
                HostValue::f32(vec![8], vec![0.0; 8]),
            ])
            .unwrap();
            (
                mesh.metrics.modelled_compute_ns.load(Ordering::Relaxed),
                mesh.metrics.modelled_host_ns.load(Ordering::Relaxed),
                mesh.metrics.modelled_sync_ns.load(Ordering::Relaxed),
                mesh.metrics.sync_bytes(),
                mesh.metrics.modelled_total_ns(),
            )
        };
        let (comp, host, sync, payload, total) = run();
        assert_eq!(comp, 2_000_000, "roofline term");
        assert_eq!(host, 32, "2 ranks × 16 B at 1 GB/s = 32 ns");
        // α + 2·(1/2)·32/1e10 s = 100µs + 3.2ns
        assert_eq!(sync, 100_003);
        assert_eq!(payload, 32);
        assert_eq!(total, comp + host + sync);
        assert_eq!(run(), (comp, host, sync, payload, total), "clock must be deterministic");
    }

    #[test]
    fn upload_all_counts_host_traffic_and_broadcast_does_not() {
        let mesh = Mesh::new(2, quiet_net());
        let v = HostValue::i32(vec![4], vec![1, 2, 3, 4]);
        mesh.upload_all("pos", v.clone()).unwrap();
        let h = mesh.metrics.host_transfers();
        assert_eq!(h.in_ops, 2);
        assert_eq!(h.in_bytes, 32);
        assert_eq!(h.out_ops, 0);
        mesh.broadcast_resident("act", &v).unwrap();
        assert_eq!(mesh.metrics.host_transfers(), h, "broadcast is interconnect, not host");
    }

    #[test]
    fn reduce_into_gathers_sums_and_scatters() {
        let mesh = Mesh::new(2, quiet_net());
        mesh.workers[0].store("p", HostValue::f32(vec![3], vec![1.0, 2.0, 3.0])).unwrap();
        mesh.workers[1].store("p", HostValue::f32(vec![3], vec![10.0, 20.0, 30.0])).unwrap();
        let mut shadow = vec![0.5f32; 3];
        mesh.reduce_into("p", &mut shadow, "act").unwrap();
        assert_eq!(shadow, vec![11.5, 22.5, 33.5]);
        // combined activation is resident on every rank
        for w in &mesh.workers {
            assert_eq!(w.fetch("act").unwrap().as_f32().unwrap(), &[11.5, 22.5, 33.5]);
        }
        let (ops, _, _, _) = mesh.metrics.snapshot();
        assert_eq!(ops, 1, "reduce_into is one sync op");
        assert_eq!(mesh.metrics.host_transfers().ops(), 0, "collective legs are not host traffic");
    }

    #[test]
    fn trace_records_dispatches_only_while_armed() {
        let mesh = Mesh::new(2, quiet_net());
        let v = HostValue::f32(vec![2], vec![1.0, 2.0]);
        // recorder off: nothing logged
        mesh.upload_all("pos", v.clone()).unwrap();
        assert!(mesh.take_trace().is_empty());
        // armed: events appear in dispatch order with exact payload fields
        mesh.begin_trace();
        mesh.upload_all("pos", v.clone()).unwrap();
        mesh.broadcast_resident("act", &v).unwrap();
        mesh.workers[0].store("p", v.clone()).unwrap();
        mesh.workers[1].store("p", v.clone()).unwrap();
        let mut shadow = vec![0.0f32; 2];
        mesh.reduce_into("p", &mut shadow, "act").unwrap();
        let tr = mesh.take_trace();
        assert_eq!(
            tr,
            vec![
                MeshEvent::Upload { name: "pos".into(), ranks: 2 },
                MeshEvent::Broadcast { name: "act".into() },
                MeshEvent::Collective { kind: "reduce_into", bytes: 8, ranks: 2 },
            ]
        );
        // draining disarms the recorder
        mesh.broadcast_resident("act", &v).unwrap();
        assert!(mesh.take_trace().is_empty());
    }

    /// The timed form of the trace: every event carries the simulated
    /// clock at dispatch plus the modelled cost it charges, the stamps
    /// are monotone, and the bare [`Mesh::take_trace`] view stays the
    /// event-for-event projection the verifier consumes.
    #[test]
    fn timed_trace_stamps_simulated_clock() {
        let net = InterconnectConfig { alpha_s: 100e-6, beta_bytes_per_s: 1e10, enabled: true };
        let mesh = Mesh::new(2, net.clone());
        mesh.begin_trace();
        mesh.upload_all("pos", HostValue::i32(vec![4], vec![0; 4])).unwrap();
        mesh.workers[0].store("p", HostValue::f32(vec![2], vec![1.0, 2.0])).unwrap();
        mesh.workers[1].store("p", HostValue::f32(vec![2], vec![3.0, 4.0])).unwrap();
        let mut shadow = vec![0.0f32; 2];
        mesh.reduce_into("p", &mut shadow, "act").unwrap();
        let tr = mesh.take_timed_trace();
        assert_eq!(tr.len(), 2);
        // upload: stamped at clock 0, priced on the host link (2 ranks × 16 B)
        let host_ns = mesh.cost.host_transfer_cost(32).as_nanos() as u64;
        assert_eq!((tr[0].at_ns, tr[0].dur_ns), (0, host_ns));
        assert!(matches!(tr[0].event, MeshEvent::Upload { .. }));
        // collective: stamped after the upload's charge, α–β cost as duration
        let sync_ns = SimNet::new(net).all_reduce_cost(8, 2).as_nanos() as u64;
        assert_eq!((tr[1].at_ns, tr[1].dur_ns), (host_ns, sync_ns));
        assert!(matches!(tr[1].event, MeshEvent::Collective { kind: "reduce_into", .. }));
        // the same run through take_trace is the projection of the timed log
        mesh.begin_trace();
        mesh.upload_all("pos", HostValue::i32(vec![4], vec![0; 4])).unwrap();
        assert_eq!(mesh.take_trace(), vec![MeshEvent::Upload { name: "pos".into(), ranks: 2 }]);
    }

    #[test]
    fn reduce_into_rejects_shadow_mismatch() {
        let mesh = Mesh::new(1, quiet_net());
        mesh.workers[0].store("p", HostValue::f32(vec![2], vec![1.0, 2.0])).unwrap();
        let mut shadow = vec![0.0f32; 3];
        assert!(mesh.reduce_into("p", &mut shadow, "act").is_err());
    }
}
