//! The mesh: a group of simulated accelerators plus the collective layer.
//!
//! `exec_all` dispatches one executable call per rank and joins — the ranks
//! run concurrently on their own threads (the real parallelism in this
//! testbed). Two collectives synchronize them:
//!
//! * `all_reduce` — legacy value-level sum of per-rank partials (scoring,
//!   benches, the serving executor's host-round-trip reference path);
//! * `reduce_into` — the resident-buffer all-reduce of the serving hot
//!   path: gathers each rank's named partial buffer (standing in for the
//!   NVLink ring), sums it into the host shadow of the activation, and
//!   scatters the combined activation back into a named resident buffer on
//!   every rank. One `sync_ops` tick and one α–β charge per call — exactly
//!   the accounting of the all-reduce it replaces, so `table3_profile` and
//!   `all_reduces_per_token` stay honest.
//!
//! ## Host-transfer accounting
//!
//! `MeshMetrics` separately meters *protocol-level* host↔device activation
//! traffic: every `ArgRef::Host` upload and every fetched output that goes
//! through `exec_all` / `exec_rank`, plus explicit `upload_all` pushes of
//! fresh host data (tokens, positions). Byte movement *inside* a collective
//! (`reduce_into`'s gather/scatter, `broadcast_resident`'s fan-out) is
//! simulation mechanics for the device-to-device interconnect and is
//! charged to the α–β model, not to the host counters. Under the resident
//! protocol a decode token costs O(1) host transfers (token ids + positions
//! in, logits out) instead of O(stages).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::config::InterconnectConfig;
use crate::error::{Error, Result};
use crate::parallel::collective::all_reduce_sum;
use crate::parallel::simnet::SimNet;
use crate::parallel::worker::{ArgRef, WorkerHandle};
use crate::runtime::pjrt::HostValue;
use crate::tensor::add_slices;

#[derive(Default, Debug)]
pub struct MeshMetrics {
    /// Number of all-reduce operations performed (value or resident form).
    pub sync_ops: AtomicU64,
    /// Wall time spent in collectives (modelled interconnect + sum), ns.
    pub sync_ns: AtomicU64,
    /// Modelled (α–β) interconnect cost of those collectives, ns. Unlike
    /// `sync_ns` this is deterministic — tests assert on it.
    pub modelled_sync_ns: AtomicU64,
    /// Wall time spent in `exec_all` (shard compute, incl. host<->device), ns.
    pub compute_ns: AtomicU64,
    /// Modelled device compute (flops) charged by the executor. Unlike
    /// `compute_ns` this is deterministic and shape-accurate: the serving
    /// model charges `runtime::buckets::decode_flops_per_lane` per
    /// *dispatched* lane, so a bucketed decode round is billed for the
    /// bucket shape, not the full slot count.
    pub modelled_flops: AtomicU64,
    /// Number of exec_all dispatches.
    pub exec_ops: AtomicU64,
    /// Host→device activation/input uploads initiated by the executor.
    pub host_in_ops: AtomicU64,
    pub host_in_bytes: AtomicU64,
    /// Device→host downloads of fetched outputs.
    pub host_out_ops: AtomicU64,
    pub host_out_bytes: AtomicU64,
}

/// Snapshot of the executor-level host↔device traffic counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostTransfers {
    pub in_ops: u64,
    pub in_bytes: u64,
    pub out_ops: u64,
    pub out_bytes: u64,
}

impl HostTransfers {
    pub fn ops(&self) -> u64 {
        self.in_ops + self.out_ops
    }

    pub fn bytes(&self) -> u64 {
        self.in_bytes + self.out_bytes
    }
}

impl MeshMetrics {
    pub fn reset(&self) {
        self.sync_ops.store(0, Ordering::Relaxed);
        self.sync_ns.store(0, Ordering::Relaxed);
        self.modelled_sync_ns.store(0, Ordering::Relaxed);
        self.compute_ns.store(0, Ordering::Relaxed);
        self.modelled_flops.store(0, Ordering::Relaxed);
        self.exec_ops.store(0, Ordering::Relaxed);
        self.host_in_ops.store(0, Ordering::Relaxed);
        self.host_in_bytes.store(0, Ordering::Relaxed);
        self.host_out_ops.store(0, Ordering::Relaxed);
        self.host_out_bytes.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, f64, f64, u64) {
        (
            self.sync_ops.load(Ordering::Relaxed),
            self.sync_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.compute_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.exec_ops.load(Ordering::Relaxed),
        )
    }

    /// Modelled interconnect cost so far, in milliseconds (deterministic).
    pub fn modelled_sync_ms(&self) -> f64 {
        self.modelled_sync_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Charge modelled device compute (see `modelled_flops`).
    pub fn charge_flops(&self, flops: u64) {
        self.modelled_flops.fetch_add(flops, Ordering::Relaxed);
    }

    /// Modelled device compute charged so far, in flops (deterministic).
    pub fn modelled_flops(&self) -> u64 {
        self.modelled_flops.load(Ordering::Relaxed)
    }

    pub fn host_transfers(&self) -> HostTransfers {
        HostTransfers {
            in_ops: self.host_in_ops.load(Ordering::Relaxed),
            in_bytes: self.host_in_bytes.load(Ordering::Relaxed),
            out_ops: self.host_out_ops.load(Ordering::Relaxed),
            out_bytes: self.host_out_bytes.load(Ordering::Relaxed),
        }
    }

    fn count_host_in(&self, args: &[ArgRef]) {
        for a in args {
            if let ArgRef::Host(v) = a {
                self.host_in_ops.fetch_add(1, Ordering::Relaxed);
                self.host_in_bytes.fetch_add(v.num_bytes() as u64, Ordering::Relaxed);
            }
        }
    }

    fn count_host_out(&self, outs: &[HostValue]) {
        for o in outs {
            self.host_out_ops.fetch_add(1, Ordering::Relaxed);
            self.host_out_bytes.fetch_add(o.num_bytes() as u64, Ordering::Relaxed);
        }
    }
}

pub struct Mesh {
    pub workers: Vec<WorkerHandle>,
    pub net: SimNet,
    pub metrics: MeshMetrics,
}

impl Mesh {
    pub fn new(n_ranks: usize, net_cfg: InterconnectConfig) -> Mesh {
        let workers = (0..n_ranks).map(WorkerHandle::spawn).collect();
        Mesh { workers, net: SimNet::new(net_cfg), metrics: MeshMetrics::default() }
    }

    pub fn ranks(&self) -> usize {
        self.workers.len()
    }

    /// Compile `key` from `path` on every rank.
    pub fn compile_all(&self, key: &str, path: &std::path::Path) -> Result<()> {
        for w in &self.workers {
            w.compile(key, path.to_path_buf())?;
        }
        Ok(())
    }

    /// Run one call per rank concurrently; returns per-rank outputs.
    /// `calls[r]` = (executable key, args, persist, fetch) for rank r.
    #[allow(clippy::type_complexity)]
    pub fn exec_all(
        &self,
        calls: Vec<(String, Vec<ArgRef>, Vec<Option<String>>, Vec<bool>)>,
    ) -> Result<Vec<Vec<HostValue>>> {
        if calls.len() != self.workers.len() {
            return Err(Error::msg(format!(
                "exec_all: {} calls for {} ranks",
                calls.len(),
                self.workers.len()
            )));
        }
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(calls.len());
        for (w, (key, args, persist, fetch)) in self.workers.iter().zip(calls) {
            self.metrics.count_host_in(&args);
            rxs.push(w.exec_async(&key, args, persist, fetch)?);
        }
        let mut outs = Vec::with_capacity(rxs.len());
        for rx in rxs {
            let o = rx
                .recv()
                .map_err(|_| Error::msg("worker died"))?
                .map_err(Error::Msg)?;
            self.metrics.count_host_out(&o);
            outs.push(o);
        }
        self.metrics
            .compute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.metrics.exec_ops.fetch_add(1, Ordering::Relaxed);
        Ok(outs)
    }

    /// Run one call on a single rank, metering its host↔device traffic
    /// (the executor's embed/logits edges go through here).
    pub fn exec_rank(
        &self,
        rank: usize,
        key: &str,
        args: Vec<ArgRef>,
        persist: Vec<Option<String>>,
        fetch: Vec<bool>,
    ) -> Result<Vec<HostValue>> {
        let w = self
            .workers
            .get(rank)
            .ok_or_else(|| Error::msg(format!("exec_rank: no rank {rank}")))?;
        self.metrics.count_host_in(&args);
        let rx = w.exec_async(key, args, persist, fetch)?;
        let o = rx
            .recv()
            .map_err(|_| Error::msg("worker died"))?
            .map_err(Error::Msg)?;
        self.metrics.count_host_out(&o);
        Ok(o)
    }

    /// Scatter a value into a named resident buffer on every rank (fire
    /// all stores, then join). Unmetered — callers decide whether the
    /// movement counts as host traffic or simulated interconnect.
    fn store_all(&self, name: &str, value: &HostValue) -> Result<()> {
        let rxs: Vec<_> = self
            .workers
            .iter()
            .map(|w| w.store_async(name, value.clone()))
            .collect::<Result<_>>()?;
        for rx in rxs {
            rx.recv().map_err(|_| Error::msg("worker died"))?.map_err(Error::Msg)?;
        }
        Ok(())
    }

    /// Push fresh host data (token ids, positions) into a named resident
    /// buffer on every rank. Counted as host→device transfers — this is
    /// real host traffic in any deployment.
    pub fn upload_all(&self, name: &str, value: HostValue) -> Result<()> {
        let bytes = value.num_bytes() as u64;
        self.store_all(name, &value)?;
        self.metrics
            .host_in_ops
            .fetch_add(self.workers.len() as u64, Ordering::Relaxed);
        self.metrics
            .host_in_bytes
            .fetch_add(bytes * self.workers.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Fan a value out into a named resident buffer on every rank. Models
    /// the device-to-device broadcast of an activation already on the mesh
    /// (e.g. rank 0's embedding output), so it is *not* counted as host
    /// traffic; the simulation merely routes the bytes through the
    /// coordinator because the PJRT CPU devices share no interconnect.
    pub fn broadcast_resident(&self, name: &str, value: &HostValue) -> Result<()> {
        self.store_all(name, value)
    }

    /// All-reduce (sum) of per-rank partials: charges the interconnect cost
    /// model and the metrics, returns the combined tensor. (Value-level
    /// form — the serving hot path uses [`Mesh::reduce_into`].)
    pub fn all_reduce(&self, parts: Vec<HostValue>) -> Result<HostValue> {
        let t0 = Instant::now();
        let bytes = parts.first().map(|p| p.num_bytes()).unwrap_or(0);
        let g = parts.len();
        let out = all_reduce_sum(parts)?;
        let modelled = self.net.charge_all_reduce(bytes, g);
        self.metrics.sync_ops.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .modelled_sync_ns
            .fetch_add(modelled.as_nanos() as u64, Ordering::Relaxed);
        self.metrics
            .sync_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Resident-buffer all-reduce: gather the named `partial` buffer from
    /// every rank, sum the partials (rank order, same combinator as
    /// [`Mesh::all_reduce`]), accumulate the sum into the host `shadow` of
    /// the activation, and scatter the combined activation back to every
    /// rank as resident buffer `dest`.
    ///
    /// One `sync_ops` tick and one α–β charge — identical accounting to the
    /// value-level all-reduce it replaces. The gather/scatter legs stand in
    /// for the on-device ring and are not counted as host transfers.
    pub fn reduce_into(&self, partial: &str, shadow: &mut [f32], dest: &str) -> Result<()> {
        let t0 = Instant::now();
        let rxs: Vec<_> = self
            .workers
            .iter()
            .map(|w| w.fetch_async(partial))
            .collect::<Result<_>>()?;
        let mut parts = Vec::with_capacity(rxs.len());
        for rx in rxs {
            parts.push(rx.recv().map_err(|_| Error::msg("worker died"))?.map_err(Error::Msg)?);
        }
        let bytes = parts.first().map(|p| p.num_bytes()).unwrap_or(0);
        let g = parts.len();
        let reduced = all_reduce_sum(parts)?;
        let shape = reduced.shape().to_vec();
        let rdata = reduced.as_f32()?;
        if rdata.len() != shadow.len() {
            return Err(Error::msg(format!(
                "reduce_into: partial `{partial}` has {} elements, shadow {}",
                rdata.len(),
                shadow.len()
            )));
        }
        add_slices(shadow, rdata);
        let modelled = self.net.charge_all_reduce(bytes, g);
        self.metrics.sync_ops.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .modelled_sync_ns
            .fetch_add(modelled.as_nanos() as u64, Ordering::Relaxed);

        let scattered = HostValue::f32(shape, shadow.to_vec());
        self.store_all(dest, &scattered)?;
        self.metrics
            .sync_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_net() -> InterconnectConfig {
        InterconnectConfig { enabled: false, ..Default::default() }
    }

    #[test]
    fn mesh_spawns_and_counts_reduces() {
        let mesh = Mesh::new(2, quiet_net());
        assert_eq!(mesh.ranks(), 2);
        let a = HostValue::f32(vec![4], vec![1.0; 4]);
        let b = HostValue::f32(vec![4], vec![2.0; 4]);
        let r = mesh.all_reduce(vec![a, b]).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[3.0; 4]);
        let (ops, _, _, _) = mesh.metrics.snapshot();
        assert_eq!(ops, 1);
    }

    #[test]
    fn exec_all_arity_checked() {
        let mesh = Mesh::new(2, quiet_net());
        assert!(mesh.exec_all(vec![]).is_err());
    }

    #[test]
    fn simnet_cost_is_charged() {
        // Deterministic: assert on the *charged* α–β cost the SimNet
        // modelled, not on wall-clock (flaky under load).
        let net = InterconnectConfig {
            alpha_s: 500e-6,
            beta_bytes_per_s: 1e12,
            enabled: true,
        };
        let mesh = Mesh::new(1, net.clone());
        // g=1 in all_reduce parts => free even though enabled
        mesh.all_reduce(vec![HostValue::f32(vec![1], vec![0.0])]).unwrap();
        assert_eq!(mesh.metrics.modelled_sync_ns.load(Ordering::Relaxed), 0);
        // two parts => alpha charged, exactly as the cost model says
        mesh.all_reduce(vec![
            HostValue::f32(vec![1], vec![0.0]),
            HostValue::f32(vec![1], vec![0.0]),
        ])
        .unwrap();
        let expect = SimNet::new(net).all_reduce_cost(4, 2).as_nanos() as u64;
        assert!(expect >= 500_000, "alpha term missing from the model");
        assert_eq!(mesh.metrics.modelled_sync_ns.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn metrics_reset() {
        let mesh = Mesh::new(1, quiet_net());
        mesh.all_reduce(vec![HostValue::f32(vec![1], vec![1.0])]).unwrap();
        mesh.metrics.charge_flops(1234);
        assert_eq!(mesh.metrics.modelled_flops(), 1234);
        mesh.metrics.reset();
        assert_eq!(mesh.metrics.modelled_flops(), 0);
        let (ops, sync_ms, comp_ms, execs) = mesh.metrics.snapshot();
        assert_eq!((ops, execs), (0, 0));
        assert_eq!(sync_ms, 0.0);
        assert_eq!(comp_ms, 0.0);
        assert_eq!(mesh.metrics.host_transfers().ops(), 0);
        assert_eq!(mesh.metrics.modelled_sync_ms(), 0.0);
    }

    #[test]
    fn upload_all_counts_host_traffic_and_broadcast_does_not() {
        let mesh = Mesh::new(2, quiet_net());
        let v = HostValue::i32(vec![4], vec![1, 2, 3, 4]);
        mesh.upload_all("pos", v.clone()).unwrap();
        let h = mesh.metrics.host_transfers();
        assert_eq!(h.in_ops, 2);
        assert_eq!(h.in_bytes, 32);
        assert_eq!(h.out_ops, 0);
        mesh.broadcast_resident("act", &v).unwrap();
        assert_eq!(mesh.metrics.host_transfers(), h, "broadcast is interconnect, not host");
    }

    #[test]
    fn reduce_into_gathers_sums_and_scatters() {
        let mesh = Mesh::new(2, quiet_net());
        mesh.workers[0].store("p", HostValue::f32(vec![3], vec![1.0, 2.0, 3.0])).unwrap();
        mesh.workers[1].store("p", HostValue::f32(vec![3], vec![10.0, 20.0, 30.0])).unwrap();
        let mut shadow = vec![0.5f32; 3];
        mesh.reduce_into("p", &mut shadow, "act").unwrap();
        assert_eq!(shadow, vec![11.5, 22.5, 33.5]);
        // combined activation is resident on every rank
        for w in &mesh.workers {
            assert_eq!(w.fetch("act").unwrap().as_f32().unwrap(), &[11.5, 22.5, 33.5]);
        }
        let (ops, _, _, _) = mesh.metrics.snapshot();
        assert_eq!(ops, 1, "reduce_into is one sync op");
        assert_eq!(mesh.metrics.host_transfers().ops(), 0, "collective legs are not host traffic");
    }

    #[test]
    fn reduce_into_rejects_shadow_mismatch() {
        let mesh = Mesh::new(1, quiet_net());
        mesh.workers[0].store("p", HostValue::f32(vec![2], vec![1.0, 2.0])).unwrap();
        let mut shadow = vec![0.0f32; 3];
        assert!(mesh.reduce_into("p", &mut shadow, "act").is_err());
    }
}
