//! PJRT runtime: artifact manifest + executable loading/execution.
//!
//! The request path never touches python: `python/compile/aot.py` lowered
//! every entrypoint to HLO *text* at build time; this module loads the
//! text, compiles it on the PJRT CPU client and executes it.

pub mod artifacts;
pub mod buckets;
pub mod keys;
pub mod pjrt;

pub use artifacts::{
    ArtifactInfo, KvPages, Manifest, ModelConfig, ModelEntry, VariantId, VariantSpec,
};
pub use buckets::{BucketChoice, BucketSet, BucketStats, ExecCache, ExecCacheStats};
pub use pjrt::Engine;
