//! Shape-bucket dispatch: occupancy-proportional decode across the
//! AOT/runtime boundary.
//!
//! The AOT pipeline compiles decode executables per *batch bucket*
//! B ∈ {1, 2, 4, …, S} (`python/compile/aot.py`, manifest key
//! `batch_buckets`), mirroring the `seq_buckets` mechanism for prefill.
//! [`BucketSet`] is the runtime half of that contract: given the number of
//! live KV slots in a decode round it selects the smallest covering bucket
//! ([`BucketSet::select`]) and keeps padded-vs-live lane accounting per
//! bucket ([`BucketSet::stats`]); the bucket's executables are compiled
//! lazily on first dispatch through the model-wide [`ExecCache`].
//!
//! Dispatch rules (the satellite edge cases, each covered by a test):
//!
//! * occupancy 0 → [`BucketChoice::Skip`] — the round runs nothing;
//! * occupancy on an exact bucket boundary → that bucket, zero pad lanes;
//! * occupancy between buckets → next bucket up, `B - live` pad lanes;
//! * occupancy above the largest registered bucket (truncated registry, or
//!   a manifest predating `batch_buckets`) → [`BucketChoice::Full`], the
//!   fixed-`[S]` executables that always exist.
//!
//! Lane mapping: bucket executables take the full `[S, C, w]` KV caches
//! plus an `i32 lanes[B]` vector; lane i gathers slot `lanes[i]`'s cache
//! row, runs the same per-lane step as the full-batch path
//! (`model._decode_step_one` on the python side — the bit-exactness
//! contract), and scatters the updated row back. Pad lanes duplicate the
//! first live lane: the sequential scatter makes a duplicate an idempotent
//! rewrite of the same row with identical bits, so padding is safe without
//! any knowledge of which other slots are live.
//!
//! [`decode_flops_per_lane`] is the modelled device-compute cost one lane
//! pays per decode token; `ServingModel` charges it per dispatched lane
//! into [`crate::parallel::MeshMetrics`] so `bench_decode` and
//! `table3_profile` report compute that scales with the *bucket* shape,
//! not the slot count. [`decode_bytes`] / [`prefill_bytes`] are the
//! matching device-memory traffic models — together they feed the roofline
//! term of `parallel::simnet::CostModel`, which prices each charge in
//! deterministic modelled device time.
//!
//! Under the plan-variant registry (per-request depth tiers) each
//! `model::serving::PlanVariant` owns its own [`BucketSet`], so bucket
//! selection and the live/padded accounting are per-tier, while the
//! *compiled* executables — plan-agnostic by construction — are shared
//! across variants through one [`ExecCache`] (lazy compile on first use,
//! LRU eviction under the `[runtime] max_cached_execs` cap).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::error::Result;
use crate::runtime::artifacts::ModelConfig;

/// Snapshot of [`ExecCache`] accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecCacheStats {
    /// Executables currently compiled on the mesh.
    pub cached: usize,
    /// Compilations performed (first use + recompiles after eviction).
    pub compiles: u64,
    /// Executables evicted to stay under the cap.
    pub evictions: u64,
}

#[derive(Debug, Default)]
struct ExecCacheInner {
    /// Cap on live compiled executables (`None` = unbounded; config knob
    /// `[runtime] max_cached_execs`).
    cap: Option<usize>,
    /// key → last-use tick (the LRU order).
    live: BTreeMap<String, u64>,
    tick: u64,
    compiles: u64,
    evictions: u64,
}

/// LRU cache of compiled executables shared by every plan variant of one
/// serving model.
///
/// The plan-variant registry serves several computational graphs from one
/// compiled pool (the AOT artifacts are plan-agnostic — weights arrive as
/// arguments), so compilation is lazy and centralized here: every dispatch
/// path calls [`ExecCache::ensure`] with exactly the keys it is about to
/// bind, which compiles the missing ones once, refreshes the LRU ticks of
/// the rest, and — when a cap is set — evicts the least-recently-used
/// executables beyond it (never a key of the current call, so a round's
/// working set always stays live even under a cap smaller than it).
/// Eviction is safe by construction: the next round that needs an evicted
/// key just recompiles it.
#[derive(Debug)]
pub struct ExecCache {
    inner: Mutex<ExecCacheInner>,
}

impl ExecCache {
    pub fn new(cap: Option<usize>) -> ExecCache {
        ExecCache {
            inner: Mutex::new(ExecCacheInner { cap, ..Default::default() }),
        }
    }

    /// Change the cap (`None` = unbounded); enforced from the next
    /// [`ExecCache::ensure`] on.
    pub fn set_cap(&self, cap: Option<usize>) {
        self.inner.lock().unwrap().cap = cap;
    }

    /// Make every key in `keys` live: `compile` the missing ones (a failed
    /// compile is not inserted and will be retried on the next call),
    /// touch the LRU tick of the rest, then `evict` least-recently-used
    /// entries outside `keys` until the cap holds. The lock is held across
    /// `compile`, so an executable is never compiled twice under
    /// concurrent callers.
    pub fn ensure(
        &self,
        keys: &[String],
        mut compile: impl FnMut(&str) -> Result<()>,
        mut evict: impl FnMut(&str),
    ) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        for k in keys {
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(t) = inner.live.get_mut(k) {
                *t = tick;
                continue;
            }
            compile(k)?;
            inner.compiles += 1;
            inner.live.insert(k.clone(), tick);
        }
        if let Some(cap) = inner.cap {
            while inner.live.len() > cap {
                let victim = inner
                    .live
                    .iter()
                    .filter(|&(k, _)| !keys.contains(k))
                    .min_by_key(|&(_, t)| *t)
                    .map(|(k, _)| k.clone());
                let Some(v) = victim else { break };
                inner.live.remove(&v);
                inner.evictions += 1;
                evict(&v);
            }
        }
        Ok(())
    }

    /// Whether `key` is currently compiled (tests / diagnostics).
    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().live.contains_key(key)
    }

    pub fn stats(&self) -> ExecCacheStats {
        let inner = self.inner.lock().unwrap();
        ExecCacheStats {
            cached: inner.live.len(),
            compiles: inner.compiles,
            evictions: inner.evictions,
        }
    }
}

/// Outcome of bucket selection for a decode round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BucketChoice {
    /// No live lanes — skip the round entirely.
    Skip,
    /// Dispatch the executables compiled for this batch bucket.
    Bucket(usize),
    /// No covering bucket registered — fall back to the fixed `[S]` path.
    Full,
}

/// Per-bucket dispatch accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BucketStats {
    /// Decode rounds dispatched at this bucket shape.
    pub rounds: u64,
    /// Lanes that carried a live slot.
    pub live_lanes: u64,
    /// Lanes padded with a free slot to fill the bucket shape.
    pub padded_lanes: u64,
}

/// Registry of decode batch buckets for one serving-model plan variant
/// (selection + dispatch accounting; executable compilation lives in the
/// model-wide [`ExecCache`]).
#[derive(Debug)]
pub struct BucketSet {
    /// Ascending bucket shapes available in the manifest (≤ slots).
    buckets: Vec<usize>,
    slots: usize,
    stats: Mutex<BTreeMap<usize, BucketStats>>,
}

impl BucketSet {
    /// Build from the manifest's `batch_buckets` list. Shapes are sorted,
    /// deduplicated and clamped to `(0, slots]`; an empty list (legacy
    /// manifest) makes every selection fall back to [`BucketChoice::Full`].
    pub fn new(buckets: &[usize], slots: usize) -> BucketSet {
        let mut b: Vec<usize> =
            buckets.iter().copied().filter(|&x| x > 0 && x <= slots).collect();
        b.sort_unstable();
        b.dedup();
        BucketSet { buckets: b, slots, stats: Mutex::new(BTreeMap::new()) }
    }

    /// The power-of-two ladder `{1, 2, 4, …, slots}` — mirror of
    /// `python/compile/modelcfg.batch_buckets` for tests and tooling.
    pub fn ladder(slots: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut b = 1;
        while b < slots {
            out.push(b);
            b *= 2;
        }
        out.push(slots);
        out
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Smallest covering bucket for `live` lanes (see module docs for the
    /// Skip / boundary / fallback rules).
    pub fn select(&self, live: usize) -> BucketChoice {
        if live == 0 {
            return BucketChoice::Skip;
        }
        match self.buckets.iter().copied().find(|&b| b >= live) {
            Some(b) => BucketChoice::Bucket(b),
            None => BucketChoice::Full,
        }
    }

    /// Executable keys a bucket dispatch binds, in compile order. The
    /// attention entries additionally take `(kcache, vcache, pos, lanes)`.
    pub fn artifact_keys(bucket: usize) -> Vec<String> {
        vec![
            format!("embed_decode_b{bucket}"),
            format!("logits_decode_b{bucket}"),
            format!("tpattn_decode_b{bucket}"),
            format!("tpffn_decode_b{bucket}"),
            format!("lpattn_decode_b{bucket}"),
            format!("lpffn_decode_b{bucket}"),
        ]
    }

    /// Record one dispatched round: `shape` lanes bound, `live` of them
    /// carrying real slots (Full rounds record under `slots`).
    pub fn record(&self, shape: usize, live: usize) {
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(shape).or_default();
        s.rounds += 1;
        s.live_lanes += live as u64;
        s.padded_lanes += shape.saturating_sub(live) as u64;
    }

    /// Snapshot of per-bucket accounting, ascending by bucket shape.
    pub fn stats(&self) -> Vec<(usize, BucketStats)> {
        self.stats.lock().unwrap().iter().map(|(&b, &s)| (b, s)).collect()
    }
}

/// Modelled device compute of ONE decode lane through `layers_equiv`
/// transformer layers (Tp stage = 1 layer split across ranks, Lp stage = 2
/// whole layers — total mesh flops, not per rank), plus the logits head:
///
/// * attention projections: 4 matmuls `[1,D]·[D,D]` → `8·D²`
/// * cached attention over C positions: QK + AV → `4·C·D`
/// * SwiGLU FFN: 3 matmuls `[1,D]·[D,F]` → `6·D·F`
/// * logits head: `[1,D]·[D,V]` → `2·D·V`
///
/// Deterministic by construction — benches and tests assert that total
/// charged flops scale with the dispatched bucket shape.
pub fn decode_flops_per_lane(cfg: &ModelConfig, layers_equiv: usize) -> u64 {
    let (d, f, c, v) =
        (cfg.d_model as u64, cfg.d_ff as u64, cfg.ctx as u64, cfg.vocab as u64);
    let per_layer = 8 * d * d + 4 * c * d + 6 * d * f;
    layers_equiv as u64 * per_layer + 2 * d * v
}

/// Modelled device compute of prefilling the padded positions
/// `[off, off + n)` of one sequence, plus `logits_rows` rows of the logits
/// head (`T` on the monolithic path, which materializes the full `[T, V]`
/// block; `chunk` on the final chunk step only — earlier chunks skip the
/// head entirely):
///
/// * per token: the same projection (`8·D²`) and SwiGLU (`6·D·F`) cost as a
///   decode lane;
/// * attention at global position p attends its causal prefix: `4·(p+1)·D`
///   (QK + AV). The masked tail columns are exact zeros a real kernel never
///   touches, so the charge is quadratic in the *prompt*, not in the padded
///   executable width — which is exactly why chunked prefill's total scales
///   with `ceil(L / chunk)` chunks while the monolithic path pays the full
///   covering bucket `T` (see `bench_prefill`'s prompt-length sweep).
pub fn prefill_flops(
    cfg: &ModelConfig,
    layers_equiv: usize,
    off: usize,
    n: usize,
    logits_rows: usize,
) -> u64 {
    let (d, f, v) = (cfg.d_model as u64, cfg.d_ff as u64, cfg.vocab as u64);
    let linear_per_tok = 8 * d * d + 6 * d * f;
    // sum of (p + 1) over p in [off, off + n)
    let attended: u64 = (off as u64 + 1..=(off + n) as u64).sum();
    layers_equiv as u64 * (n as u64 * linear_per_tok + 4 * attended * d)
        + logits_rows as u64 * 2 * d * v
}

/// Modelled device-memory traffic (bytes) of one decode round over `lanes`
/// dispatched lanes — the memory side of the roofline the cost model
/// prices ([`crate::parallel::CostModel::compute_cost`]):
///
/// * weights stream once per round regardless of batch (`4·D² + 3·D·F`
///   params per layer-equivalent, plus the logits head `D·V`, `lnf` and the
///   gathered embedding rows) — the term batching amortizes;
/// * per lane per layer: the cached K/V prefix is read (`2·C·D`) and the
///   new row written (`2·D`) — the term that scales with occupancy.
///
/// All f32 (4 bytes/element); activations are O(lanes·D) per stage and
/// folded into the lane term's write. Deterministic by construction.
pub fn decode_bytes(cfg: &ModelConfig, layers_equiv: usize, lanes: usize) -> u64 {
    let (d, f, c, v) =
        (cfg.d_model as u64, cfg.d_ff as u64, cfg.ctx as u64, cfg.vocab as u64);
    let le = layers_equiv as u64;
    let lanes = lanes as u64;
    let weights = le * (4 * d * d + 3 * d * f) + d * v + d + lanes * d;
    let kv = lanes * le * (2 * c * d + 2 * d);
    4 * (weights + kv)
}

/// Modelled device-memory traffic (bytes) of prefilling the padded
/// positions `[off, off + n)` of one sequence — the memory companion of
/// [`prefill_flops`], with the same shape rules (`logits_rows` > 0 adds the
/// head weights; the attention read is proportional to the attended
/// prefix, so chunked prefill's total scales with `ceil(L / K)` chunk
/// passes while each pass re-streams the layer weights once):
///
/// * per pass: layer weights `4·D² + 3·D·F` per layer-equivalent, the
///   embedding rows `n·D`, and (final chunk / monolithic only) the logits
///   head `D·V + D`;
/// * per token: its K/V row written (`2·D` per layer) and the causal
///   prefix read (`2·(p+1)·D` at global position p).
pub fn prefill_bytes(
    cfg: &ModelConfig,
    layers_equiv: usize,
    off: usize,
    n: usize,
    logits_rows: usize,
) -> u64 {
    let (d, f, v) = (cfg.d_model as u64, cfg.d_ff as u64, cfg.vocab as u64);
    let le = layers_equiv as u64;
    let attended: u64 = (off as u64 + 1..=(off + n) as u64).sum();
    let weights = le * (4 * d * d + 3 * d * f)
        + n as u64 * d
        + if logits_rows > 0 { d * v + d } else { 0 };
    let kv = le * (2 * n as u64 * d + 2 * attended * d);
    4 * (weights + kv)
}

/// Modelled device compute a follower request SAVES by attaching
/// `shared_tokens` of prefix from the paged-KV index instead of prefilling
/// them (`model::kvcache::PagedKv::attach_prefix`): exactly the chunk
/// charges the skipped steps would have incurred — `Σ_j prefill_flops(j·k,
/// k, 0)` over the skipped chunks, none of which is final (the final chunk
/// is never shared), so no logits rows. By additivity of the prefill model
/// over contiguous splits this equals one `[0, shared_tokens)` pass.
/// `shared_tokens` is a whole number of blocks (`attach_prefix` only
/// matches full blocks of the page size `k`).
pub fn prefix_shared_flops(
    cfg: &ModelConfig,
    layers_equiv: usize,
    shared_tokens: usize,
    k: usize,
) -> u64 {
    debug_assert!(k > 0 && shared_tokens % k == 0, "shared prefix is whole blocks");
    (0..shared_tokens / k).map(|j| prefill_flops(cfg, layers_equiv, j * k, k, 0)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set() -> BucketSet {
        BucketSet::new(&[1, 2, 4], 4)
    }

    #[test]
    fn ladder_matches_python_batch_buckets() {
        assert_eq!(BucketSet::ladder(1), vec![1]);
        assert_eq!(BucketSet::ladder(4), vec![1, 2, 4]);
        assert_eq!(BucketSet::ladder(6), vec![1, 2, 4, 6]);
        assert_eq!(BucketSet::ladder(8), vec![1, 2, 4, 8]);
    }

    #[test]
    fn occupancy_zero_skips_the_round() {
        assert_eq!(set().select(0), BucketChoice::Skip);
    }

    #[test]
    fn exact_boundary_selects_that_bucket() {
        let s = set();
        assert_eq!(s.select(1), BucketChoice::Bucket(1));
        assert_eq!(s.select(2), BucketChoice::Bucket(2));
        assert_eq!(s.select(4), BucketChoice::Bucket(4));
    }

    #[test]
    fn between_buckets_rounds_up() {
        assert_eq!(set().select(3), BucketChoice::Bucket(4));
    }

    #[test]
    fn occupancy_above_largest_bucket_falls_back_to_full() {
        // truncated registry: buckets stop below the slot count
        let s = BucketSet::new(&[1, 2], 8);
        assert_eq!(s.select(2), BucketChoice::Bucket(2));
        assert_eq!(s.select(5), BucketChoice::Full);
        // legacy manifest with no batch_buckets section at all
        let legacy = BucketSet::new(&[], 8);
        assert_eq!(legacy.select(1), BucketChoice::Full);
        assert_eq!(legacy.select(0), BucketChoice::Skip);
    }

    #[test]
    fn new_clamps_and_sorts_shapes() {
        let s = BucketSet::new(&[4, 2, 0, 2, 9], 4);
        assert_eq!(s.buckets(), &[2, 4]);
        assert_eq!(s.slots(), 4);
    }

    #[test]
    fn stats_account_live_and_padded_lanes() {
        let s = set();
        s.record(2, 2); // exact fit
        s.record(4, 3); // one pad lane
        s.record(4, 3);
        let stats = s.stats();
        assert_eq!(
            stats,
            vec![
                (2, BucketStats { rounds: 1, live_lanes: 2, padded_lanes: 0 }),
                (4, BucketStats { rounds: 2, live_lanes: 6, padded_lanes: 2 }),
            ]
        );
    }

    #[test]
    fn flop_model_scales_with_depth_and_width() {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 260,
            d_model: 128,
            n_layers: 12,
            n_heads: 4,
            head_dim: 32,
            d_ff: 256,
            ctx: 256,
            slots: 4,
        };
        let f6 = decode_flops_per_lane(&cfg, 6);
        let f12 = decode_flops_per_lane(&cfg, 12);
        assert!(f12 > f6);
        let head = 2 * cfg.d_model as u64 * cfg.vocab as u64;
        assert_eq!(f12 - head, 2 * (f6 - head), "per-layer cost is linear in depth");
    }

    #[test]
    fn prefill_flop_model_scales_with_chunks_not_buckets() {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 260,
            d_model: 128,
            n_layers: 12,
            n_heads: 4,
            head_dim: 32,
            d_ff: 256,
            ctx: 256,
            slots: 4,
        };
        // chunked prefill of L=40 under chunk=32: two chunk steps, logits
        // only on the final one — identical total to one [0, 64) pass
        let chunked = prefill_flops(&cfg, 6, 0, 32, 0) + prefill_flops(&cfg, 6, 32, 32, 32);
        assert_eq!(chunked, prefill_flops(&cfg, 6, 0, 64, 32));
        // the covering bucket T=128 pays for 128 padded tokens and the
        // full [128, V] logits block — strictly more than 2 chunks
        let mono = prefill_flops(&cfg, 6, 0, 128, 128);
        assert!(mono > 2 * chunked, "mono {mono} vs chunked {chunked}");
        // attention term is quadratic: a later chunk costs more than an
        // earlier one at equal width
        assert!(
            prefill_flops(&cfg, 6, 64, 32, 0) > prefill_flops(&cfg, 6, 0, 32, 0),
            "prefix-proportional attention charge missing"
        );
    }

    /// The prefix-reuse saving is honest: it equals the sum of the chunk
    /// charges the follower skips, which (by additivity of the prefill
    /// model over contiguous splits) is one logits-free pass over the
    /// shared tokens — and the follower's remaining charge tops it back up
    /// to the full-prompt total.
    #[test]
    fn prefix_shared_flops_matches_the_skipped_chunk_charges() {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 260,
            d_model: 128,
            n_layers: 12,
            n_heads: 4,
            head_dim: 32,
            d_ff: 256,
            ctx: 256,
            slots: 4,
        };
        let (le, k) = (6, 32);
        let saved = prefix_shared_flops(&cfg, le, 3 * k, k);
        let by_chunks = prefill_flops(&cfg, le, 0, k, 0)
            + prefill_flops(&cfg, le, k, k, 0)
            + prefill_flops(&cfg, le, 2 * k, k, 0);
        assert_eq!(saved, by_chunks);
        assert_eq!(saved, prefill_flops(&cfg, le, 0, 3 * k, 0), "additive over splits");
        // saving + the follower's one remaining (final, logits-bearing)
        // chunk = the leader's full 4-chunk prompt charge
        let follower = prefill_flops(&cfg, le, 3 * k, k, k);
        let leader: u64 = (0..4)
            .map(|j| prefill_flops(&cfg, le, j * k, k, if j == 3 { k } else { 0 }))
            .sum();
        assert_eq!(saved + follower, leader);
        assert_eq!(prefix_shared_flops(&cfg, le, 0, k), 0, "no match, no saving");
    }

    #[test]
    fn byte_model_amortizes_weights_and_scales_kv_per_lane() {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 260,
            d_model: 128,
            n_layers: 12,
            n_heads: 4,
            head_dim: 32,
            d_ff: 256,
            ctx: 256,
            slots: 4,
        };
        let b1 = decode_bytes(&cfg, 6, 1);
        let b2 = decode_bytes(&cfg, 6, 2);
        let b4 = decode_bytes(&cfg, 6, 4);
        // monotone in lanes, but sublinear: the weight stream is shared
        assert!(b1 < b2 && b2 < b4);
        assert!(b4 < 4 * b1, "weights must amortize across lanes");
        // the per-lane increment is constant (pure KV + embedding row)
        assert_eq!(2 * (b2 - b1), b4 - b2);
        // monotone in depth
        assert!(decode_bytes(&cfg, 12, 2) > decode_bytes(&cfg, 6, 2));

        // prefill: chunk passes re-stream weights, so 2 chunks cost more
        // bytes than one pass over the same tokens...
        let chunked =
            prefill_bytes(&cfg, 6, 0, 32, 0) + prefill_bytes(&cfg, 6, 32, 32, 32);
        let one_pass = prefill_bytes(&cfg, 6, 0, 64, 32);
        assert!(chunked > one_pass);
        // ...but the K/V read term is prefix-proportional either way: a
        // later chunk reads a longer prefix than an earlier one
        assert!(prefill_bytes(&cfg, 6, 64, 32, 0) > prefill_bytes(&cfg, 6, 0, 32, 0));
        // the logits head weights only appear when logits rows are priced
        assert!(prefill_bytes(&cfg, 6, 0, 32, 32) > prefill_bytes(&cfg, 6, 0, 32, 0));
    }

    fn keys(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn exec_cache_compiles_once_and_counts() {
        let c = ExecCache::new(None);
        let mut compiled = Vec::new();
        c.ensure(&keys(&["a", "b"]), |k| Ok(compiled.push(k.to_string())), |_| {}).unwrap();
        c.ensure(&keys(&["a", "b"]), |k| Ok(compiled.push(k.to_string())), |_| {}).unwrap();
        assert_eq!(compiled, vec!["a", "b"], "second ensure must be a no-op");
        let st = c.stats();
        assert_eq!((st.cached, st.compiles, st.evictions), (2, 2, 0));
        assert!(c.contains("a") && !c.contains("z"));
    }

    #[test]
    fn exec_cache_failed_compile_is_retried() {
        let c = ExecCache::new(None);
        assert!(c
            .ensure(&keys(&["a"]), |_| Err(crate::Error::msg("boom")), |_| {})
            .is_err());
        assert!(!c.contains("a"), "failed compile must not be cached");
        c.ensure(&keys(&["a"]), |_| Ok(()), |_| {}).unwrap();
        assert!(c.contains("a"));
    }

    #[test]
    fn exec_cache_evicts_lru_beyond_cap() {
        let c = ExecCache::new(Some(2));
        let mut evicted = Vec::new();
        c.ensure(&keys(&["a"]), |_| Ok(()), |_| {}).unwrap();
        c.ensure(&keys(&["b"]), |_| Ok(()), |_| {}).unwrap();
        // touch `a` so `b` becomes the LRU victim
        c.ensure(&keys(&["a"]), |_| Ok(()), |_| {}).unwrap();
        c.ensure(&keys(&["c"]), |_| Ok(()), |k| evicted.push(k.to_string())).unwrap();
        assert_eq!(evicted, vec!["b"]);
        assert!(c.contains("a") && c.contains("c") && !c.contains("b"));
        assert_eq!(c.stats().evictions, 1);
        // an evicted key recompiles on next use
        let mut recompiled = 0;
        c.ensure(
            &keys(&["b"]),
            |_| {
                recompiled += 1;
                Ok(())
            },
            |k| evicted.push(k.to_string()),
        )
        .unwrap();
        assert_eq!(recompiled, 1);
    }

    #[test]
    fn exec_cache_never_evicts_the_current_working_set() {
        // cap smaller than one round's key set: the round still runs (all
        // its keys stay live); only foreign entries get evicted
        let c = ExecCache::new(Some(1));
        let round = keys(&["x", "y", "z"]);
        c.ensure(&keys(&["old"]), |_| Ok(()), |_| {}).unwrap();
        let mut evicted = Vec::new();
        c.ensure(&round, |_| Ok(()), |k| evicted.push(k.to_string())).unwrap();
        assert_eq!(evicted, vec!["old"]);
        assert_eq!(c.stats().cached, 3, "working set must survive a tiny cap");
        // raising / clearing the cap is dynamic
        c.set_cap(None);
        c.ensure(&keys(&["w"]), |_| Ok(()), |_| panic!("unbounded")).unwrap();
        assert_eq!(c.stats().cached, 4);
    }

    #[test]
    fn artifact_keys_cover_all_six_entrypoints() {
        let keys = BucketSet::artifact_keys(2);
        assert_eq!(keys.len(), 6);
        for k in &keys {
            assert!(k.ends_with("_b2"), "{k}");
        }
        assert!(keys.contains(&"embed_decode_b2".to_string()));
        assert!(keys.contains(&"lpattn_decode_b2".to_string()));
    }
}
