//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (artifact names, file paths, argument shapes, model
//! hyper-parameters).
//!
//! ## Schema (format 1)
//!
//! ```text
//! { "format": 1, "source_hash": "...", "impl": "pallas",
//!   "seq_buckets": [32, 128, 256],          // prefill T buckets (global)
//!   "prefill_chunk": 32,                    // streaming-prefill chunk K
//!   "models": { "<name>": {
//!       "config": { vocab, d_model, n_layers, ... , slots },
//!       "batch_buckets": [1, 2, 4],         // decode B buckets (per model,
//!                                           // derived from `slots`)
//!       "kv_pages": {                       // paged-KV pool geometry
//!           "page_tokens": 32,              //   tokens per page (divides
//!                                           //   prefill_chunk and ctx)
//!           "blocks_per_slot": 8,           //   ctx / page_tokens
//!           "pool_pages_half": 513,         //   per-width pool page counts
//!           "pool_pages_full": 321 },       //   (incl. scratch page 0)
//!       "variants": {                       // plan-variant registry
//!           "dense":   { "stages": [[0], [1], ...] },
//!           "lp":      { "stages": [[0], [1], [2, 3], ...] },
//!           "lp_aggr": { "stages": [[0, 1], [2, 3], ...] } },
//!       "artifacts": { "<key>": { "file": "...", "args": [
//!           { "name": "...", "dtype": "...", "shape": [...] }, ... ] } } } } }
//! ```
//!
//! `batch_buckets` (added with the shape-bucket dispatch subsystem) names
//! the decode batch shapes B for which per-bucket executables exist —
//! `{tp,lp}attn_decode_b{B}` (full `[S, C, w]` caches + `i32 lanes[B]`),
//! `{tp,lp}ffn_decode_b{B}`, `embed_decode_b{B}`, `logits_decode_b{B}` —
//! each with its own argument signature under `artifacts` like any other
//! entry. The section is optional: manifests that predate it parse with an
//! empty list and `runtime::buckets::BucketSet` then routes every round to
//! the fixed-`[S]` executables.
//!
//! `prefill_chunk` (added with the chunked streaming-prefill subsystem)
//! gives the fixed chunk token count K of the resumable prefill executables
//! `{tp,lp}attn_chunk` (chunk activations + full `[S, C, w]` caches +
//! `slot`/`off`/`valid` i32 scalars; the attention inserts its own K/V
//! rows, masked by `valid`), `{tp,lp}ffn_chunk`, `embed_chunk` and
//! `logits_chunk`. K always divides every model's `ctx` (the AOT side
//! asserts it), so the final chunk's cache window stays in bounds. The
//! section is optional: legacy manifests parse with `None` and
//! `model::prefill` then routes every prompt through the monolithic
//! fixed-`T` path in a single step.
//!
//! ## Paged KV cache (`kv_pages`)
//!
//! `kv_pages` (added with the paged-KV subsystem) records the page-pool
//! geometry the paged attention executables were lowered against. Instead
//! of one dense `[S, C, w]` cache per stage per tier, K/V rows live in two
//! shared per-rank pools — `kvpool.half.{k,v}` shaped
//! `[pool_pages_half, page_tokens, D/2]` for TP stages and
//! `kvpool.full.{k,v}` shaped `[pool_pages_full, page_tokens, D]` for LP
//! stages — and the paged executables (`{tp,lp}attn_chunk_paged`,
//! `{tp,lp}attn_decode_paged_b{B}`) reach a sequence's rows through an
//! `i32` page-table operand `pt` (`[blocks_per_slot]` per chunk step,
//! `[B, blocks_per_slot]` per decode bucket) that maps context block `j`
//! to a pool page id. Page 0 is reserved scratch: unmapped table entries
//! point at it, and the causal mask keeps its (finite) garbage out of
//! every output bit. Pool page counts are the dense-equivalent worst case
//! (every stage of every variant × slots × blocks, + scratch), so a
//! runtime that admits what dense admitted never exhausts the compiled
//! pool shape; tighter budgets are runtime policy
//! (`model::kvcache::PagedKv::set_page_capacity`). The section is
//! optional — and paging is opt-in at runtime even when present
//! (`model::serving::ServingModel::enable_paging`); the dense caches
//! remain the bit-exactness oracle.
//!
//! ## Plan-variant registry (`variants`)
//!
//! `variants` (added with the per-request depth-tier redesign) names the
//! serving tiers one weight set supports. Each [`VariantSpec`] is a stage
//! list: `[i]` executes original layer `i` TP-sharded across the mesh
//! (`{tp}attn/ffn` executable family), `[a, b]` executes the pair as one
//! Layer-Parallelism stage (rank 0 runs layer `a`, rank 1 layer `b`, full
//! width — `{lp}` family). Variants add **no executables**: every stage,
//! embed, logits, chunk and bucket executable above is plan-agnostic
//! (weights arrive as arguments), so all tiers share the compiled pool and
//! the section only records which stages each tier walks.
//! `model::serving::ServingModel::from_manifest` builds every listed
//! variant over one resident weight set and serves them concurrently,
//! keyed by [`VariantId`] (the tier name a `RequestOptions::tier`
//! selects). The section is optional: legacy manifests parse with a single
//! synthesized `dense` variant (the sequential plan over `n_layers`), so
//! the registry degrades to exactly the pre-redesign single-plan serving.
//!
//! ## Invariants (statically verified)
//!
//! [`Manifest::load`] runs the `crate::verify` pass over every parsed
//! manifest and rejects it — at load time, with `VariantId`-qualified
//! diagnostics — if any of these invariants fail:
//!
//! * **Coverage** — every variant covers each of the model's `n_layers`
//!   transformer layers exactly once; stage arity is 1 (TP) or 2 (LP pair);
//!   LP pairs are adjacent `[i, i+1]`. (Pairs forming a non-contiguous band
//!   are a warning: servable, but not a shape the paper's transform emits.)
//! * **Executables** — every executable a variant's stage walk dispatches
//!   (decode, per-`seq_buckets` prefill, chunk when `prefill_chunk` is set)
//!   exists in the `artifacts` section. Missing per-`batch_buckets`
//!   executables are a warning (the runtime falls back to fixed-`[S]`).
//! * **Buckets/chunk** — `batch_buckets` unique and within `slots`;
//!   `prefill_chunk` divides every model's `ctx`.
//! * **KV pages** — when `kv_pages` is present: `page_tokens` divides
//!   `prefill_chunk` and `ctx` (`blocks_per_slot` consistent), and each
//!   pool holds at least one slot-worth of blocks per configured slot plus
//!   the scratch page (`slots · blocks_per_slot + 1`) so admission can
//!   always place the dense-equivalent working set.
//! * **Bindings** — abstract interpretation of each variant's dispatch
//!   sequence: every resident buffer is written before read, no executable
//!   is used after release, and every weight key (`l{i}.tp.*` /
//!   `l{i}.full.*`) and KV key (`kv.{tier}.*`) a stage references exists in
//!   the resident set the loader would build.
//! * **Collectives** — all ranks issue the same collective sequence with
//!   identical payload shapes, so a rank-divergent plan is a load-time
//!   error instead of a serving-time deadlock.
//!
//! In addition the *parser itself* rejects malformed sections outright
//! (duplicate JSON keys — e.g. two variants with one id — via
//! `util::json`; a present-but-empty `variants` section; non-numeric or
//! duplicate `batch_buckets` / `seq_buckets`; a zero `prefill_chunk`)
//! rather than silently coercing them. `Manifest::load_strict` additionally
//! promotes warnings to errors and checks artifact files on disk;
//! `Manifest::load_unverified` parses without the verify pass (the `verify`
//! CLI uses it so it can render *all* diagnostics, not just the first
//! error).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Value;

/// Model hyper-parameters (mirror of `python/compile/modelcfg.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub ctx: usize,
    pub slots: usize,
}

impl ModelConfig {
    fn from_json(v: &Value) -> Result<ModelConfig> {
        let u = |k: &str| -> Result<usize> {
            v.req(k)?
                .as_usize()
                .ok_or_else(|| Error::msg(format!("config key `{k}` not a number")))
        };
        Ok(ModelConfig {
            name: v
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::msg("config name not a string"))?
                .to_string(),
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            d_ff: u("d_ff")?,
            ctx: u("ctx")?,
            slots: u("slots")?,
        })
    }

    /// Approximate parameter count (same formula as the python side).
    pub fn n_params(&self) -> usize {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        let per_layer = 2 * d + 4 * d * d + 3 * d * f;
        v * d + self.n_layers * per_layer + d + d * v
    }
}

/// One AOT-compiled executable: path + argument signature.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    /// (arg_name, dtype, shape)
    pub args: Vec<(String, String, Vec<usize>)>,
}

/// Identifier of a plan variant — the key of the manifest `variants`
/// section and the serving-tier name a request selects
/// (`coordinator::request::RequestOptions::tier`).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct VariantId(String);

impl VariantId {
    pub fn new(name: impl Into<String>) -> VariantId {
        VariantId(name.into())
    }

    /// The baseline full-depth tier every multi-variant manifest carries
    /// (and the tier legacy manifests synthesize).
    pub fn dense() -> VariantId {
        VariantId("dense".into())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl std::fmt::Display for VariantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(&self.0)
    }
}

impl From<&str> for VariantId {
    fn from(s: &str) -> VariantId {
        VariantId(s.to_string())
    }
}

/// One named plan variant: the stage walk a serving tier executes (see the
/// module docs for the `[i]` / `[a, b]` encoding). Converted to a
/// `model::plan::GraphPlan` via `GraphPlan::from_stage_lists`, which also
/// validates layer reuse/range.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VariantSpec {
    pub id: VariantId,
    /// One entry per effective layer: 1 index = TP-sharded stage, 2 = LP
    /// pair.
    pub stages: Vec<Vec<usize>>,
}

impl VariantSpec {
    /// The synthesized full-depth sequential variant (legacy-manifest
    /// fallback).
    pub fn dense(n_layers: usize) -> VariantSpec {
        VariantSpec {
            id: VariantId::dense(),
            stages: (0..n_layers).map(|i| vec![i]).collect(),
        }
    }
}

/// Paged-KV pool geometry (the per-model `kv_pages` manifest section —
/// see the module docs). `page_tokens` rows of one stage of one sequence
/// per page; pool page counts include the reserved scratch page 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPages {
    /// Tokens per page (the vLLM block size). Divides `prefill_chunk` and
    /// every model `ctx`.
    pub page_tokens: usize,
    /// Page-table length: `ctx / page_tokens`.
    pub blocks_per_slot: usize,
    /// Pages in the half-width pool (TP stages, w = D/2 per rank).
    pub pool_pages_half: usize,
    /// Pages in the full-width pool (LP stages, w = D per rank).
    pub pool_pages_full: usize,
}

impl KvPages {
    /// Minimum pool size admission relies on: every configured slot can
    /// hold a full context of one stage, plus the scratch page.
    pub fn min_pool_pages(&self, slots: usize) -> usize {
        slots * self.blocks_per_slot + 1
    }
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub config: ModelConfig,
    /// Decode batch buckets with compiled per-bucket executables (ascending;
    /// empty for manifests predating the `batch_buckets` section).
    pub batch_buckets: Vec<usize>,
    /// Paged-KV pool geometry (`None` for manifests predating the
    /// `kv_pages` section — serving then has no paged path to opt into).
    pub kv_pages: Option<KvPages>,
    /// Plan-variant registry: the serving tiers this weight set supports,
    /// in `VariantId` order. Manifests predating the `variants` section
    /// get a single synthesized `dense` (sequential) variant.
    pub variants: BTreeMap<VariantId, VariantSpec>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ModelEntry {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::MissingArtifact(format!("{}:{}", self.config.name, name)))
    }
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub impl_name: String,
    pub seq_buckets: Vec<usize>,
    /// Streaming-prefill chunk token count K (`None` for legacy manifests
    /// predating the `prefill_chunk` section — prefill then runs the
    /// monolithic fixed-`T` path).
    pub prefill_chunk: Option<usize>,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    /// Parse `dir/manifest.json` and reject it with `VariantId`-qualified
    /// diagnostics if the static verification pass (`crate::verify`) finds
    /// any error — see the module-level *Invariants* section.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let m = Self::parse(dir)?;
        crate::verify::check_load(&m)?;
        Ok(m)
    }

    /// Strict load: the verify pass additionally checks that every
    /// artifact file exists on disk, and *any* finding — warnings
    /// included — rejects the manifest. The CI artifact-verification gate
    /// goes through here (`bin/verify_artifacts.rs`).
    pub fn load_strict(dir: &Path) -> Result<Manifest> {
        let m = Self::parse(dir)?;
        crate::verify::check_strict(&m)?;
        Ok(m)
    }

    /// Parse without the verify pass. The `truedepth verify` CLI uses this
    /// so it can render *every* diagnostic instead of failing on the first
    /// error; everything that serves should go through [`Manifest::load`].
    pub fn load_unverified(dir: &Path) -> Result<Manifest> {
        Self::parse(dir)
    }

    fn parse(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::msg(format!(
                "cannot read {}/manifest.json (run `make artifacts` first): {e}",
                dir.display()
            ))
        })?;
        let v = Value::parse(&text)?;
        let mut models = BTreeMap::new();
        for (mname, entry) in v
            .req("models")?
            .as_obj()
            .ok_or_else(|| Error::msg("manifest `models` not an object"))?
        {
            let config = ModelConfig::from_json(entry.req("config")?)?;
            // Strict bucket parsing: a non-numeric or duplicate entry must
            // not silently vanish from the registry (the runtime would then
            // quietly never route to that bucket's executables).
            let mut batch_buckets: Vec<usize> = Vec::new();
            if let Some(bb) = entry.get("batch_buckets") {
                let arr = bb.as_arr().ok_or_else(|| {
                    Error::msg(format!("{mname}: `batch_buckets` not an array"))
                })?;
                for b in arr {
                    let b = b
                        .as_f64()
                        .filter(|f| f.fract() == 0.0 && *f > 0.0)
                        .map(|f| f as usize)
                        .ok_or_else(|| {
                            Error::msg(format!(
                                "{mname}: `batch_buckets` entry not a positive integer"
                            ))
                        })?;
                    if batch_buckets.contains(&b) {
                        return Err(Error::msg(format!(
                            "{mname}: duplicate batch bucket {b}"
                        )));
                    }
                    batch_buckets.push(b);
                }
            }
            // Strict kv_pages parsing: a malformed geometry must error here
            // rather than silently serving unpaged (the paged executables
            // were lowered against these exact pool shapes).
            let kv_pages = match entry.get("kv_pages") {
                None | Some(Value::Null) => None,
                Some(kp) => {
                    let u = |k: &str| -> Result<usize> {
                        kp.req(k)?
                            .as_f64()
                            .filter(|f| f.fract() == 0.0 && *f > 0.0)
                            .map(|f| f as usize)
                            .ok_or_else(|| {
                                Error::msg(format!(
                                    "{mname}: `kv_pages.{k}` must be a positive integer"
                                ))
                            })
                    };
                    Some(KvPages {
                        page_tokens: u("page_tokens")?,
                        blocks_per_slot: u("blocks_per_slot")?,
                        pool_pages_half: u("pool_pages_half")?,
                        pool_pages_full: u("pool_pages_full")?,
                    })
                }
            };
            let mut variants = BTreeMap::new();
            if let Some(vsec) = entry.get("variants") {
                let vs = vsec.as_obj().ok_or_else(|| {
                    Error::msg(format!("{mname}: `variants` not an object"))
                })?;
                if vs.is_empty() {
                    // an empty registry would serve *no* tiers; only a fully
                    // absent section means "legacy manifest, synthesize dense"
                    return Err(Error::msg(format!(
                        "{mname}: `variants` section is empty — list at least one \
                         tier, or delete the section to get the legacy synthesized \
                         `dense` variant"
                    )));
                }
                for (vname, vspec) in vs {
                    // Strict parsing: a malformed variant must error here,
                    // not serve a silently-wrong graph (e.g. a non-array
                    // `stages` must not decay to a zero-stage tier, and a
                    // non-numeric layer entry must not shrink an LP pair
                    // into a TP stage).
                    let raw = vspec.req("stages")?.as_arr().ok_or_else(|| {
                        Error::msg(format!(
                            "{mname}: variant `{vname}` stages not an array"
                        ))
                    })?;
                    if raw.is_empty() {
                        return Err(Error::msg(format!(
                            "{mname}: variant `{vname}` has no stages"
                        )));
                    }
                    let mut stages = Vec::new();
                    for st in raw {
                        let layers = st.as_arr().ok_or_else(|| {
                            Error::msg(format!(
                                "{mname}: variant `{vname}` stage not an array"
                            ))
                        })?;
                        let idx: Vec<usize> = layers
                            .iter()
                            .filter_map(|x| x.as_usize())
                            .collect();
                        if idx.len() != layers.len() || idx.is_empty() || idx.len() > 2 {
                            return Err(Error::msg(format!(
                                "{mname}: variant `{vname}` stage {layers:?} \
                                 malformed (want 1 or 2 layer indices)"
                            )));
                        }
                        stages.push(idx);
                    }
                    let id = VariantId::new(vname.clone());
                    variants.insert(id.clone(), VariantSpec { id, stages });
                }
            }
            if variants.is_empty() {
                // legacy manifest: serve a single synthesized dense tier
                let spec = VariantSpec::dense(config.n_layers);
                variants.insert(spec.id.clone(), spec);
            }
            let mut artifacts = BTreeMap::new();
            for (aname, a) in entry
                .req("artifacts")?
                .as_obj()
                .ok_or_else(|| Error::msg("artifacts not an object"))?
            {
                let file = dir.join(
                    a.req("file")?
                        .as_str()
                        .ok_or_else(|| Error::msg("artifact file not a string"))?,
                );
                let mut args = Vec::new();
                for arg in a.req("args")?.as_arr().unwrap_or(&[]) {
                    let name = arg.req("name")?.as_str().unwrap_or("?").to_string();
                    let dtype = arg.req("dtype")?.as_str().unwrap_or("?").to_string();
                    let shape = arg
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect();
                    args.push((name, dtype, shape));
                }
                artifacts.insert(
                    aname.clone(),
                    ArtifactInfo { name: aname.clone(), file, args },
                );
            }
            models.insert(
                mname.clone(),
                ModelEntry { config, batch_buckets, kv_pages, variants, artifacts },
            );
        }
        let mut seq_buckets: Vec<usize> = Vec::new();
        for b in v
            .req("seq_buckets")?
            .as_arr()
            .ok_or_else(|| Error::msg("manifest `seq_buckets` not an array"))?
        {
            let b = b
                .as_f64()
                .filter(|f| f.fract() == 0.0 && *f > 0.0)
                .map(|f| f as usize)
                .ok_or_else(|| {
                    Error::msg("manifest `seq_buckets` entry not a positive integer")
                })?;
            if seq_buckets.contains(&b) {
                return Err(Error::msg(format!("duplicate seq bucket {b}")));
            }
            seq_buckets.push(b);
        }
        let prefill_chunk = match v.get("prefill_chunk") {
            None | Some(Value::Null) => None,
            Some(c) => Some(
                c.as_f64()
                    .filter(|f| f.fract() == 0.0 && *f > 0.0)
                    .map(|f| f as usize)
                    .ok_or_else(|| {
                        Error::msg("manifest `prefill_chunk` must be a positive integer")
                    })?,
            ),
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            impl_name: v
                .req("impl")?
                .as_str()
                .unwrap_or("pallas")
                .to_string(),
            seq_buckets,
            prefill_chunk,
            models,
        })
    }

    /// Load from the repo's default `artifacts/` directory.
    pub fn load_default() -> Result<Manifest> {
        Self::load(&crate::repo_root().join("artifacts"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| Error::msg(format!("model `{name}` not in manifest")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load_default().ok()
    }

    #[test]
    fn manifest_loads_and_has_models() {
        let Some(m) = manifest() else { return };
        assert!(m.models.contains_key("td-small"));
        assert!(m.models.contains_key("td-base"));
        assert_eq!(m.seq_buckets, vec![32, 128, 256]);
    }

    #[test]
    fn batch_buckets_match_ladder_and_have_artifacts() {
        let Some(m) = manifest() else { return };
        for entry in m.models.values() {
            let slots = entry.config.slots;
            assert_eq!(
                entry.batch_buckets,
                crate::runtime::BucketSet::ladder(slots),
                "{}: stale batch_buckets (re-run `make artifacts`)",
                entry.config.name
            );
            for &b in &entry.batch_buckets {
                for key in crate::runtime::BucketSet::artifact_keys(b) {
                    assert!(
                        entry.artifacts.contains_key(&key),
                        "{}: bucket {b} missing artifact {key}",
                        entry.config.name
                    );
                }
            }
        }
    }

    #[test]
    fn bucket_attn_artifacts_carry_full_caches_and_lanes() {
        let Some(m) = manifest() else { return };
        let e = m.model("td-small").unwrap();
        let cfg = &e.config;
        for &b in &e.batch_buckets {
            let a = e.artifact(&format!("tpattn_decode_b{b}")).unwrap();
            let names: Vec<&str> = a.args.iter().map(|(n, _, _)| n.as_str()).collect();
            assert_eq!(
                names,
                ["x", "ln1", "wq", "wk", "wv", "wo", "kcache", "vcache", "pos", "lanes"]
            );
            assert_eq!(a.args[0].2, vec![b, cfg.d_model], "x is bucket-shaped");
            assert_eq!(
                a.args[6].2,
                vec![cfg.slots, cfg.ctx, cfg.d_model / 2],
                "caches stay full-[S]"
            );
            let (_, dt, shape) = &a.args[9];
            assert_eq!(dt, "int32");
            assert_eq!(shape, &vec![b], "lanes is [B]");
        }
    }

    #[test]
    fn prefill_chunk_section_and_artifacts_are_consistent() {
        let Some(m) = manifest() else { return };
        let chunk = m
            .prefill_chunk
            .expect("manifest predates prefill_chunk — re-run `make artifacts`");
        for entry in m.models.values() {
            let cfg = &entry.config;
            assert_eq!(cfg.ctx % chunk, 0, "{}: chunk must divide ctx", cfg.name);
            for key in crate::model::prefill::CHUNK_ARTIFACT_KEYS {
                assert!(
                    entry.artifacts.contains_key(key),
                    "{}: missing chunk artifact {key}",
                    cfg.name
                );
            }
            let a = entry.artifact("tpattn_chunk").unwrap();
            let names: Vec<&str> = a.args.iter().map(|(n, _, _)| n.as_str()).collect();
            assert_eq!(
                names,
                ["h", "ln1", "wq", "wk", "wv", "wo", "kcache", "vcache", "slot", "off", "valid"]
            );
            assert_eq!(a.args[0].2, vec![chunk, cfg.d_model], "h is chunk-shaped");
            assert_eq!(
                a.args[6].2,
                vec![cfg.slots, cfg.ctx, cfg.d_model / 2],
                "caches stay full-[S]"
            );
            for i in [8, 9, 10] {
                let (_, dt, shape) = &a.args[i];
                assert_eq!(dt, "int32");
                assert!(shape.is_empty(), "slot/off/valid are scalars");
            }
        }
    }

    #[test]
    fn kv_pages_section_and_paged_artifacts_are_consistent() {
        let Some(m) = manifest() else { return };
        let chunk = m.prefill_chunk.expect("prefill_chunk");
        for entry in m.models.values() {
            let cfg = &entry.config;
            let kp = entry
                .kv_pages
                .expect("manifest predates kv_pages — re-run `make artifacts`");
            assert_eq!(chunk % kp.page_tokens, 0, "page must divide chunk");
            assert_eq!(kp.blocks_per_slot * kp.page_tokens, cfg.ctx);
            assert!(kp.pool_pages_half >= kp.min_pool_pages(cfg.slots));
            assert!(kp.pool_pages_full >= kp.min_pool_pages(cfg.slots));

            let a = entry.artifact("tpattn_chunk_paged").unwrap();
            let names: Vec<&str> = a.args.iter().map(|(n, _, _)| n.as_str()).collect();
            assert_eq!(
                names,
                ["h", "ln1", "wq", "wk", "wv", "wo", "kpool", "vpool", "pt", "off", "valid"]
            );
            assert_eq!(
                a.args[6].2,
                vec![kp.pool_pages_half, kp.page_tokens, cfg.d_model / 2],
                "half pool shape"
            );
            let (_, dt, shape) = &a.args[8];
            assert_eq!(dt, "int32");
            assert_eq!(shape, &vec![kp.blocks_per_slot], "pt is [nblocks]");

            let lp = entry.artifact("lpattn_chunk_paged").unwrap();
            assert_eq!(
                lp.args[6].2,
                vec![kp.pool_pages_full, kp.page_tokens, cfg.d_model],
                "full pool shape"
            );

            for &b in &entry.batch_buckets {
                let a = entry.artifact(&format!("tpattn_decode_paged_b{b}")).unwrap();
                let names: Vec<&str> =
                    a.args.iter().map(|(n, _, _)| n.as_str()).collect();
                assert_eq!(
                    names,
                    ["x", "ln1", "wq", "wk", "wv", "wo", "kpool", "vpool", "pos", "pt"]
                );
                let (_, dt, shape) = &a.args[9];
                assert_eq!(dt, "int32");
                assert_eq!(shape, &vec![b, kp.blocks_per_slot], "pt is [B, nblocks]");
            }
        }
    }

    #[test]
    fn variants_section_lists_strictly_descending_depth_tiers() {
        let Some(m) = manifest() else { return };
        for entry in m.models.values() {
            let n = entry.config.n_layers;
            let ids: Vec<&str> =
                entry.variants.keys().map(|v| v.as_str()).collect();
            assert_eq!(
                ids,
                ["dense", "lp", "lp_aggr"],
                "{}: stale variants (re-run `make artifacts`)",
                entry.config.name
            );
            let dense = &entry.variants[&VariantId::dense()];
            assert_eq!(dense.stages.len(), n, "dense must be the full stack");
            assert!(dense.stages.iter().all(|s| s.len() == 1));
            let mut prev = usize::MAX;
            for spec in entry.variants.values() {
                // each layer at most once, in range, arity 1 or 2
                let mut seen = vec![false; n];
                for st in &spec.stages {
                    assert!(matches!(st.len(), 1 | 2), "{}: arity", spec.id);
                    for &l in st {
                        assert!(l < n && !seen[l], "{}: layer {l}", spec.id);
                        seen[l] = true;
                    }
                }
                assert!(
                    spec.stages.len() < prev,
                    "tiers must strictly descend in depth"
                );
                prev = spec.stages.len();
            }
        }
    }

    #[test]
    fn legacy_manifest_synthesizes_a_dense_variant() {
        let spec = VariantSpec::dense(3);
        assert_eq!(spec.id, VariantId::dense());
        assert_eq!(spec.stages, vec![vec![0], vec![1], vec![2]]);
        assert_eq!(VariantId::new("lp").to_string(), "lp");
        assert_eq!(VariantId::from("lp").as_str(), "lp");
        assert!(VariantId::dense() < VariantId::new("lp"), "BTreeMap order");
    }

    #[test]
    fn model_config_is_consistent() {
        let Some(m) = manifest() else { return };
        let c = &m.model("td-small").unwrap().config;
        assert_eq!(c.d_model, c.n_heads * c.head_dim);
        assert!(c.n_params() > 1_000_000);
    }

    #[test]
    fn artifact_files_exist() {
        let Some(m) = manifest() else { return };
        for entry in m.models.values() {
            for a in entry.artifacts.values() {
                assert!(a.file.exists(), "missing {:?}", a.file);
                assert!(!a.args.is_empty() || a.name.starts_with("embed"), "{}", a.name);
            }
        }
    }

    #[test]
    fn decode_artifacts_have_expected_signature() {
        let Some(m) = manifest() else { return };
        let e = m.model("td-small").unwrap();
        let a = e.artifact("tpattn_decode").unwrap();
        let names: Vec<&str> = a.args.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, ["x", "ln1", "wq", "wk", "wv", "wo", "kcache", "vcache", "pos"]);
        let (_, dt, shape) = &a.args[8];
        assert_eq!(dt, "int32");
        assert_eq!(shape, &vec![e.config.slots]);
    }
}
