//! PJRT engine: compile HLO-text artifacts, manage device buffers, execute.
//!
//! One `Engine` per simulated accelerator (worker threads each construct
//! their own — the PJRT wrapper types are not `Send`, which conveniently
//! enforces the "each worker owns its device" discipline of the simulated
//! mesh).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::error::{Error, Result};

/// A host-side argument value shipped across threads (Literals are not
/// Send; raw vectors are).
#[derive(Clone, Debug)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostValue {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostValue {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 { shape, data }
    }

    pub fn scalar_i32(v: i32) -> HostValue {
        HostValue::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } | HostValue::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => Err(Error::msg("expected f32 value")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => Err(Error::msg("expected f32 value")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            _ => Err(Error::msg("expected i32 value")),
        }
    }

    pub fn num_bytes(&self) -> usize {
        match self {
            HostValue::F32 { data, .. } => data.len() * 4,
            HostValue::I32 { data, .. } => data.len() * 4,
        }
    }
}

/// PJRT CPU engine with an executable cache.
pub struct Engine {
    client: PjRtClient,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: PjRtClient::cpu()?, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the HLO-text artifact at `path`.
    pub fn load(&self, path: &Path) -> Result<Rc<PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::msg(format!("loading HLO text {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    // ---- buffers -----------------------------------------------------------

    pub fn upload(&self, v: &HostValue) -> Result<PjRtBuffer> {
        let b = match v {
            HostValue::F32 { shape, data } => {
                self.client.buffer_from_host_buffer::<f32>(data, shape, None)?
            }
            HostValue::I32 { shape, data } => {
                self.client.buffer_from_host_buffer::<i32>(data, shape, None)?
            }
        };
        Ok(b)
    }

    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    // ---- execution -----------------------------------------------------------

    /// Execute with device-resident buffers; outputs come back as host
    /// literals. The patched xla crate sets `untuple_result`, so each tuple
    /// element of the AOT executable arrives as its own device buffer.
    pub fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let outs = exe.execute_b::<&PjRtBuffer>(args)?;
        outs[0].iter().map(|b| Ok(b.to_literal_sync()?)).collect()
    }

    /// Execute, keeping every output as a device-resident buffer.
    pub fn run_raw(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[&PjRtBuffer],
    ) -> Result<Vec<PjRtBuffer>> {
        let mut outs = exe.execute_b::<&PjRtBuffer>(args)?;
        Ok(outs.remove(0))
    }

    /// Convenience: upload host values, execute, download host values.
    pub fn call(&self, exe: &PjRtLoadedExecutable, args: &[HostValue]) -> Result<Vec<HostValue>> {
        let bufs: Vec<PjRtBuffer> =
            args.iter().map(|a| self.upload(a)).collect::<Result<_>>()?;
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        let lits = self.run(exe, &refs)?;
        lits.iter().map(literal_to_host).collect()
    }
}

/// Convert an output literal to a host value.
pub fn literal_to_host(lit: &Literal) -> Result<HostValue> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostValue::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
        xla::ElementType::S32 => Ok(HostValue::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
        other => Err(Error::msg(format!("unsupported output element type {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine_and_manifest() -> Option<(Engine, crate::runtime::Manifest)> {
        let m = crate::runtime::Manifest::load_default().ok()?;
        let e = Engine::cpu().ok()?;
        Some((e, m))
    }

    #[test]
    fn engine_boots_cpu() {
        let e = Engine::cpu().unwrap();
        assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
    }

    #[test]
    fn embed_artifact_runs_end_to_end() {
        let Some((e, m)) = engine_and_manifest() else { return };
        let entry = m.model("td-small").unwrap();
        let cfg = &entry.config;
        let art = entry.artifact("embed_t32").unwrap();
        let exe = e.load(&art.file).unwrap();
        // tokens 0..32, embedding = identity-ish random table
        let tokens: Vec<i32> = (0..32).collect();
        let emb: Vec<f32> = (0..cfg.vocab * cfg.d_model).map(|i| (i % 97) as f32 * 0.01).collect();
        let outs = e
            .call(
                &exe,
                &[
                    HostValue::i32(vec![32], tokens),
                    HostValue::f32(vec![cfg.vocab, cfg.d_model], emb.clone()),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 1);
        let h = outs[0].as_f32().unwrap();
        assert_eq!(outs[0].shape(), &[32, cfg.d_model]);
        // row t of the output must equal row t of the table (token ids 0..32)
        for t in 0..32 {
            assert_eq!(
                h[t * cfg.d_model..(t + 1) * cfg.d_model],
                emb[t * cfg.d_model..(t + 1) * cfg.d_model]
            );
        }
    }

    #[test]
    fn executable_cache_hits() {
        let Some((e, m)) = engine_and_manifest() else { return };
        let art = m.model("td-small").unwrap().artifact("embed_t32").unwrap();
        let a = e.load(&art.file).unwrap();
        let b = e.load(&art.file).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn host_value_shape_checks() {
        let v = HostValue::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(v.num_bytes(), 24);
        assert!(v.as_i32().is_err());
    }
}
