//! Resident-buffer key schema, shared by the weight loader, the serving
//! dispatch paths and the static verifier.
//!
//! Every buffer the mesh holds resident is addressed by a string name, and
//! three subsystems must agree on the naming scheme: `ServingModel`'s
//! upload/dispatch code writes and binds the names, the trace emitters
//! mirror them into [`crate::verify::DispatchTrace`]s, and
//! `verify::binding_check` classifies a missing read by which schema family
//! the name belongs to. Pre-refactor each site format!-ed its own copy;
//! this module is the single constructor set so a schema change cannot
//! drift between the loader, the hot path and the checker.
//!
//! Families:
//!
//! * `l{i}.tp.{field}` / `l{i}.full.{field}` — layer weights, keyed by
//!   layer index and sharding form ([`weight`]);
//! * `emb`, `lnf`, `wout` — the rank-0 embedding/head set;
//! * `kv.{tier}.{k|v}.{sidx}` — the dense per-variant KV caches
//!   ([`kv_cache`]);
//! * `kvpool.{half|full}.{k|v}` — the shared paged KV pools, one per cache
//!   width, tier-agnostic ([`kv_pool`]).

use crate::runtime::VariantId;

/// Embedding/head weights owned by rank 0.
pub const HEAD_WEIGHT_KEYS: [&str; 3] = ["emb", "lnf", "wout"];

/// Layer-weight resident name: `l{layer}.{form}.{field}` where `form` is
/// `tp` (this rank's Megatron shard) or `full` (the full-width copy an LP
/// stage binds).
pub fn weight(layer: usize, form: &str, field: &str) -> String {
    format!("l{layer}.{form}.{field}")
}

/// Dense KV-cache resident name of one variant stage (`kv` ∈ {k, v}).
pub fn kv_cache(vid: &VariantId, kv: &str, sidx: usize) -> String {
    format!("kv.{vid}.{kv}.{sidx}")
}

/// Paged KV-pool resident name (`width` ∈ {half, full}, `kv` ∈ {k, v}) —
/// one `[P, page, w]` pool per cache width, shared by every tier and slot.
pub fn kv_pool(width: &str, kv: &str) -> String {
    format!("kvpool.{width}.{kv}")
}

/// Does `name` follow the weight-key schema (embedding/head set or a
/// `l{i}.tp.* / l{i}.full.*` layer key)?
pub fn is_weight_key(name: &str) -> bool {
    HEAD_WEIGHT_KEYS.contains(&name)
        || (name.starts_with('l') && (name.contains(".tp.") || name.contains(".full.")))
}

/// Does `name` follow the KV schema (a dense per-variant cache or a shared
/// paged pool)?
pub fn is_kv_key(name: &str) -> bool {
    name.starts_with("kv.") || name.starts_with("kvpool.")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_match_the_documented_schema() {
        assert_eq!(weight(3, "tp", "wq"), "l3.tp.wq");
        assert_eq!(weight(0, "full", "ln2"), "l0.full.ln2");
        assert_eq!(kv_cache(&VariantId::new("lp"), "k", 4), "kv.lp.k.4");
        assert_eq!(kv_pool("half", "v"), "kvpool.half.v");
    }

    #[test]
    fn recognizers_classify_every_family() {
        for name in ["emb", "lnf", "wout", "l0.tp.wq", "l11.full.wd"] {
            assert!(is_weight_key(name), "{name}");
            assert!(!is_kv_key(name), "{name}");
        }
        for name in ["kv.dense.k.0", "kv.lp_aggr.v.7", "kvpool.half.k", "kvpool.full.v"] {
            assert!(is_kv_key(name), "{name}");
            assert!(!is_weight_key(name), "{name}");
        }
        // names outside both schemas (activations, scalars) match neither
        for name in ["act", "act.partial", "pos", "lanes", "slot", "pt", "tmp.k"] {
            assert!(!is_weight_key(name) && !is_kv_key(name), "{name}");
        }
    }
}
