//! Phase profiler: named wall-clock accumulators, the tool behind the
//! Table-3 sync-vs-compute breakdown (paper App. C flame graphs).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::{self, Value};

#[derive(Default, Debug)]
pub struct PhaseTimer {
    acc: BTreeMap<String, (Duration, u64)>,
}

pub struct PhaseGuard<'a> {
    timer: &'a mut PhaseTimer,
    name: String,
    start: Instant,
}

impl PhaseTimer {
    pub fn new() -> PhaseTimer {
        PhaseTimer::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        let e = self.acc.entry(name.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// RAII variant for phases spanning non-closure code.
    pub fn start(&mut self, name: &str) -> PhaseGuard<'_> {
        PhaseGuard { name: name.to_string(), start: Instant::now(), timer: self }
    }

    pub fn total(&self, name: &str) -> Duration {
        self.acc.get(name).map(|(d, _)| *d).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.acc.get(name).map(|(_, c)| *c).unwrap_or(0)
    }

    pub fn total_ms(&self, name: &str) -> f64 {
        self.total(name).as_secs_f64() * 1e3
    }

    /// Table rows: (phase, total ms, calls, ms/call), hottest phase first
    /// (total time descending; name breaks ties so the order is total).
    pub fn rows(&self) -> Vec<(String, f64, u64, f64)> {
        let mut rows: Vec<_> = self
            .acc
            .iter()
            .map(|(k, (d, c))| {
                let ms = d.as_secs_f64() * 1e3;
                (k.clone(), ms, *c, if *c > 0 { ms / *c as f64 } else { 0.0 })
            })
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0)));
        rows
    }

    pub fn report(&self) -> String {
        let mut out = format!("{:<24} {:>12} {:>8} {:>12}\n", "phase", "total ms", "calls", "ms/call");
        for (name, ms, calls, per) in self.rows() {
            out += &format!("{name:<24} {ms:>12.2} {calls:>8} {per:>12.3}\n");
        }
        out
    }

    /// JSON form of [`PhaseTimer::rows`] (same hottest-first order), for
    /// machine-readable artifacts like `table3_profile`'s phase breakdown.
    pub fn to_json(&self) -> Value {
        let phases = self
            .rows()
            .into_iter()
            .map(|(name, ms, calls, per)| {
                json::obj(vec![
                    ("phase", json::s(name)),
                    ("total_ms", json::num(ms)),
                    ("calls", json::num(calls as f64)),
                    ("ms_per_call", json::num(per)),
                ])
            })
            .collect();
        json::obj(vec![("phases", json::arr(phases))])
    }

    pub fn reset(&mut self) {
        self.acc.clear();
    }
}

impl Drop for PhaseGuard<'_> {
    fn drop(&mut self) {
        let d = self.start.elapsed();
        self.timer.add(&self.name, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_phases() {
        let mut t = PhaseTimer::new();
        t.time("a", || std::thread::sleep(Duration::from_millis(2)));
        t.time("a", || std::thread::sleep(Duration::from_millis(2)));
        t.time("b", || ());
        assert_eq!(t.count("a"), 2);
        assert!(t.total_ms("a") >= 4.0);
        assert_eq!(t.count("b"), 1);
        assert!(t.report().contains("a"));
    }

    #[test]
    fn guard_records_on_drop() {
        let mut t = PhaseTimer::new();
        {
            let _g = t.start("span");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(t.count("span"), 1);
        assert!(t.total("span") >= Duration::from_millis(1));
    }

    /// Rows (and so the report and JSON) list the hottest phase first —
    /// reading a profile should not require scanning an alphabetical table.
    #[test]
    fn rows_sort_by_total_time_descending() {
        let mut t = PhaseTimer::new();
        t.add("alpha", Duration::from_millis(1));
        t.add("zeta", Duration::from_millis(30));
        t.add("mid", Duration::from_millis(10));
        let names: Vec<&str> = t.rows().iter().map(|r| r.0.as_str()).collect();
        assert_eq!(names, ["zeta", "mid", "alpha"]);
        let j = t.to_json();
        let phases = j.get("phases").and_then(Value::as_arr).unwrap();
        assert_eq!(phases.len(), 3);
        assert_eq!(phases[0].get("phase").and_then(Value::as_str), Some("zeta"));
        assert_eq!(phases[2].get("calls").and_then(Value::as_f64), Some(1.0));
        assert!(phases[0].get("total_ms").and_then(Value::as_f64).unwrap() >= 30.0);
    }

    #[test]
    fn reset_clears() {
        let mut t = PhaseTimer::new();
        t.time("x", || ());
        t.reset();
        assert_eq!(t.count("x"), 0);
    }
}
