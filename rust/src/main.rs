//! `truedepth` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                         manifest + checkpoint inventory
//!   verify    [--artifacts DIR] [--strict]
//!                                static plan/binding/collective check of the
//!                                artifact manifest (prints every diagnostic;
//!                                --strict also requires artifact files on
//!                                disk and promotes warnings to errors)
//!   generate  --model M --prompt P [--depth D] [--max-new N] [--no-simnet]
//!   ppl       --model M [--transform T --s S --e E]
//!   serve     --model M [--depth D | --tiers] [--config run.toml]
//!             [--max-cached-execs N] --requests N
//!             [--paged [--page-pool N]]
//!             [--trace-out F] [--metrics-out F]
//!             [--listen ADDR [--http-workers N] [--http-backlog N]]
//!                                synthetic load demo; --tiers serves every
//!                                manifest plan variant concurrently
//!                                (requests cycle dense/lp/lp_aggr).
//!                                --paged serves from the paged KV cache and
//!                                prefixes every request with one shared
//!                                system prompt, so the prefix index prefills
//!                                it once (kv.* section in the snapshot);
//!                                --page-pool caps the logical page pools to
//!                                model memory pressure.
//!                                --config applies a RunConfig TOML
//!                                ([interconnect]/[device] cost model +
//!                                [runtime] max_cached_execs); the CLI flag
//!                                overrides the [runtime] knob.
//!                                --trace-out writes a Chrome/Perfetto trace
//!                                of the run on the simulated clock;
//!                                --metrics-out writes a machine-readable
//!                                metrics snapshot (both deterministic; see
//!                                README "Observability")
//!                                --listen ADDR serves the HTTP API instead
//!                                of synthetic load: POST /v1/completions
//!                                (SSE streaming via "stream": true),
//!                                GET /healthz, GET /metrics,
//!                                POST /admin/shutdown (see docs/api.md)
//!   apidoc                       print docs/api.md, generated from the
//!                                api:: schema (regenerate after API edits)
//!
//! Examples live in `examples/` (quickstart, serve_batch, depth_explorer);
//! experiment regenerators in `rust/src/bin/` (see DESIGN.md).

use truedepth::api::CompletionRequest;
use truedepth::cli::Args;
use truedepth::config::ServerConfig;
use truedepth::coordinator::Server;
use truedepth::eval::ppl::{eval_windows, perplexity};
use truedepth::gen::{generate, Sampler};
use truedepth::harness::{default_net, no_net, ScoringCtx};
use truedepth::model::{transform, Scorer, ServingModel};
use truedepth::obs::{MetricsSnapshot, Tracer};
use truedepth::text::corpus::{self, DATA_SEED};
use truedepth::util::rng::SplitMix64;

fn main() {
    let args = Args::from_env(&["no-simnet", "tiers", "strict", "paged", "help"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "info" => info(),
        "verify" => cmd_verify(&args),
        "generate" => cmd_generate(&args),
        "ppl" => cmd_ppl(&args),
        "serve" => cmd_serve(&args),
        "apidoc" => {
            print!("{}", truedepth::api::docs::render_api_md());
            Ok(())
        }
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

const HELP: &str = "truedepth — Layer Parallelism for LLM inference
usage: truedepth <info|verify|generate|ppl|serve|apidoc> [options]   (see src/main.rs docs)";

fn cmd_verify(args: &Args) -> truedepth::Result<()> {
    let dir = match args.get("artifacts") {
        Some(p) => std::path::PathBuf::from(p),
        None => truedepth::repo_root().join("artifacts"),
    };
    truedepth::verify::run_cli(&dir, args.flag("strict"))
}

fn info() -> truedepth::Result<()> {
    let manifest = truedepth::runtime::Manifest::load_default()?;
    println!("artifacts: {} (impl: {})", manifest.dir.display(), manifest.impl_name);
    println!("seq buckets: {:?}", manifest.seq_buckets);
    for (name, entry) in &manifest.models {
        let c = &entry.config;
        let ckpt = truedepth::repo_root().join("checkpoints").join(name).join("weights.tdw");
        println!(
            "model {name}: {} layers, d={}, heads={}, ~{:.1}M params, {} artifacts, checkpoint: {}",
            c.n_layers,
            c.d_model,
            c.n_heads,
            c.n_params() as f64 / 1e6,
            entry.artifacts.len(),
            if ckpt.exists() { "yes" } else { "no (run `make models`)" }
        );
    }
    Ok(())
}

fn plan_for(args: &Args, n: usize) -> truedepth::Result<truedepth::model::GraphPlan> {
    let depth = args.get_usize("depth", n);
    if depth == n {
        return Ok(transform::sequential(n));
    }
    transform::lp_for_depth(n, depth, args.get_usize("end", n - 2))
        .ok_or_else(|| truedepth::Error::msg(format!("no LP window for depth {depth}")))
}

fn cmd_generate(args: &Args) -> truedepth::Result<()> {
    let model = args.get_or("model", "td-small");
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let n = ctx.entry().config.n_layers;
    let plan = plan_for(args, n)?;
    let net = if args.flag("no-simnet") { no_net() } else { default_net() };
    let serving = ServingModel::new(&ctx.manifest, model, &weights, &plan, net)?;
    let prompt = args.get_or("prompt", "the capital of avaria is");
    let g = generate(&serving, prompt, args.get_usize("max-new", 32), &Sampler::Greedy)?;
    println!("plan: {} (depth {})", plan.describe(), plan.effective_depth());
    println!("prompt: {prompt}");
    println!("output: {}", g.text);
    println!(
        "prefill {:.1} ms, decode {:.1} ms ({:.1} tok/s)",
        g.prefill_ms,
        g.decode_ms,
        g.tokens.len() as f64 / (g.decode_ms / 1e3)
    );
    Ok(())
}

fn cmd_ppl(args: &Args) -> truedepth::Result<()> {
    let model = args.get_or("model", "td-small");
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let (s, e) = (args.get_usize("s", 0), args.get_usize("e", 0));
    let plan = match args.get_or("transform", "seq") {
        "seq" => transform::sequential(n),
        "shuffle" => {
            let mut rng = SplitMix64::new(1);
            transform::shuffle(n, s, e, &mut rng)
        }
        "prune" => transform::prune(n, s, e),
        "merge" => transform::merge(n, s, e),
        "parallel" => transform::parallel(n, s, e),
        "pair" => transform::pair_parallel(n, s, e, true),
        other => return Err(truedepth::Error::msg(format!("unknown transform {other}"))),
    };
    let scorer = Scorer::new(&ctx.engine, entry, &weights, 128)?;
    let windows = eval_windows(128, args.get_usize("windows", 2), DATA_SEED);
    let ppl = perplexity(&scorer, &plan, &windows)?;
    println!("plan: {} (depth {})", plan.describe(), plan.effective_depth());
    println!("perplexity: {ppl:.4}");
    Ok(())
}

fn cmd_serve(args: &Args) -> truedepth::Result<()> {
    let model = args.get_or("model", "td-small");
    let n_requests = args.get_usize("requests", 12);
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let n = ctx.entry().config.n_layers;
    // --config: a RunConfig TOML supplies the cost model ([interconnect] +
    // [device]) and the [runtime] section; without it the calibrated
    // defaults apply (--no-simnet still disables the α–β term either way).
    let run_cfg = match args.get("config") {
        Some(p) => truedepth::config::RunConfig::from_file(std::path::Path::new(p))?,
        None => truedepth::config::RunConfig::default(),
    };
    let mut net = if args.get("config").is_some() {
        run_cfg.interconnect.clone()
    } else if args.flag("no-simnet") {
        no_net()
    } else {
        default_net()
    };
    if args.flag("no-simnet") {
        net.enabled = false;
    }
    let cost = truedepth::parallel::CostModel::new(net, run_cfg.device.clone());
    // --tiers: one resident weight set, every manifest plan variant served
    // concurrently (the plan-variant registry); default: one --depth plan.
    let multi = args.flag("tiers");
    let mut serving = if multi {
        ServingModel::from_manifest_with_cost(&ctx.manifest, model, &weights, cost)?
    } else {
        let plan = plan_for(args, n)?;
        ServingModel::new_with_cost(&ctx.manifest, model, &weights, &plan, cost)?
    };
    // --paged: serve from the paged KV cache (+ shared-prefix index);
    // --page-pool shrinks the logical pools to model memory pressure —
    // over-pool requests are rejected at admission, cold shared blocks
    // are evicted under load.
    let paged = args.flag("paged");
    if paged {
        serving.enable_paging()?;
        let pool = args.get_usize("page-pool", 0);
        if pool > 0 {
            serving.set_page_capacity(pool);
        }
    }
    // `[runtime] max_cached_execs` (CLI flag overrides the config file;
    // 0 / absent = unbounded): LRU-evict compiled executables beyond the
    // cap, recompiling transparently on reuse.
    let cap = match args.get_usize("max-cached-execs", 0) {
        0 => run_cfg.runtime.max_cached_execs,
        c => Some(c),
    };
    serving.set_exec_cache_cap(cap);
    let tiers: Vec<String> =
        serving.variant_ids().iter().map(|v| v.as_str().to_string()).collect();
    let depths: Vec<String> = serving
        .variant_ids()
        .iter()
        .map(|v| format!("{v}:{}", serving.variant(v).unwrap().effective_depth()))
        .collect();
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| std::sync::Arc::new(Tracer::new()));
    let server = std::sync::Arc::new(match &tracer {
        Some(t) => Server::start_traced(serving, &ServerConfig::default(), t.clone()),
        None => Server::start(serving, &ServerConfig::default()),
    });
    let metrics = server.metrics.clone();

    if let Some(listen) = args.get("listen") {
        // network mode: serve the HTTP API until POST /admin/shutdown
        let cfg = truedepth::serve::HttpConfig {
            workers: args.get_usize("http-workers", 4),
            backlog: args.get_usize("http-backlog", 16),
        };
        let edge = truedepth::serve::serve(server.clone(), listen, &cfg)?;
        println!(
            "serving {model} [{}] on http://{} — POST /v1/completions (docs/api.md)",
            depths.join(" "),
            edge.local_addr()
        );
        edge.wait();
        println!("{}", metrics.report());
    } else {
        println!(
            "serving {model} [{}] — {n_requests} synthetic requests",
            depths.join(" ")
        );
        let t0 = std::time::Instant::now();
        // --paged load: every request carries the same system prompt ahead
        // of its own document snippet, so the shared-prefix index prefills
        // those leading blocks once and every later request attaches them —
        // the reuse shows up as kv.prefix_hits in the report and snapshot.
        const SYSTEM_PROMPT: &str = "system: you are a terse assistant. answer only from the \
             provided context, cite sources, never speculate. ";
        let handles: Vec<_> = (0..n_requests)
            .map(|i| {
                let doc = corpus::eval_doc(DATA_SEED, 1000 + i as u64);
                let snippet = &doc[..doc.len().min(if paged { 16 } else { 48 })];
                let prompt = if paged {
                    format!("{SYSTEM_PROMPT}{snippet}")
                } else {
                    snippet.to_string()
                };
                let mut req = CompletionRequest::new(prompt).max_tokens(16);
                if multi {
                    req = req.tier(&tiers[i % tiers.len()]);
                }
                server.request(req)
            })
            .collect::<truedepth::Result<_>>()?;
        let mut total_tokens = 0;
        for h in handles {
            total_tokens += h.wait()?.generated_tokens();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!("{}", metrics.report());
        println!(
            "throughput: {:.1} generated tok/s ({total_tokens} tokens / {wall:.2}s)",
            total_tokens as f64 / wall
        );
    }
    // dropping the last handle drains the scheduler, which flushes the
    // mesh event track into the tracer — export only after it returns
    drop(server);
    if let (Some(tr), Some(path)) = (&tracer, &trace_out) {
        tr.write_chrome(path)?;
        println!("trace: {} ({} events)", path.display(), tr.len());
    }
    if let Some(path) = &metrics_out {
        MetricsSnapshot::new("serve").with_server(&metrics).write(path)?;
        println!("metrics snapshot: {}", path.display());
    }
    Ok(())
}
